"""The paper's convex experiments end-to-end (Sec 2.3):

1. Beck-Teboulle feasibility — separation fails, O(1/n) residuals.
2. Over-parameterized regression — linear rate for T = 1..inf; larger T
   means fewer communication rounds.

    PYTHONPATH=src python examples/convex_feasibility.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.reference import rounds_to, run_alg1
from repro.data.convex import (beck_teboulle_losses,
                               make_overparam_regression)


def main():
    print("== 1. synthetic feasibility (no separation -> ~1/n) ==")
    out = run_alg1(beck_teboulle_losses(), jnp.array([1.5, 0.8]),
                   lr=0.4, T=10, rounds=800)
    gsq = np.asarray(out["gsq"])
    n = np.arange(1, len(gsq) + 1)
    slope = np.polyfit(np.log(n[80:]), np.log(gsq[80:]), 1)[0]
    print(f"  final x = {np.asarray(out['w']).round(4)}  (optimum: [0 0])")
    print(f"  ||grad||^2: {gsq[0]:.2e} -> {gsq[-1]:.2e}; "
          f"log-log slope {slope:.2f} (paper reference: -1)")

    print("== 2. over-parameterized regression (linear rate, any T) ==")
    prob = make_overparam_regression(n=62, d=2000, m=2)
    losses = prob.local_losses()
    w0 = jnp.zeros(2000)
    for label, T, thr in [("T=1", 1, None), ("T=10", 10, None),
                          ("T=100", 100, None), ("T=inf", None, 1e-8)]:
        out = run_alg1(losses, w0, lr=2.0, T=T, rounds=150, threshold=thr,
                       stop_below=1e-13)
        r = rounds_to(out["gsq"], 1e-7)
        print(f"  {label:6s} rounds to ||grad||^2<=1e-7: {r}"
              f"   (final {out['gsq'][-1]:.1e})")
    print("  -> more local work, fewer communication rounds (paper Fig 2b)")


if __name__ == "__main__":
    main()
