"""Batched-serving example: greedy-decode 4 concurrent requests on a
reduced hybrid (Mamba2 + shared-attention) model — exercising the O(1)
recurrent-state cache path used by the long_500k dry-run shape.

    PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    for arch in ("zamba2-7b", "qwen3-32b"):
        print(f"=== {arch} (reduced) ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--reduced", "--batch", "4", "--prompt-len", "12",
             "--gen", "12"],
            cwd=str(ROOT), check=True,
            env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})


if __name__ == "__main__":
    main()
