"""Batched-serving example: the continuous-batching engine over Poisson
arrivals on a reduced hybrid (Mamba2 + shared-attention) model and a
reduced dense GQA model — exercising the paged KV + O(1) recurrent-state
cache paths, with per-request parity checked against isolated decode.

    PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "benchmarks"))

from common import child_env  # noqa: E402


def main():
    for arch in ("zamba2-7b", "qwen3-32b"):
        print(f"=== {arch} (reduced, continuous batching) ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--reduced", "--engine", "continuous", "--slots", "3",
             "--page-size", "4", "--requests", "6", "--rate", "8",
             "--prompt-max", "12", "--gen", "4", "--gen-max", "8",
             "--check-parity"],
            cwd=str(ROOT), check=True, env=child_env())


if __name__ == "__main__":
    main()
