"""End-to-end training driver: a ~100M-parameter transformer trained with
the paper's local-SGD schedule through the production launcher.

Default run is CPU-sized (reduced rounds); pass --full for the complete
few-hundred-round run described in the deliverables.

    PYTHONPATH=src python examples/train_localsgd.py            # quick
    PYTHONPATH=src python examples/train_localsgd.py --full     # ~hours on CPU
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    full = "--full" in sys.argv
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "paper-lenet",            # 8L d=768 vocab 32k ~ 110M
        "--mode", "localsgd",
        "--groups", "4", "--per-group", "2",
        "--seq", "128",
        "--t-inner", "4",
        "--opt", "adamw", "--lr", "3e-4",
        "--rounds", "300" if full else "10",
        "--checkpoint", str(ROOT / "experiments" / "ckpt" / "lenet100m"),
    ]
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    print("+", " ".join(args[1:]))
    subprocess.run(args, cwd=str(ROOT), env=env, check=True)


if __name__ == "__main__":
    main()
