"""Quickstart: the paper's algorithm in ~40 lines against the public API.

Builds a reduced qwen3-32b, runs 5 local-SGD communication rounds
(m=4 nodes, T=8 local steps) and shows the loss dropping while only 5
model averages (vs 40 gradient all-reduces for sync-DP) are communicated.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.data.synthetic import fixed_group_batches
from repro.models import build_model


def main():
    cfg = get_config("qwen3-32b").reduced()       # 2L, d=256 smoke variant
    model = build_model(cfg, schedule="rect")
    params = model.init(jax.random.PRNGKey(0))

    G, T = 4, 8                                    # m nodes, local steps
    opt = optim.sgd(0.05)
    round_ = jax.jit(lsgd.make_local_round(
        model.loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=T)))

    state = lsgd.init_state(params, opt, n_groups=G)
    batch = {"tokens": jnp.asarray(
        fixed_group_batches(cfg.vocab_size, seq_len=64, n_groups=G,
                            per_group=2)["tokens"])}

    print(f"arch={cfg.name}  m={G} nodes  T={T} local steps/round")
    for n in range(5):
        state, m = round_(state, batch)
        print(f"round {n}: mean local loss {float(jnp.mean(m['loss'])):.4f}"
              f"  grad_sq {float(jnp.mean(m['grad_sq'])):.3e}"
              f"  (1 model average <-> {G * T} local GD steps)")
    print("communicated 5 averages; sync-DP would have all-reduced "
          f"{5 * T} gradients")


if __name__ == "__main__":
    main()
