"""Sec-4 trade-off demo: the adaptive-T controller detects the local decay
order on the fly and sets T near the cost-optimal T*.

Quadratic local losses (linear decay)  -> small T* ~ log(1/r)
Quartic  local losses (sublinear decay)-> large T* ~ r^(-1/beta)

The last demo instantiates r from MEASURED communication instead of a
hand-picked constant: the comm subsystem's exact wire-byte accounting
(repro.comm, DESIGN.md §8) prices one exchange round per codec, and
``AdaptiveT.from_comm_bytes`` turns that into the cost ratio — cheaper
wire (int8 ~4x fewer bytes) means relatively pricier local steps, so the
controller converges to a SMALLER T*.

    PYTHONPATH=src python examples/adaptive_t.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro import comm as comm_mod
from repro.core import theory
from repro.core.controller import AdaptiveT
from repro.core.reference import make_local_T
from repro.data.convex import make_overparam_regression
from repro.launch.roofline import comm_round_seconds


def demo(name, power, lr, r=None, ctl=None):
    prob = make_overparam_regression(n=20, d=400, m=2, power=power, seed=0)
    losses = prob.local_losses()
    w = jnp.ones(400) * 0.1
    if ctl is None:
        ctl = AdaptiveT(r=r, ema=0.3)
    r = ctl.r
    print(f"-- {name} local losses, cost ratio r={r:.4g} --")
    T = 50
    for rnd in range(6):
        runners = [make_local_T(f, lr, T) for f in losses]
        outs = [run(w) for run in runners]
        w = jnp.mean(jnp.stack([o[0] for o in outs]), axis=0)
        traj = np.asarray(outs[0][1])           # node-0 ||grad||^2 per step
        T_new = ctl.update(traj)
        fit = ctl.history[-1][0] if ctl.history else None
        print(f"  round {rnd}: detected {fit.kind if fit else '?':9s} "
              f"(beta={fit.beta:.3f})  ->  T={T_new}")
        T = T_new
    if fit.kind == "linear":
        print(f"  closed form T* = {theory.t_star_linear(fit.beta, r):.1f}")
    else:
        print(f"  closed form T* = "
              f"{theory.t_star_sublinear(fit.a, fit.beta, r):.1f}")


def demo_measured_comm(n_model: int = 1_000_000, step_time_s: float = 2e-6):
    """r from MEASURED comm bytes (codec-aware) instead of a hand-picked
    constant — the old constant-r path above stays as the fallback.

    The exchange prices one round's exact wire bytes for an
    ``n_model``-parameter buffer (m=2 server uplinks); cutting the
    payload with int8 makes communication ~4x cheaper, so r = C_g/C_c
    rises and the controller settles on a smaller T*."""
    print(f"-- codec-aware r: server exchange, {n_model/1e6:.0f}M params, "
          f"step {step_time_s*1e6:.1f}us --")
    for codec in ("fp32", "int8"):
        ex = comm_mod.get_exchange("server", codec, n_groups=2)
        wire = ex.wire_bytes_per_round(n_model)
        ctl = AdaptiveT.from_comm_bytes(
            step_time_s, wire, bandwidth_bytes_per_s=50e9, ema=0.3)
        # equivalently: r = step_time_s / comm_round_seconds(wire)
        assert abs(ctl.r - step_time_s / comm_round_seconds(wire)) < 1e-12
        print(f"   codec {codec}: {wire:,} wire bytes/round "
              f"-> r = {ctl.r:.4g}")
        demo(f"quadratic ({codec} wire)", power=1, lr=1.0, ctl=ctl)


def demo_moment_codec(n_model: int = 1_000_000, step_time_s: float = 2e-6):
    """Stream-resolved r (DESIGN.md §10): with adamw the payload is
    params + TWO moment buffers, so the wire is dominated by the moments
    — compressing the params alone (the pre-§10 state: moments pinned at
    fp32) buys little. ``AdaptiveT.from_exchange`` prices the whole
    multi-stream payload through the per-stream codec policy, so the
    measured r now reflects the moment codec too."""
    print(f"-- stream-resolved r: adamw (m+v ride), {n_model/1e6:.0f}M "
          f"params, step {step_time_s*1e6:.1f}us --")
    moment_sizes = {"m": n_model, "v": n_model}
    for codec, mcodec in (("fp32", "fp32"), ("int8", "fp32"),
                          ("int8", "int8")):
        ex = comm_mod.get_exchange("server", codec, n_groups=2,
                                   moment_codec=mcodec)
        ctl = AdaptiveT.from_exchange(step_time_s, ex, n_model,
                                      moment_sizes, ema=0.3)
        by = ex.wire_bytes_by_stream(n_model, moment_sizes)
        print(f"   params={codec:5s} moments={mcodec:5s}: "
              f"{sum(by.values()):,} wire B/round "
              f"(params {by['params']:,} + moments "
              f"{by['m'] + by['v']:,}) -> r = {ctl.r:.4g}")
        demo(f"quadratic ({ex.name} wire)", power=1, lr=1.0, ctl=ctl)


def demo_online(rounds: int = 120):
    """The ONLINE controller (``--adaptive-t online``, DESIGN.md §14):
    instead of pricing r once and re-fitting only the decay order, every
    round feeds back the measured consensus contraction and codec error
    mass from the §13 telemetry. Early on the consensus guard holds T
    down (lossy exchanges barely keep the groups together); as the run
    converges and the consensus mass collapses, the relief factor
    sqrt(c0/consensus) ramps T up — fewer, longer rounds at the tail.
    Wire bytes per round are T-independent, so the ramp is a direct
    wire saving vs the static Sec-4 T* run to the same floor."""
    import jax

    from repro import optim
    from repro.core import localsgd as lsgd
    from repro.core.controller import OnlineT
    from repro.optim import packing

    g = 4
    rng = np.random.RandomState(0)
    A = rng.randn(g, 8, 40).astype(np.float32) / np.sqrt(40)
    w_star = rng.randn(40).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(40).astype(np.float32))}
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    ex = comm_mod.get_exchange("server", "int8", g)
    wire = ex.wire_bytes_per_round(layout.padded)

    def quad_loss(p, b):
        return 0.5 * jnp.sum((b["A"] @ p["w"] - b["b"]) ** 2)

    def run(make_t, tag):
        st = lsgd.init_state(params, opt, n_groups=g, layout=layout,
                             exchange=ex)
        ctl_rounds, cache, gsq, t_log = 0, {}, float("inf"), []
        while ctl_rounds < rounds and gsq > 1e-3:
            t = int(make_t())
            if t not in cache:
                cfg = lsgd.LocalSGDConfig(n_groups=g, inner_steps=t,
                                          metrics="traj")
                cache[t] = jax.jit(lsgd.make_local_round(
                    quad_loss, opt, cfg, layout=layout, exchange=ex))
            st, m = cache[t](st, batch)
            ctl_rounds += 1
            t_log.append(t)
            gsq = float(jnp.mean(m["grad_sq"]))
            yield m, t
        print(f"   {tag:9s}: {ctl_rounds} rounds x {wire:,} B "
              f"= {ctl_rounds * wire:,} wire B  "
              f"(gsq {gsq:.1e}, T path {t_log[0]}→{t_log[-1]})")

    print("-- online T: consensus telemetry ramps T as the run "
          "converges --")
    for _m, _t in run(lambda: 4, "static T=4"):
        pass
    ctl = OnlineT(r=1.0, t_min=1, t_max=64)
    state = {"t": 4}

    def online_t():
        return state["t"]

    shown = set()
    for m, t in run(online_t, "online"):
        cons = float(jnp.mean(m["consensus_sq"]))
        state["t"] = ctl.update(
            np.asarray(m["grad_sq_traj"])[0], t_used=t,
            local_s=1.0 * t, exchange_s=1.0,
            consensus_pre=cons,
            consensus_post=float(jnp.mean(m["consensus_sq_post"])),
            codec_err=sum(float(jnp.mean(v)) for k, v in m.items()
                          if k.startswith("codec_err/")))
        h = ctl.history[-1]
        bucket = len(ctl.history) // 20
        if bucket not in shown:            # a few waypoints, not 100 rows
            shown.add(bucket)
            print(f"   round {len(ctl.history):3d}: consensus "
                  f"{cons:.1e}  guard γ̂={h['gamma']:.2f}  "
                  f"relief={h['relief']:.1f}  -> T={h['t']}")


def main():
    demo("quadratic", power=1, lr=1.0, r=0.01)
    demo("quartic", power=2, lr=0.5, r=0.01)
    demo_measured_comm()
    demo_moment_codec()
    demo_online()


if __name__ == "__main__":
    main()
