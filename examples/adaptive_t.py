"""Sec-4 trade-off demo: the adaptive-T controller detects the local decay
order on the fly and sets T near the cost-optimal T*.

Quadratic local losses (linear decay)  -> small T* ~ log(1/r)
Quartic  local losses (sublinear decay)-> large T* ~ r^(-1/beta)

    PYTHONPATH=src python examples/adaptive_t.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.core.controller import AdaptiveT
from repro.core.reference import make_local_T
from repro.data.convex import make_overparam_regression


def demo(name, power, lr, r):
    prob = make_overparam_regression(n=20, d=400, m=2, power=power, seed=0)
    losses = prob.local_losses()
    w = jnp.ones(400) * 0.1
    ctl = AdaptiveT(r=r, ema=0.3)
    print(f"-- {name} local losses, cost ratio r={r} --")
    T = 50
    for rnd in range(6):
        runners = [make_local_T(f, lr, T) for f in losses]
        outs = [run(w) for run in runners]
        w = jnp.mean(jnp.stack([o[0] for o in outs]), axis=0)
        traj = np.asarray(outs[0][1])           # node-0 ||grad||^2 per step
        T_new = ctl.update(traj)
        fit = ctl.history[-1][0] if ctl.history else None
        print(f"  round {rnd}: detected {fit.kind if fit else '?':9s} "
              f"(beta={fit.beta:.3f})  ->  T={T_new}")
        T = T_new
    if fit.kind == "linear":
        print(f"  closed form T* = {theory.t_star_linear(fit.beta, r):.1f}")
    else:
        print(f"  closed form T* = "
              f"{theory.t_star_sublinear(fit.a, fit.beta, r):.1f}")


def main():
    demo("quadratic", power=1, lr=1.0, r=0.01)
    demo("quartic", power=2, lr=0.5, r=0.01)


if __name__ == "__main__":
    main()
