"""Shared helpers for the paper-figure benchmarks."""
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.reference import rounds_to, run_alg1  # noqa: F401,E402

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def save_result(name: str, payload: dict) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    p = OUT_DIR / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
