"""Shared helpers for the paper-figure benchmarks."""
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import obs  # noqa: E402
from repro.core.reference import rounds_to, run_alg1  # noqa: F401,E402

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def child_env(force_devices: int = 0) -> dict:
    """Environment for a benchmark/test child process: inherit everything
    (venv interpreters, PATH, XLA flags — PR 2 broke comm_reduction by
    rebuilding a bare env), PREPEND repo src to PYTHONPATH, and
    optionally force a host-platform device count (jax locks the count
    at first init, so multi-device runs need a fresh process)."""
    env = dict(os.environ)
    root = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(root / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if force_devices:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={force_devices} "
            + env.get("XLA_FLAGS", "")).strip()
    return env


def save_result(name: str, payload: dict) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    # stamp the telemetry schema so BENCH_*.json artifacts and --trace
    # files declare the same contract version (DESIGN.md §13)
    payload.setdefault("obs_schema", obs.SCHEMA_VERSION)
    p = OUT_DIR / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def bench_trace(name: str, meta: dict = None) -> obs.Trace:
    """A structured JSONL sink next to the bench artifact
    (experiments/bench/<name>.trace.jsonl), sharing the --trace schema."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    return obs.Trace(str(OUT_DIR / f"{name}.trace.jsonl"),
                     meta={"bench": name, **(meta or {})})


class Timer(obs.PhaseTimer):
    """Fenced wall-clock timer (DESIGN.md §13): ``t.fence(x)`` registers
    jax values the timed region produced, ``__exit__`` blocks until they
    are ready before reading the clock. Back-compat with the old naive
    timer — ``with Timer() as t: ...`` then ``t.seconds``."""
