"""Paper Fig 3 / Sec 3.2.1: necessity of the intersection assumption.

Two 1-layer nets on a 500-sample MNIST-shaped classification set:
  * Intersected:    affine 784 -> 10 (7850 params > 500 samples)
  * Non-intersected: two 2x2 max-pools -> affine 49 -> 10 (500 params)
Distributed (m=10 nodes, T=100) vs centralized (1 node). With the
intersection assumption the 10-node run matches centralized; without it
the distributed gradient residual stalls well above centralized."""
from benchmarks.common import run_alg1, save_result

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import gaussian_classification, maxpool2x2_twice


def make_losses(x, labels, m):
    """Split (x, labels) over m nodes; softmax-CE affine model on flat w."""
    n, d = x.shape
    k = 10
    idx = np.array_split(np.arange(n), m)

    def node_loss(xi, yi):
        xi = jnp.asarray(xi)
        yi = jnp.asarray(yi)

        def f(w):
            W = w[: d * k].reshape(d, k)
            b = w[d * k:]
            logits = xi @ W + b
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yi[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        return f

    return [node_loss(x[i], labels[i]) for i in idx], d * k + k


def main(rounds: int = 40) -> dict:
    x, labels = gaussian_classification(n=500, side=28, seed=0)
    x = x / np.abs(x).max()
    cases = {
        "intersected": x,                         # 7850 params > 500
        "non_intersected": maxpool2x2_twice(x),   # 500 params
    }
    res = {"figure": "3", "rounds": rounds, "cases": {}}
    for name, xc in cases.items():
        losses_m, dim = make_losses(xc, labels, m=10)
        losses_1, _ = make_losses(xc, labels, m=1)
        w0 = jnp.zeros(dim)
        lr = 0.5
        out_m = run_alg1(losses_m, w0, lr=lr, T=100, rounds=rounds)
        out_1 = run_alg1(losses_1, w0, lr=lr, T=100, rounds=rounds)
        res["cases"][name] = {
            "params": dim, "samples": 500,
            "gsq_10node": out_m["gsq"][-1], "gsq_1node": out_1["gsq"][-1],
            "f_10node": out_m["f"][-1], "f_1node": out_1["f"][-1],
            "gsq_curve_10node": out_m["gsq"][::4],
            "f_gap_vs_centralized": out_m["f"][-1] - out_1["f"][-1],
        }
    inter = res["cases"]["intersected"]
    noninter = res["cases"]["non_intersected"]
    # paper Fig 3(b)/(d): with the intersection assumption the 10-node
    # loss matches centralized; without it a persistent gap remains
    # (CE on non-separable pooled features also keeps grad residuals from
    # vanishing at the same rate as centralized — Fig 3(a)).
    res["pass"] = bool(
        inter["f_gap_vs_centralized"] < 1e-3
        and noninter["f_gap_vs_centralized"]
        > 100 * max(inter["f_gap_vs_centralized"], 1e-6)
        and inter["gsq_10node"] < 1e-4)
    save_result("fig3_intersection", res)
    return res


if __name__ == "__main__":
    r = main()
    print({k: (v if k != "cases" else {c: cc["f_gap_vs_centralized"]
                                       for c, cc in v.items()})
           for k, v in r.items()})
