"""Paper Fig 6/7 (appendix): effect of node count m at fixed T=100.

More nodes -> each local problem sees less data (stays intersected) but
the averaged step contracts more slowly: convergence rate decreases in m."""
from benchmarks.common import rounds_to, run_alg1, save_result

import jax.numpy as jnp
import numpy as np

from repro.data.convex import make_overparam_regression


def main(rounds: int = 40) -> dict:
    res = {"figure": "6/7", "by_m": {}}
    for m in (2, 5, 10):
        prob = make_overparam_regression(n=60, d=1200, m=m, seed=0)
        out = run_alg1(prob.local_losses(), jnp.zeros(1200), lr=2.0,
                       T=100, rounds=rounds)
        gsq = np.asarray(out["gsq"])
        res["by_m"][m] = {
            "final_gsq": float(gsq[-1]),
            "rounds_to_1e-9": rounds_to(gsq, 1e-9),
            # contraction factor per round (geometric mean over the run)
            "rate": float((gsq[-1] / gsq[0]) ** (1.0 / (len(gsq) - 1))),
        }
    rates = [res["by_m"][m]["rate"] for m in (2, 5, 10)]
    res["rate_increases_with_m"] = bool(rates[0] < rates[1] < rates[2])
    res["pass"] = res["rate_increases_with_m"]
    save_result("fig67_nodes", res)
    return res


if __name__ == "__main__":
    r = main()
    print({"by_m": {m: v["rate"] for m, v in r["by_m"].items()},
           "pass": r["pass"]})
