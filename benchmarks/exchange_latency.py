"""Exchange-phase benchmark: hop wire bytes + fused-epilogue latency
(ISSUE 5 / DESIGN.md §11).

The paper prices communication rounds as the scarce resource, so the
exchange phase is benchmarked in ISOLATION here — three sections:

  hop_bytes   EXACT per-hop wire bytes of the decentralized mixing step:
              the old all_gather hop moves O(G·shard) per device while
              the ppermute neighbor hop ships only the mixing row's
              nonzero entries — O(deg·shard), `topology.n_edge_sends`
              edge-true. Static math (no timing noise); the headline
              reduction for a G=16 ring is (G-1)/deg = 7.5x.
  epilogue    measured fused-vs-staged time of the replicated lossy
              exchange (`Exchange.streams` with the §11 fused codec-mix
              epilogue vs `fused=False`), per codec x topology. On this
              CPU container both run the jnp path under jit — XLA
              already fuses much of the staged chain, so the honest
              expectation is ~1x here; the fused win is the single
              Pallas pass on TPU (reported, not gated).
  sharded     (full runs; subprocess with 8 forced host devices) the
              ppermute-vs-allgather sharded exchange timing, sharded
              top-k convergence vs the replicated exact selection on the
              convex feasibility problem, and the fig2 Beck-Teboulle
              suite under sharded top-k (slope must match replicated).

Standalone: ``python benchmarks/exchange_latency.py`` writes
experiments/bench/exchange_latency.json. ``benchmarks/comm_bytes.py``
embeds ``run()``'s result in the committed BENCH_comm_bytes.json
(headline_exchange) so the `run.py --check` gate covers it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import child_env, save_result
from repro import comm as comm_mod
from repro.comm import topology as topo_mod

G = 4
HOP_BAR = 3.0          # ring G=16 edge-true reduction is exactly 7.5x


# ---------------------------------------------------------------------------
# hop bytes (static, exact)
# ---------------------------------------------------------------------------


def hop_bytes_section(n_elems: int = 1 << 20) -> dict:
    """Per-hop wire bytes per mixing hop (fp32 payloads of n_elems):
    all_gather = every device pulls the other G-1 group blocks;
    ppermute/edge-true = one payload per nonzero off-diagonal W entry."""
    out = {}
    for topo in ("ring", "gossip"):
        for g in (4, 16):
            w = topo_mod.mixing_matrix(topo, g, seed=0)
            payload = 4 * n_elems
            allgather = g * (g - 1) * payload
            edge_true = topo_mod.n_edge_sends(w) * payload
            offs = topo_mod.neighbor_offsets(w)
            out[f"{topo}/G{g}"] = {
                "allgather_hop_bytes": allgather,
                "ppermute_hop_bytes": edge_true,
                "n_offsets": len(offs),
                "reduction": allgather / edge_true,
            }
    return out


# ---------------------------------------------------------------------------
# fused epilogue timing (replicated path)
# ---------------------------------------------------------------------------


def _time_fn(fn, args, iters: int) -> float:
    out = fn(*args)                      # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def epilogue_section(n: int, iters: int) -> dict:
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (G, n))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    out = {}
    for topo, mr in (("server", 1), ("ring", 2)):
        for codec in ("int8", "bf16"):
            ex = comm_mod.get_exchange(topo, codec, G, mix_rounds=mr,
                                       impl="jnp")
            staged = dataclasses.replace(ex, fused=False)
            st = ex.init(x0)
            t_f = _time_fn(jax.jit(ex.params), (x, x0, st), iters)
            t_s = _time_fn(jax.jit(staged.params), (x, x0, st), iters)
            out[f"{topo}/{codec}"] = {
                "fused_ms": t_f * 1e3, "staged_ms": t_s * 1e3,
                "speedup": t_s / t_f,
            }
    return out


# ---------------------------------------------------------------------------
# sharded sections (run in a forced-8-device child process)
# ---------------------------------------------------------------------------


def _child_main(rounds: int, fig2_rounds: int) -> dict:
    """Everything that needs a multi-device mesh: ppermute-vs-allgather
    exchange timing, sharded top-k convergence, fig2 under sharded topk.
    Runs in a subprocess (jax locks the device count at first init)."""
    from jax.sharding import Mesh

    from repro import optim
    from repro.core import localsgd as lsgd
    from repro.optim import packing
    from repro.sharding import shardexec as shx

    out = {"n_devices": jax.device_count()}
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                ("data", "model"))
    sexec = shx.plan_for(mesh)
    sexec_ag = dataclasses.replace(sexec, hop_impl="allgather")

    # -- exchange timing: ring/int8, ppermute vs allgather hops ----------
    d = 1 << 16
    params = {"w": jnp.zeros((d,), jnp.float32)}
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (4, layout.padded))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    ex = comm_mod.get_exchange("ring", "int8", 4, mix_rounds=2,
                               impl="jnp")
    st = ex.init(x0)
    t_pp = _time_fn(jax.jit(sexec.exchange(ex, layout)), (x, x0, st), 20)
    t_ag = _time_fn(jax.jit(sexec_ag.exchange(ex, layout)), (x, x0, st),
                    20)
    out["hop_time"] = {"ppermute_ms": t_pp * 1e3,
                       "allgather_ms": t_ag * 1e3,
                       "note": "host-simulated mesh: collectives are "
                               "memcpy, wire cost is the hop_bytes "
                               "section's exact counts"}

    # -- sharded top-k convergence on the convex feasibility problem -----
    def quad_loss(p, batch):
        r = batch["A"] @ p["w"] - batch["b"]
        return 0.5 * jnp.sum(r ** 2)

    rng = np.random.RandomState(0)
    dim, rows = 64, 48
    A = rng.randn(4, rows, dim).astype(np.float32) / np.sqrt(dim)
    w_star = rng.randn(dim).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    p0 = {"w": jnp.asarray(rng.randn(dim).astype(np.float32))}
    layout = packing.shard_layout(packing.layout_of(p0), sexec.n_shards)
    ex_t = comm_mod.get_exchange("server", "topk", 4, topk_frac=0.05)
    cfg = lsgd.LocalSGDConfig(n_groups=4, inner_steps=4)
    opt = optim.packed("sgd", 0.4, impl="jnp")
    conv = {}
    for tag, sx in (("sharded", sexec), ("replicated", None)):
        rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                            layout=layout, exchange=ex_t,
                                            shardexec=sx))
        stt = lsgd.init_state(p0, opt, n_groups=4, layout=layout,
                              exchange=ex_t)
        m = None
        for _ in range(rounds):
            stt, m = rnd(stt, batch)
        conv[tag] = {"gsq_final": float(jnp.mean(m["grad_sq"])),
                     "rounds": rounds}
    out["topk_conv"] = conv

    # -- fig2 Beck-Teboulle under sharded top-k (2 nodes x 2 shards) -----
    mesh2 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                 ("data", "model"))
    sexec2 = shx.plan_for(mesh2)

    def bt_loss(p, batch):
        xx, yy = p["w"][0], p["w"][1]
        f1 = jnp.maximum(jnp.sqrt(xx ** 2 + (yy - 1.0) ** 2 + 1e-30)
                         - 1.0, 0.0) ** 2
        f2 = jnp.maximum(yy, 0.0) ** 2
        return jnp.where(batch["i"] == 0, f1, f2)

    fig2 = {}
    for tag, sx in (("sharded", sexec2), ("replicated", None)):
        p0 = {"w": jnp.array([1.5, 0.8], jnp.float32)}
        base = packing.layout_of(p0)
        layout2 = (packing.shard_layout(base, sexec2.n_shards)
                   if sx is not None else base)
        ex2 = comm_mod.get_exchange("server", "topk", 2, topk_frac=0.05)
        cfg2 = lsgd.LocalSGDConfig(n_groups=2, inner_steps=10)
        rnd2 = jax.jit(lsgd.make_local_round(bt_loss, opt, cfg2,
                                             layout=layout2, exchange=ex2,
                                             shardexec=sx))
        st2 = lsgd.init_state(p0, opt, n_groups=2, layout=layout2,
                              exchange=ex2)
        bt_batch = {"i": jnp.arange(2)}

        @jax.jit
        def global_gsq(wv):
            g = (jax.grad(lambda w: bt_loss({"w": w}, {"i": 0}))(wv)
                 + jax.grad(lambda w: bt_loss({"w": w}, {"i": 1}))(wv)) / 2.
            return jnp.sum(g ** 2)

        gsq = []
        for _ in range(fig2_rounds):
            st2, _m = rnd2(st2, bt_batch)
            wv = packing.unpack(st2["params"][0], layout2)["w"]
            gsq.append(float(global_gsq(wv)))
        nn = np.arange(1, fig2_rounds + 1)
        tail = slice(fig2_rounds // 10, None)
        slope = float(np.polyfit(np.log(nn[tail]),
                                 np.log(np.maximum(gsq, 1e-300))[tail],
                                 1)[0])
        fig2[tag] = {"loglog_slope": slope, "gsq_last": gsq[-1],
                     "rounds": fig2_rounds}
    out["fig2_topk"] = fig2
    return out


def run(smoke: bool = False) -> dict:
    """The exchange_latency payload `comm_bytes.py` embeds. Smoke runs
    keep the exact hop-byte math + a tiny epilogue timing and skip the
    8-device subprocess (CI's sharded job covers that path's tests)."""
    hop = hop_bytes_section()
    epi = epilogue_section(n=1 << 14 if smoke else 1 << 20,
                           iters=3 if smoke else 20)
    ring16 = hop["ring/G16"]
    payload = {
        "hop_bytes": hop,
        "epilogue_latency": epi,
        "epilogue_note": "CPU container, jnp path under jit both sides "
                         "(XLA fuses the staged chain too) — the fused "
                         "win is the single Pallas VMEM pass on TPU; "
                         "timing reported, hop BYTES are the gated "
                         "headline",
        "headline": {
            "ring_G16_allgather_hop_bytes": ring16["allgather_hop_bytes"],
            "ring_G16_ppermute_hop_bytes": ring16["ppermute_hop_bytes"],
            "ring_hop_bytes_reduction_G16": ring16["reduction"],
            "bar": HOP_BAR,
            "fused_epilogue_speedup_server_int8":
                epi["server/int8"]["speedup"],
        },
        "smoke": smoke,
    }
    ok = ring16["reduction"] >= HOP_BAR
    if not smoke:
        cmd = [sys.executable, os.path.abspath(__file__), "--child"]
        r = subprocess.run(cmd, env=child_env(8), capture_output=True,
                           text=True, timeout=1800, cwd=str(REPO_ROOT))
        if r.returncode != 0:
            payload["sharded"] = {"error": (r.stderr or "")[-2000:]}
            ok = False
        else:
            sharded = json.loads(r.stdout.strip().splitlines()[-1])
            payload["sharded"] = sharded
            conv = sharded["topk_conv"]
            f2 = sharded["fig2_topk"]
            payload["headline"].update({
                "sharded_topk_gsq": conv["sharded"]["gsq_final"],
                "replicated_topk_gsq": conv["replicated"]["gsq_final"],
                "sharded_topk_fig2_slope": f2["sharded"]["loglog_slope"],
                "replicated_topk_fig2_slope":
                    f2["replicated"]["loglog_slope"],
            })
            # the §11 convergence gate: sharded top-k converges like the
            # exact replicated selection, fig2 slope preserved
            ok = ok and conv["sharded"]["gsq_final"] < 1e-10 \
                and conv["sharded"]["gsq_final"] \
                <= 10 * conv["replicated"]["gsq_final"] + 1e-12 \
                and f2["sharded"]["loglog_slope"] < -2.5 \
                and abs(f2["sharded"]["loglog_slope"]
                        - f2["replicated"]["loglog_slope"]) < 0.5
    payload["pass"] = bool(ok)
    return payload


def main() -> dict:
    smoke = bool(int(os.environ.get("EXCHANGE_LATENCY_SMOKE", "0")))
    payload = run(smoke=smoke)
    save_result("exchange_latency_smoke" if smoke else "exchange_latency",
                payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        print(json.dumps(_child_main(rounds=120, fig2_rounds=800),
                         default=float))
        sys.exit(0)
    r = main()
    print(json.dumps(r["headline"], indent=1))
    sys.exit(0 if r["pass"] else 1)
