"""Paper Fig 2(b): mean-square regression on a colon-cancer-shaped
over-parameterized problem (n=62 samples, d=2000 features, m=2 nodes),
T_i in {1, 10, 100, inf}. All choices give LINEAR convergence and larger
T_i needs fewer communication rounds. T=inf is simulated by local GD until
||grad_i||^2 <= 1e-8 (the paper's threshold)."""
from benchmarks.common import rounds_to, run_alg1, save_result

import jax.numpy as jnp
import numpy as np

from repro.data.convex import make_overparam_regression


def main(rounds: int = 150, tol: float = 1e-7) -> dict:
    # tol sits ABOVE the T=inf local threshold (1e-8): once every node
    # solves to ||g_i||^2 <= 1e-8, the averaged global residual plateaus
    # near that threshold and cannot reach far below it.
    prob = make_overparam_regression(n=62, d=2000, m=2, seed=0)
    losses = prob.local_losses()
    w0 = jnp.zeros(2000)
    res = {"figure": "2b", "tol": tol, "curves": {}, "rounds_to_tol": {},
           "linear_rate_r2": {}}
    for label, T, thr in [("T=1", 1, None), ("T=10", 10, None),
                          ("T=100", 100, None), ("T=inf", None, 1e-8)]:
        out = run_alg1(losses, w0, lr=2.0, T=T, rounds=rounds,
                       threshold=thr, stop_below=tol * 1e-6)
        gsq = np.asarray(out["gsq"])
        res["curves"][label] = gsq.tolist()
        res["rounds_to_tol"][label] = rounds_to(gsq, tol)
        # linear convergence = straight line in semilog; fit the pre-
        # plateau segment (T=inf plateaus at its local threshold)
        above = np.nonzero(gsq <= tol)[0]
        k = int(above[0]) + 1 if above.size else len(gsq)
        k = max(k, 3)
        y = np.log(gsq[:k])
        x = np.arange(k)
        c = np.polyfit(x, y, 1)
        r2 = 1 - np.sum((y - np.polyval(c, x)) ** 2) / max(
            np.sum((y - y.mean()) ** 2), 1e-30)
        res["linear_rate_r2"][label] = float(r2)
    rt = res["rounds_to_tol"]
    # T=inf stops each local solve at ||g_i||^2 <= 1e-8, so its per-round
    # progress saturates at the threshold — the paper's Fig 2(b) likewise
    # shows the threshold curve coinciding with (not beating) T=100.
    res["monotone_in_T"] = bool(
        (rt["T=100"] or rounds) <= (rt["T=10"] or rounds)
        <= (rt["T=1"] or rounds)
        and (rt["T=inf"] or rounds) <= (rt["T=100"] or rounds) + 2)
    res["pass"] = bool(res["monotone_in_T"]
                       and all(v and v > 0.9 for v in
                               res["linear_rate_r2"].values()))
    save_result("fig2b_linear_rate", res)
    return res


if __name__ == "__main__":
    r = main()
    print({k: r[k] for k in ("rounds_to_tol", "linear_rate_r2", "pass")})
