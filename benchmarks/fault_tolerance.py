"""Fault-tolerance frontier: drop rate x topology x T (ISSUE 6 /
DESIGN.md §12).

The paper's convergence claims assume a reliable network; this benchmark
prices what packet loss does to each exchange topology with the
DETERMINISTIC FaultPlan masks (seeded, replayable — every cell is a pure
function of its config). Three sections:

  sweep   the convex feasibility problem (consistent least squares over
          G nodes, Sec 2.3 geometry) for every (topology x drop_rate x
          T) cell through the packed round engine: final mean
          ||grad_i||^2, delivered-fraction participation, and the
          exchange's own wire accounting (push_sum prices only
          DELIVERED edges; server/ring price attempts).
  bias    the mixing-only consensus experiment behind the §12 design
          choice: under 5% drop the masked doubly-stochastic hop
          (gossip) contracts the spread but DRIFTS the group mean —
          consensus on a provably wrong point — while push-sum ratio
          consensus under the SAME masks stays unbiased (mass is
          conserved, loss only delays it).
  sharded (subprocess with 8 forced host devices, the same pattern as
          tests/test_faults.py's REPRO_SHARDEXEC_CHILD driver) the
          push_sum-vs-lossless comparison re-run through the shard_map
          execution layer — the fault masks are generated outside the
          shard_map block, so the sharded cells replay the replicated
          schedule.

Headline (the acceptance bars, all bigger-is-better for run.py --check):

  push_sum_gsq_margin    10x tolerance-floored lossless gsq over the
                         push_sum-at-5%-drop gsq (>= 1.0 means push_sum
                         converges within 10x of lossless fp32), on the
                         replicated AND the sharded path.
  push_sum_unbias_factor gossip mixing bias / push_sum mixing bias under
                         the same 5% masks (>= 100).

Writes experiments/bench/fault_tolerance.json and the committed
perf-trajectory artifact BENCH_fault.json on full runs. FAULT_SMOKE=1
(or --smoke) runs the reduced CI lane — fewer rounds/cells but still
including the forced-8-device sharded child — with proportionally
relaxed convergence floors, writing only fault_tolerance_smoke.json.
Exit code reflects the pass flag.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import child_env, save_result
from repro import comm as comm_mod
from repro import optim
from repro.core import localsgd as lsgd
from repro.optim import packing

G = 4
D = 400
LR = 0.4
FAULT_SEED = 0       # training cells; the bias cell pins its own seed
BIAS_SEED = 2        # an early-loss schedule: the drift is unmistakable
GSQ_FLOOR = 1e-10            # converged-to-tolerance floor (full runs)
GSQ_FLOOR_SMOKE = 1e-4
UNBIAS_BAR = 100.0


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_feasibility(seed: int = 0, rows: int = 20):
    rng = np.random.RandomState(seed)
    A = rng.randn(G, rows, D).astype(np.float32) / np.sqrt(D)
    w_star = rng.randn(D).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(D).astype(np.float32))}
    return params, batch


def run_cell(params, batch, layout, topology: str, drop: float,
             t_inner: int, rounds: int, shardexec=None) -> dict:
    """One (topology x drop x T) training cell through the packed round
    (fp32 wire; the codec frontier is BENCH_comm_bytes.json's job)."""
    ex = comm_mod.get_exchange(topology, "fp32", G, drop_rate=drop,
                               fault_seed=FAULT_SEED)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    opt = optim.packed("sgd", LR, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex,
                                        shardexec=shardexec))
    state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                            exchange=ex)
    parts = []
    m = None
    for _ in range(rounds):
        state, m = rnd(state, batch)
        if "participation" in m:
            parts.append(float(m["participation"]))
    wire = int(m["wire_bytes"])
    # sharded layouts pad the buffer to the shard grid; the round prices
    # the actual (padded) payload it ships
    assert wire == ex.wire_bytes_per_round(layout.padded), (
        wire, ex.wire_bytes_per_round(layout.padded))
    return {
        "wire_bytes_per_round": wire,
        "delivery_rate": ex.delivery_rate,
        "participation_mean": float(np.mean(parts)) if parts else 1.0,
        "gsq_final": float(jnp.mean(m["grad_sq"])),
        "loss_final": float(jnp.mean(m["loss"])),
        "rounds": rounds,
    }


def bias_cell(drop: float, iters: int = 60) -> dict:
    """Mixing-only consensus under identical fault masks: iterate the
    exchange as a pure consensus map and measure where it lands."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (G, 20)) * 3.0
    mean0 = np.asarray(jnp.mean(x, axis=0))
    out = {}
    for topology in ("gossip", "push_sum"):
        ex = comm_mod.get_exchange(topology, "fp32", G, mix_rounds=1,
                                   drop_rate=drop, fault_seed=BIAS_SEED)
        st = ex.init(x)
        fn = jax.jit(ex.params)
        y = x
        for _ in range(iters):
            y, st = fn(y, None, st)
        o = np.asarray(y)
        out[topology] = {
            "mean_bias": float(np.abs(o.mean(axis=0) - mean0).max()),
            "consensus_spread": float(np.abs(o - o.mean(axis=0)).max()),
            "iters": iters, "drop_rate": drop, "seed": BIAS_SEED,
        }
    return out


def _margin(gsq_lossless: float, gsq_faulty: float, floor: float) -> float:
    """>= 1.0 iff the faulty cell's gsq is within 10x of the lossless
    one, both floored at the convergence tolerance (two runs at the
    numerical floor should PASS, not divide noise by noise)."""
    return 10.0 * max(gsq_lossless, floor) / max(gsq_faulty, floor)


# ---------------------------------------------------------------------------
# sharded child: the same comparison through the shard_map layer
# ---------------------------------------------------------------------------


def _child_main(rounds: int) -> dict:
    from jax.sharding import Mesh

    from repro.sharding import shardexec as shx

    out = {"n_devices": jax.device_count()}
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                ("data", "model"))
    sexec = shx.plan_for(mesh)
    params, batch = make_feasibility()
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    for tag, topology, drop in (("lossless", "server", 0.0),
                                ("push_sum_5pct", "push_sum", 0.05),
                                ("push_sum_10pct", "push_sum", 0.10)):
        out[tag] = run_cell(params, batch, layout, topology, drop,
                            t_inner=16, rounds=rounds, shardexec=sexec)
    return out


def main() -> dict:
    smoke = bool(int(os.environ.get("FAULT_SMOKE", "0"))) \
        or "--smoke" in sys.argv
    rounds = 15 if smoke else 120
    child_rounds = 15 if smoke else 120
    floor = GSQ_FLOOR_SMOKE if smoke else GSQ_FLOOR
    topologies = ["server", "gossip", "push_sum"] if smoke else \
        ["server", "ring", "gossip", "push_sum"]
    drops = [0.0, 0.05] if smoke else [0.0, 0.05, 0.10]
    t_values = [16] if smoke else [4, 16]

    params, batch = make_feasibility()
    layout = packing.layout_of(params)
    sweep = {}
    for topo in topologies:
        for drop in drops:
            for t in t_values:
                cell = run_cell(params, batch, layout, topo, drop, t,
                                rounds)
                sweep[f"{topo}/drop{drop:g}/T{t}"] = cell
                print(f"  {topo:9s} drop={drop:<5g} T={t:<3d} "
                      f"wire {cell['wire_bytes_per_round']:>6,}B/round "
                      f"part {cell['participation_mean']:.2f} "
                      f"gsq {cell['gsq_final']:.2e}", flush=True)

    t_head = t_values[-1]
    lossless = sweep[f"server/drop0/T{t_head}"]
    ps5 = sweep[f"push_sum/drop0.05/T{t_head}"]
    margin = _margin(lossless["gsq_final"], ps5["gsq_final"], floor)

    bias = bias_cell(0.05)
    unbias = (bias["gossip"]["mean_bias"]
              / max(bias["push_sum"]["mean_bias"], 1e-12))
    print(f"  bias@5%: gossip {bias['gossip']['mean_bias']:.3f} "
          f"(spread {bias['gossip']['consensus_spread']:.1e}) "
          f"push_sum {bias['push_sum']['mean_bias']:.2e} "
          f"-> unbias factor {unbias:.0f}x", flush=True)

    # -- forced-8-device shard_map path (same masks, same schedule) ------
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           str(child_rounds)]
    r = subprocess.run(cmd, env=child_env(8), capture_output=True,
                       text=True, timeout=1800, cwd=str(REPO_ROOT))
    if r.returncode != 0:
        sharded = {"error": (r.stderr or "")[-2000:]}
        sharded_margin = 0.0
    else:
        sharded = json.loads(r.stdout.strip().splitlines()[-1])
        sharded_margin = _margin(sharded["lossless"]["gsq_final"],
                                 sharded["push_sum_5pct"]["gsq_final"],
                                 floor)
        print(f"  sharded: lossless gsq "
              f"{sharded['lossless']['gsq_final']:.2e} push_sum@5% "
              f"{sharded['push_sum_5pct']['gsq_final']:.2e} "
              f"-> margin {sharded_margin:.1f}x", flush=True)

    payload = {
        "G": G, "dim": D, "lr": LR, "fault_seed": FAULT_SEED,
        "gsq_floor": floor,
        "problem": "consistent least squares over G nodes (Sec 2.3 "
                   "feasibility geometry), fp32 wire",
        "fault_model": "deterministic FaultPlan masks, pure in (round, "
                       "seed): Bernoulli per-edge drops (DESIGN.md §12)",
        "sweep": sweep,
        "bias": bias,
        "sharded": sharded,
        "headline": {
            "topology": "push_sum", "T": t_head, "drop_rate": 0.05,
            "push_sum_gsq_margin": margin, "bar": 1.0,
            "push_sum_unbias_factor": unbias, "unbias_bar": UNBIAS_BAR,
            "lossless_gsq": lossless["gsq_final"],
            "push_sum_gsq": ps5["gsq_final"],
            "gossip_bias_at_5pct": bias["gossip"]["mean_bias"],
        },
        "headline_sharded": {
            "push_sum_gsq_margin": sharded_margin, "bar": 1.0,
        },
        "pass": bool(margin >= 1.0 and sharded_margin >= 1.0
                     and unbias >= UNBIAS_BAR
                     and lossless["gsq_final"] < floor
                     and sweep[f"push_sum/drop0/T{t_head}"]["gsq_final"]
                     < floor),
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    save_result("fault_tolerance_smoke" if smoke else "fault_tolerance",
                payload)
    if not smoke:
        # the committed fault-tolerance artifact — full runs only
        (REPO_ROOT / "BENCH_fault.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        n = int(sys.argv[sys.argv.index("--child") + 1])
        print(json.dumps(_child_main(rounds=n), default=float))
        sys.exit(0)
    res = main()
    print(json.dumps(res["headline"], indent=1))
    sys.exit(0 if res["pass"] else 1)
