"""Overlapped-vs-barrier exchange benchmark + online-T wire accounting
(ISSUE 8 / DESIGN.md §14).

Three sections:

  throughput   (forced-8-device child) the overlapped round vs the
               barrier round at T ∈ {1, 4, 16} on the sharded 4x2 mesh,
               ring/int8. Three fenced measurements per T — the
               communication-free round (local), the barrier round, and
               the overlapped round — give the honest phase split:
               exch_s = barrier − local, overhead_s = overlap − barrier
               (the correction/encode arithmetic the overlap round
               adds). HEADLINE (gated): the MODELED overlapped round
               time max(local, exch) + overhead vs the barrier round —
               what a backend that schedules the round's leading
               collective concurrently with the local-step block pays.
               HONEST CPU CAVEAT: this container is a single serial
               host backend (forced host devices share one core;
               collectives are memcpy) — nothing truly runs
               concurrently, the MEASURED wall-clock ratio is reported
               alongside and sits at ~1x by construction. The modeled
               ratio is built only from honestly fenced components of
               real rounds, never from an assumed overlap.
  convergence  delayed mixing preserves the convex-suite gsq floor:
               barrier vs overlap at T=4 over the over-parameterized
               quadratic suite; the one-round lag costs a small
               constant, not the rate (gated).
  online_t     the --adaptive-t online controller vs the static Sec-4
               T*: both run the convex suite to the SAME gsq floor;
               wire bytes per round are constant, so rounds-to-floor IS
               total wire. The online controller's convergence relief
               lengthens rounds as consensus collapses — it must reach
               the floor with no more wire than static T* (gated; the
               ISSUE 8 acceptance bar).

Standalone: ``python benchmarks/overlap.py`` writes
experiments/bench/overlap.json and the committed BENCH_overlap.json;
``OVERLAP_SMOKE=1`` runs the reduced lane CI gates via run.py --check.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import child_env, save_result
from repro import comm as comm_mod
from repro import optim
from repro.core import controller, localsgd as lsgd, theory
from repro.optim import packing

G = 4
PROBE_K = 1                # dense probe rows: sizes compute vs exchange
HEADLINE_BAR = 1.15        # modeled overlap speedup at T=4 (run.py gate)
WIRE_BAR = 1.0             # static-T* wire / online wire must be >= 1


def make_probe_loss(k: int, d: int):
    """Dense-matvec probe: a (k, d) quadratic sized so the local-step
    block and the exchange are COMPARABLE — the regime overlap targets.
    (round_throughput's separable probe isolates the round engine, but
    its local step is ~100x cheaper than the ring exchange here; with
    nothing to hide, every overlap schedule models at ~1x. The T=1 row
    still reports the exchange-dominated regime honestly.) H rides the
    jit closure, not the batch, so it carries no group axis."""
    H = jnp.asarray(np.random.RandomState(0).randn(k, d)
                    .astype(np.float32) / np.sqrt(d))

    def probe_loss(params, batch):
        r = H @ params["w"].astype(jnp.float32) - batch["c"]
        return 0.5 * jnp.sum(r * r) * 1e-6

    return probe_loss


def _median_round_s(rnd, state, batch, reps: int) -> float:
    state, m = rnd(state, batch)             # compile + warm
    jax.block_until_ready(m)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        state, m = rnd(state, batch)
        jax.block_until_ready(m)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


# ---------------------------------------------------------------------------
# throughput (forced-8-device child)
# ---------------------------------------------------------------------------


def _child_main(d: int, t_values, reps: int, k: int = PROBE_K) -> dict:
    """Overlap-vs-barrier round timing on the sharded 4x2 mesh. Runs in
    a subprocess (jax locks the device count at first init)."""
    from jax.sharding import Mesh

    from repro.sharding import shardexec as shx

    out = {"n_devices": jax.device_count(), "d": d, "probe_k": k}
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                ("data", "model"))
    sexec = shx.plan_for(mesh)
    params = {"w": jnp.zeros((d,), jnp.float32)}
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    batch = {"c": jnp.linspace(0.0, 1.0, G)}
    probe_loss = make_probe_loss(k, d)
    opt = optim.packed("sgd", 0.05, impl="jnp")
    rows = {}
    for t_inner in t_values:
        cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
        cell = {}
        for tag, topo, ov in (("local", "none", False),
                              ("barrier", "ring", False),
                              ("overlap", "ring", True)):
            ex = comm_mod.get_exchange(topo, "fp32" if topo == "none"
                                       else "int8", G, overlap=ov,
                                       impl="jnp")
            rnd = jax.jit(lsgd.make_local_round(
                probe_loss, opt, cfg, layout=layout, exchange=ex,
                shardexec=sexec))
            st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                                 exchange=ex)
            cell[tag] = _median_round_s(rnd, st, batch, reps)
        local_s, barrier_s, overlap_s = (cell["local"], cell["barrier"],
                                         cell["overlap"])
        exch_s = max(0.0, barrier_s - local_s)
        overhead_s = max(0.0, overlap_s - barrier_s)
        modeled_s = max(local_s, exch_s) + overhead_s
        rows[f"T{t_inner}"] = {
            "local_round_s": local_s, "barrier_round_s": barrier_s,
            "overlap_round_s": overlap_s, "exchange_s": exch_s,
            "overhead_s": overhead_s,
            "modeled_overlap_round_s": modeled_s,
            "modeled_speedup": barrier_s / modeled_s if modeled_s > 0
            else 1.0,
            "measured_speedup": barrier_s / overlap_s if overlap_s > 0
            else 1.0,
        }
    out["by_t"] = rows
    return out


def _run_child(d: int, t_values, reps: int, k: int = PROBE_K) -> dict:
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           str(d), ",".join(map(str, t_values)), str(reps), str(k)]
    r = subprocess.run(cmd, env=child_env(8), capture_output=True,
                       text=True, timeout=1800, cwd=str(REPO_ROOT))
    if r.returncode != 0:
        raise SystemExit("overlap throughput child failed:\n"
                         + (r.stderr or "")[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# convergence: delayed mixing keeps the convex-suite floor
# ---------------------------------------------------------------------------


def _quad_problem(seed: int = 0, r: int = 8, d: int = 40):
    rng = np.random.RandomState(seed)
    A = rng.randn(G, r, d).astype(np.float32) / np.sqrt(d)
    w_star = rng.randn(d).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(d).astype(np.float32))}
    return params, batch


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def convergence_section(rounds: int) -> dict:
    params, batch = _quad_problem()
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    out = {"rounds": rounds}
    for tag, ov in (("barrier", False), ("overlap", True)):
        ex = comm_mod.get_exchange("ring", "int8", G, overlap=ov,
                                   impl="jnp")
        rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                            layout=layout, exchange=ex))
        st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                             exchange=ex)
        m = None
        for _ in range(rounds):
            st, m = rnd(st, batch)
        out[tag] = {
            "gsq_final": float(jnp.mean(m["grad_sq"])),
            "consensus_sq_post": float(jnp.mean(m["consensus_sq_post"])),
        }
    out["gsq_ratio_overlap_vs_barrier"] = (
        out["overlap"]["gsq_final"]
        / max(out["barrier"]["gsq_final"], 1e-30))
    return out


# ---------------------------------------------------------------------------
# online T vs static T*: rounds (== wire) to the same floor
# ---------------------------------------------------------------------------


def _run_to_floor(make_t, params, batch, layout, ex, floor: float,
                  max_rounds: int, *, on_round=None) -> dict:
    """Drive the packed round with a per-round T from ``make_t`` until
    the group-mean gsq reaches ``floor``. Jitted rounds are cached per
    distinct T, mirroring the launcher's rebuild-on-T-change."""
    opt = optim.packed("sgd", 0.3, impl="jnp")
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    cache, n, gsq, t_total = {}, 0, float("inf"), 0
    wire_round = ex.wire_bytes_per_round(layout.padded)
    while n < max_rounds and gsq > floor:
        t_cur = int(make_t())
        if t_cur not in cache:
            cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_cur,
                                      metrics="traj")
            cache[t_cur] = jax.jit(lsgd.make_local_round(
                quad_loss, opt, cfg, layout=layout, exchange=ex))
        st, m = cache[t_cur](st, batch)
        n += 1
        t_total += t_cur
        gsq = float(jnp.mean(m["grad_sq"]))
        if on_round is not None:
            on_round(m, t_cur)
    return {"rounds": n, "local_steps": t_total,
            "wire_bytes_total": wire_round * n, "gsq_final": gsq,
            "reached_floor": gsq <= floor,
            "distinct_t": sorted(cache)}


def online_t_section(floor: float, max_rounds: int,
                     r_cost: float = 1.0) -> dict:
    """Static Sec-4 T* vs the online controller, identical problem and
    exchange. Wire bytes per round are T-independent, so total wire is
    rounds x wire_per_round for both — the online controller must reach
    the floor with a wire total <= static's (ISSUE 8 acceptance)."""
    params, batch = _quad_problem()
    layout = packing.layout_of(params)
    ex = comm_mod.get_exchange("server", "fp32", G, impl="jnp")

    # -- static T*: fit the decay once on a probe round, then freeze ----
    opt = optim.packed("sgd", 0.3, impl="jnp")
    cfg0 = lsgd.LocalSGDConfig(n_groups=G, inner_steps=8, metrics="traj")
    rnd0 = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg0,
                                         layout=layout, exchange=ex))
    st0 = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                          exchange=ex)
    _, m0 = rnd0(st0, batch)
    fit = theory.fit_decay(np.asarray(m0["grad_sq_traj"])[0])
    t_static = max(1, int(round(theory.t_star_from_fit(fit, r_cost))))
    static = _run_to_floor(lambda: t_static, params, batch, layout, ex,
                           floor, max_rounds)

    # -- online: consensus guard + relief from the round's own metrics --
    ctl = controller.OnlineT(r=r_cost, t_min=1, t_max=256)
    state = {"t": t_static}

    def on_round(m, t_used):
        codec_err = sum(float(jnp.mean(v)) for k, v in m.items()
                        if k.startswith("codec_err/"))
        state["t"] = ctl.update(
            np.asarray(m["grad_sq_traj"])[0], t_used=t_used,
            # simulated fenced times consistent with r_cost: one local
            # step costs r_cost x the exchange (the controller only
            # consumes their ratio)
            local_s=r_cost * t_used, exchange_s=1.0,
            consensus_pre=float(jnp.mean(m["consensus_sq"])),
            consensus_post=float(jnp.mean(m["consensus_sq_post"])),
            codec_err=codec_err)

    online = _run_to_floor(lambda: state["t"], params, batch, layout, ex,
                           floor, max_rounds, on_round=on_round)
    wire_ratio = (static["wire_bytes_total"]
                  / max(online["wire_bytes_total"], 1))
    return {"floor": floor, "t_static": t_static,
            "static": static, "online": online,
            "controller_tail": ctl.history[-3:] if ctl.history else [],
            "wire_ratio_static_over_online": wire_ratio}


# ---------------------------------------------------------------------------


def run(smoke: bool = False) -> dict:
    # d in the multi-million range is what makes the exchange fence REAL
    # on this host: the ring's int8 encode + ppermute hops then cost
    # actual memory bandwidth (~1-2s/round) instead of free L2 memcpys,
    # so exch_s = barrier - local measures wire work, not noise.
    t_values = (4,) if smoke else (1, 4, 16)
    d = 1 << 21 if smoke else 1 << 22
    reps = 3 if smoke else 7
    thr = _run_child(d, t_values, reps, PROBE_K)
    conv = convergence_section(rounds=60 if smoke else 200)
    onl = online_t_section(floor=5e-3 if smoke else 1e-3,
                           max_rounds=200 if smoke else 600)
    t4 = thr["by_t"]["T4"]
    bar = 1.05 if smoke else HEADLINE_BAR
    payload = {
        "G": G,
        "throughput": thr,
        "convergence": conv,
        "online_t": onl,
        "headline": {
            "topology": "ring", "codec": "int8", "T": 4, "d": thr["d"],
            "modeled_speedup_T4": t4["modeled_speedup"],
            "measured_speedup_T4": t4["measured_speedup"],
            "bar": bar,
            "note": "MODELED from fenced components (barrier / "
                    "(max(local, exch) + overhead)); all three fences "
                    "run the same sharded round engine so exch_s is "
                    "the exchange's marginal cost. This container is a "
                    "serial single-core host backend — nothing truly "
                    "runs concurrently, so the measured wall-clock "
                    "ratio rides alongside at ~1x; see module "
                    "docstring",
        },
        "headline_online_t": {
            "wire_ratio_static_over_online":
                onl["wire_ratio_static_over_online"],
            "bar": WIRE_BAR,
            "static_wire_bytes": onl["static"]["wire_bytes_total"],
            "online_wire_bytes": onl["online"]["wire_bytes_total"],
        },
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    ok = (t4["modeled_speedup"] >= bar
          and conv["overlap"]["gsq_final"]
          <= 10 * conv["barrier"]["gsq_final"] + 1e-9
          and conv["overlap"]["gsq_final"] < (1e-2 if smoke else 2e-3)
          and onl["online"]["reached_floor"]
          and onl["wire_ratio_static_over_online"] >= WIRE_BAR)
    payload["pass"] = bool(ok)
    return payload


def main() -> dict:
    smoke = bool(int(os.environ.get("OVERLAP_SMOKE", "0")))
    payload = run(smoke=smoke)
    save_result("overlap_smoke" if smoke else "overlap", payload)
    if not smoke:
        # the committed perf-trajectory artifact — full runs only, so CI
        # smoke runs never clobber it with reduced data
        (REPO_ROOT / "BENCH_overlap.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        d_ = int(sys.argv[2])
        ts_ = tuple(int(x) for x in sys.argv[3].split(","))
        reps_ = int(sys.argv[4])
        k_ = int(sys.argv[5]) if len(sys.argv) > 5 else PROBE_K
        print(json.dumps(_child_main(d_, ts_, reps_, k_), default=float))
        sys.exit(0)
    r = main()
    print(json.dumps({"headline": r["headline"],
                      "headline_online_t": r["headline_online_t"]},
                     indent=1))
    sys.exit(0 if r["pass"] else 1)
