"""Paper Fig 5 / Sec 4: quadratic vs quartic loss and the T* trade-off.

Quadratic local losses decay linearly -> small T* ~ log(1/r); quartic
losses decay sub-linearly -> large T* ~ r^(-1/beta). We (1) reproduce the
figure's observation (T=100 nearly matches threshold for quadratic, but
quartic still gains from much larger T), and (2) validate the Sec-4
formulas against brute-force cost minimization, including the on-the-fly
decay detection used by the adaptive controller."""
from benchmarks.common import rounds_to, run_alg1, save_result

import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.data.convex import make_overparam_regression


def main() -> dict:
    res = {"figure": "5", "cases": {}}
    for name, power, lr in [("quadratic", 1, 1.0), ("quartic", 2, 0.5)]:
        prob = make_overparam_regression(n=20, d=400, m=2, power=power,
                                         seed=0, scale=1.0)
        losses = prob.local_losses()
        w0 = jnp.ones(400) * 0.3
        curves, r2t = {}, {}
        for label, T, thr in [("T=10", 10, None), ("T=100", 100, None),
                              ("T=1000", 1000, None),
                              ("threshold", None, 1e-8)]:
            out = run_alg1(losses, w0, lr=lr, T=T, rounds=12, threshold=thr,
                           record_local_traj=(label == "T=1000"))
            curves[label] = out["gsq"]
            r2t[label] = rounds_to(out["gsq"], 1e-6)
            if label == "T=1000":
                traj = np.asarray(out["local_traj"][:1000])
        # trim the fp32 noise floor before decay-order detection
        traj = traj[traj > traj[0] * 1e-10][:200]
        fit = theory.fit_decay(traj)
        res["cases"][name] = {
            "rounds_to_1e-6": r2t,
            "final": {k: v[-1] for k, v in curves.items()},
            "detected_decay": None if fit is None else
            {"kind": fit.kind, "beta": fit.beta, "a": fit.a},
        }

    # T* formula vs brute force for both regimes: the formula's T must
    # achieve near-optimal cost under the discrete objective
    r = 0.01
    h_lin = lambda t: 0.9 ** t
    h_sub = lambda t: (1 + 2.0 * t) ** -1.5
    t_lin = theory.t_star_linear(0.9, r)
    t_sub = theory.t_star_sublinear(2.0, 1.5, r)
    tstar = {
        "linear_formula": t_lin,
        "linear_bruteforce": theory.t_star_numeric(r, h_lin),
        "sublinear_formula": t_sub,
        "sublinear_bruteforce": theory.t_star_numeric(r, h_sub),
        "linear_cost_ratio": theory.cost_bound(
            max(int(round(t_lin)), 1), r, h_lin) / theory.cost_bound(
            theory.t_star_numeric(r, h_lin), r, h_lin),
        "sublinear_cost_ratio": theory.cost_bound(
            max(int(round(t_sub)), 1), r, h_sub) / theory.cost_bound(
            theory.t_star_numeric(r, h_sub), r, h_sub),
    }
    res["t_star"] = tstar
    quad = res["cases"]["quadratic"]
    quar = res["cases"]["quartic"]
    res["pass"] = bool(
        quad["detected_decay"]["kind"] == "linear"
        and quar["detected_decay"]["kind"] == "sublinear"
        # quartic keeps gaining from T=100 -> T=1000; quadratic does not
        and quar["final"]["T=1000"] < 0.5 * quar["final"]["T=100"]
        and tstar["linear_cost_ratio"] <= 1.1
        and tstar["sublinear_cost_ratio"] <= 1.15)
    save_result("fig5_quartic", res)
    return res


if __name__ == "__main__":
    r = main()
    print({"t_star": r["t_star"], "pass": r["pass"]})
