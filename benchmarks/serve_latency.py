"""Continuous-vs-static batching serve benchmark (ISSUE 9 tentpole).

Poisson arrivals at a calibrated request rate drive the SAME serve
engine (repro.serve.Engine: one fixed-shape jit step program, paged
flat-buffer KV/state pool) under its two admission policies:

  static      classic static batching — a batch is admitted only when
              every slot is idle, so the whole batch drains before the
              next one starts. Late arrivals queue behind the drain.
  continuous  requests are admitted into any freed slot every scheduler
              tick; retirement frees pages without recompilation.

The workload rate is CALIBRATED from a fenced probe of this machine's
own decode-step time (target utilization ~0.85 of the continuous
engine's slot capacity), so the queueing pressure — the regime where
continuous batching matters — is the same on any host speed.

All request latencies come from the discrete-event virtual clock in
``serve.drive_workload``: the clock advances by each step's MEASURED
phase-fenced duration (prefill / decode_step block_until_ready), and
latency = completion clock - arrival. Both policies run identical
compiled programs over the identical request list, so the headline
ratios are pure scheduling, not implementation difference; greedy
decode also makes the per-request token sequences of the two policies
byte-identical, which is asserted as part of the gate.

HEADLINE (run.py --check gated): committed tokens/s ratio
continuous/static, and p99 latency ratio static/continuous.

Standalone: ``python benchmarks/serve_latency.py`` writes
experiments/bench/serve.json and the committed BENCH_serve.json;
``SERVE_SMOKE=1`` runs the reduced lane CI gates via run.py --check.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import numpy as np

from benchmarks.common import save_result
from repro.configs.base import get_config
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, Request, drive_workload,
                         poisson_workload)

ARCH = "qwen3-32b"         # dense GQA: exercises paged-KV prefill+decode
UTILIZATION = 0.85         # target fraction of continuous slot capacity
TOKS_BAR = 1.10            # committed tok/s continuous/static (gate)
P99_BAR = 1.30             # p99 latency static/continuous (gate)
SMOKE_TOKS_BAR = 1.0       # smoke: continuous must not be WORSE
SMOKE_P99_BAR = 1.0


def _fresh(reqs):
    return [Request(r.rid, r.prompt.copy(), r.max_new, r.arrival)
            for r in reqs]


def _calibrate_step_s(engine: Engine, vocab: int) -> float:
    """Median fenced decode-step time with every slot occupied — the
    service-time unit the arrival rate is expressed in."""
    rng = np.random.default_rng(7)
    reqs = [Request(rid=-100 - i,
                    prompt=rng.integers(0, vocab, size=4).astype(np.int32),
                    max_new=8)
            for i in range(engine.cfg.n_slots)]
    for r in reqs:
        engine.submit(r)
    full_steps = []
    while engine.queue or engine.n_active():
        rep = engine.step()
        if rep.admitted == 0 and engine.n_active() == engine.cfg.n_slots:
            full_steps.append(rep.decode_s)
        if rep.admitted == 0 and not full_steps and rep.decode_s > 0:
            full_steps.append(rep.decode_s)   # tail: partial occupancy
    return float(np.median(full_steps))


def _run_policy(model, params, policy: str, reqs, *, n_slots: int,
                page_size: int, max_prompt: int, max_new: int) -> dict:
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, page_size=page_size, max_prompt=max_prompt,
        max_new=max_new, policy=policy))
    eng.warmup()
    done, makespan = drive_workload(eng, _fresh(reqs))
    lat = np.asarray(sorted(c.latency for c in done))
    committed = int(sum(len(c.tokens) for c in done))
    return {
        "policy": policy,
        "n_requests": len(done),
        "committed_tokens": committed,
        "makespan_s": float(makespan),
        "tokens_per_s": committed / max(makespan, 1e-9),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "latency_mean_s": float(lat.mean()),
        "tokens": {int(c.rid): list(c.tokens) for c in done},
    }


def run(smoke: bool = False) -> dict:
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_slots = 3 if smoke else 4
    page_size = 4 if smoke else 8
    prompt_rng = (2, 10) if smoke else (4, 16)
    gen_rng = (3, 8) if smoke else (4, 16)
    n_req = 10 if smoke else 28
    max_prompt, max_new = prompt_rng[1], gen_rng[1]

    # rate calibration: requests/sec such that offered slot-seconds are
    # UTILIZATION of the continuous engine's capacity
    cal = Engine(model, params, EngineConfig(
        n_slots=n_slots, page_size=page_size, max_prompt=max_prompt,
        max_new=max_new))
    cal.warmup()
    step_s = _calibrate_step_s(cal, cfg.vocab_size)
    mean_tokens = 0.5 * (gen_rng[0] + gen_rng[1])
    rate = UTILIZATION * n_slots / (mean_tokens * step_s)

    reqs = poisson_workload(rate, n_req, seed=3, prompt_len=prompt_rng,
                            max_new=gen_rng, vocab=cfg.vocab_size)
    kw = dict(n_slots=n_slots, page_size=page_size,
              max_prompt=max_prompt, max_new=max_new)
    stat = _run_policy(model, params, "static", reqs, **kw)
    cont = _run_policy(model, params, "continuous", reqs, **kw)

    # greedy decode + per-slot isolation => identical tokens regardless
    # of scheduling; a mismatch means the engine leaked state
    parity = stat["tokens"] == cont["tokens"]

    toks_ratio = cont["tokens_per_s"] / stat["tokens_per_s"]
    p99_ratio = stat["latency_p99_s"] / max(cont["latency_p99_s"], 1e-12)
    toks_bar = SMOKE_TOKS_BAR if smoke else TOKS_BAR
    p99_bar = SMOKE_P99_BAR if smoke else P99_BAR

    payload = {
        "bench": "serve_latency",
        "arch": cfg.name,
        "workload": {
            "n_requests": n_req, "rate_req_per_s": rate,
            "calibrated_step_s": step_s, "utilization_target": UTILIZATION,
            "prompt_len": list(prompt_rng), "max_new": list(gen_rng),
            "n_slots": n_slots, "page_size": page_size,
        },
        "static": {k: v for k, v in stat.items() if k != "tokens"},
        "continuous": {k: v for k, v in cont.items() if k != "tokens"},
        "token_parity_static_vs_continuous": bool(parity),
        "headline": {
            "tokens_per_s_ratio": float(toks_ratio),
            "bar": float(toks_bar),
            "p99_ratio_static_over_continuous": float(p99_ratio),
            "p99_bar": float(p99_bar),
            "note": "virtual-clock discrete-event drive over fenced "
                    "prefill/decode_step durations; identical compiled "
                    "programs + identical Poisson request list for both "
                    "policies, so the ratios are pure scheduling. Rate "
                    "calibrated to ~0.85 slot utilization from this "
                    "host's own measured step time.",
        },
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    payload["pass"] = bool(parity and toks_ratio >= toks_bar
                           and p99_ratio >= p99_bar)
    return payload


def main() -> dict:
    smoke = bool(int(os.environ.get("SERVE_SMOKE", "0")))
    payload = run(smoke=smoke)
    save_result("serve_smoke" if smoke else "serve", payload)
    if not smoke:
        # the committed perf-trajectory artifact — full runs only, so CI
        # smoke runs never clobber it with reduced data
        (REPO_ROOT / "BENCH_serve.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    r = main()
    print(json.dumps({"workload": r["workload"], "headline": r["headline"],
                      "parity": r["token_parity_static_vs_continuous"],
                      "pass": r["pass"]}, indent=1))
    sys.exit(0 if r["pass"] else 1)
