"""Wire-byte frontier of the comm subsystem: T x codec x topology sweep.

The paper's claim is rounds-vs-bytes (arXiv:2102.01583 frames exactly this
resource); this benchmark prices it EXACTLY with the comm subsystem's
wire accounting (repro.comm, DESIGN.md §8) instead of post-hoc HLO
analysis. Two experiments, both through the packed round engine:

  sweep   convex feasibility (consistent least squares over G nodes,
          paper Sec 2.3 geometry) run to convergence for every
          (topology x codec x T) cell: exact payload bytes per round,
          cumulative bytes, and the final mean ||grad_i||^2 — showing
          the frontier (e.g. int8 cuts bytes ~3.9x at equal T with
          convergence preserved; delta coding makes quantization noise
          vanish as rounds converge).
  fig2    the paper's Fig-2(a) Beck-Teboulle feasibility re-run with the
          fp32 and int8 wire: the log-log slope of ||grad f(x_n)||^2 and
          the final residual must survive quantized communication.
  moments the multi-stream frontier (DESIGN.md §10): momentum/adamw x
          moment codec at T=16 with params pinned to int8 — for adamw
          the wire is DOMINATED by the two fp32 moment buffers, so the
          moment codec is the biggest remaining lever. Convergence bars:
          momentum must converge absolutely; adamw reaches its
          optimizer floor (~lr^2) and every lossy moment codec must
          match the moments-fp32 row within 2x.
  exchange_latency (embedded from benchmarks/exchange_latency.py,
          DESIGN.md §11): exact ppermute-vs-all_gather hop bytes, the
          fused-vs-staged epilogue timing, and — full runs — sharded
          top-k convergence + the fig2 suite under sharded top-k.

Headline (the acceptance bar): server topology, T=16 — int8 wire bytes
>= 3.5x under fp32 AND int8 converges to the same tolerance; fig2 keeps
slope < -0.5 and gsq_last < 1e-6 under int8; adamw params-int8 +
moments-int8 cuts >= 2.5x total wire vs params-int8/moments-fp32 with
convergence preserved; ring G=16 hop bytes cut >= 3x by the ppermute
neighbor exchange (exactly 7.5x) with sharded top-k matching replicated
top-k convergence and the fig2 slope (headline_exchange).

Writes experiments/bench/comm_bytes.json and the committed
perf-trajectory artifact BENCH_comm_bytes.json on full runs.
COMM_BYTES_SMOKE=1 runs a reduced sweep for CI with proportionally
relaxed convergence bars (so CI fails on real regressions, not just
crashes) and writes only comm_bytes_smoke.json — it never clobbers the
full-run artifacts. Exit code reflects the pass flag.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro import comm as comm_mod
from repro import optim
from repro.core import localsgd as lsgd
from repro.optim import packing

G = 4
D = 400          # model dim: int8 @ chunk=256 -> 4N/(N + 4*ceil(N/256))
LR = 0.4
GSQ_TOL = 1e-10  # converged: mean per-group ||grad_i||^2 at the result
# smoke runs use far fewer rounds, so the convergence bars scale with
# them — the CI step then FAILS (nonzero exit) on a real regression
# instead of only guarding against crashes
GSQ_TOL_SMOKE = 1e-5
FIG2_TOL, FIG2_TOL_SMOKE = 1e-6, 1e-4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_feasibility(seed: int = 0, rows: int = 20):
    """Consistent least squares split over G nodes: every node's system
    is satisfiable at w*, so the intersection is non-empty and Alg 1
    converges (paper Sec 2.3 geometry)."""
    rng = np.random.RandomState(seed)
    A = rng.randn(G, rows, D).astype(np.float32) / np.sqrt(D)
    w_star = rng.randn(D).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(D).astype(np.float32))}
    return params, batch


def run_cell(params, batch, layout, topology: str, codec: str, t_inner: int,
             rounds: int, gsq_tol: float = GSQ_TOL) -> dict:
    ex = comm_mod.get_exchange(topology, codec, G, staleness=1)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    opt = optim.packed("sgd", LR, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                            exchange=ex)
    m = None
    for _ in range(rounds):
        state, m = rnd(state, batch)
    wire = int(m["wire_bytes"])
    # the metric must agree with the exchange's static accounting
    assert wire == ex.wire_bytes_per_round(layout.size), (
        wire, ex.wire_bytes_per_round(layout.size))
    gsq = float(jnp.mean(m["grad_sq"]))
    return {
        "wire_bytes_per_round": wire,
        "cumulative_wire_mb": wire * rounds / 1e6,
        "gsq_final": gsq,
        "loss_final": float(jnp.mean(m["loss"])),
        "converged": bool(gsq < gsq_tol),
        "rounds": rounds,
    }


# ---------------------------------------------------------------------------
# Multi-stream sweep: momentum/adamw x moment codec (DESIGN.md §10)
# ---------------------------------------------------------------------------

# convergence: momentum reaches the feasibility point absolutely (like
# sgd); adamw's constant-lr steady state oscillates at ~lr^2, so its bar
# is the optimizer floor PLUS staying within 2x of its moments-fp32 row
MOMENT_OPTS = {"momentum": {"lr": 0.04, "rounds": 120, "tol": 1e-10},
               "adamw": {"lr": 0.02, "rounds": 400, "tol": 1e-2}}
MOMENT_OPTS_SMOKE = {"momentum": {"lr": 0.04, "rounds": 15, "tol": 1e-1},
                     "adamw": {"lr": 0.02, "rounds": 15, "tol": 1e0}}


def run_moment_cell(params, batch, layout, opt_name: str,
                    moment_codec: str, t_inner: int, lr: float,
                    rounds: int, tol: float) -> dict:
    """One cell of the moments frontier: params pinned to int8 (the §8
    result), moments through ``moment_codec`` — per-stream wire bytes
    from the round metrics, checked against the static accounting."""
    ex = comm_mod.get_exchange("server", "int8", G,
                               moment_codec=moment_codec)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    opt = optim.packed(opt_name, lr, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                            exchange=ex)
    m = None
    for _ in range(rounds):
        state, m = rnd(state, batch)
    moment_sizes = {k: layout.padded for k in opt.moment_keys}
    by_stream = ex.wire_bytes_by_stream(layout.padded, moment_sizes)
    wire = int(m["wire_bytes"])
    assert wire == sum(by_stream.values()), (wire, by_stream)
    for k, v in by_stream.items():
        assert int(m[f"wire_bytes/{k}"]) == v, (k, v)
    gsq = float(jnp.mean(m["grad_sq"]))
    return {
        "wire_bytes_per_round": wire,
        "wire_bytes_by_stream": by_stream,
        "moment_bytes_per_round": wire - by_stream["params"],
        "gsq_final": gsq,
        "loss_final": float(jnp.mean(m["loss"])),
        "converged": bool(gsq < tol),
        "rounds": rounds, "lr": lr,
    }


# ---------------------------------------------------------------------------
# Fig-2(a)-style check: Beck-Teboulle feasibility through the quantized wire
# ---------------------------------------------------------------------------


def bt_loss(params, batch):
    """The two Beck-Teboulle losses as ONE batch-indexed loss so the
    standard G-axis round runs them (group i gets batch["i"] == i)."""
    x, y = params["w"][0], params["w"][1]
    f1 = jnp.maximum(jnp.sqrt(x ** 2 + (y - 1.0) ** 2 + 1e-30) - 1.0,
                     0.0) ** 2
    f2 = jnp.maximum(y, 0.0) ** 2
    return jnp.where(batch["i"] == 0, f1, f2)


def run_fig2(codec: str, rounds: int, tol: float = FIG2_TOL) -> dict:
    m_nodes, T = 2, 10
    params = {"w": jnp.array([1.5, 0.8], jnp.float32)}
    layout = packing.layout_of(params)
    batch = {"i": jnp.arange(m_nodes)}
    ex = comm_mod.get_exchange("server", codec, m_nodes, chunk=256)
    cfg = lsgd.LocalSGDConfig(n_groups=m_nodes, inner_steps=T)
    opt = optim.packed("sgd", 0.4, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(bt_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    state = lsgd.init_state(params, opt, n_groups=m_nodes, layout=layout,
                            exchange=ex)

    @jax.jit
    def global_gsq(w):   # ||grad of the AVERAGE objective||^2, as fig2a
        g = (jax.grad(lambda w: bt_loss({"w": w}, {"i": 0}))(w)
             + jax.grad(lambda w: bt_loss({"w": w}, {"i": 1}))(w)) / 2.0
        return jnp.sum(g ** 2)

    gsq, wire = [], 0
    for _ in range(rounds):
        state, m = rnd(state, batch)
        wire += int(m["wire_bytes"])
        gsq.append(float(global_gsq(state["params"][0])))
    n = np.arange(1, rounds + 1)
    tail = slice(rounds // 10, None)
    slope = float(np.polyfit(np.log(n[tail]),
                             np.log(np.maximum(gsq, 1e-300))[tail], 1)[0])
    return {"codec": codec, "rounds": rounds, "T": T,
            "wire_bytes_total": wire,
            "gsq_first": gsq[0], "gsq_last": gsq[-1],
            "loglog_slope": slope,
            "pass": bool(slope < -0.5 and gsq[-1] < tol)}


def main() -> dict:
    smoke = bool(int(os.environ.get("COMM_BYTES_SMOKE", "0")))
    rounds = 15 if smoke else 120
    fig2_rounds = 150 if smoke else 2000
    gsq_tol = GSQ_TOL_SMOKE if smoke else GSQ_TOL
    fig2_tol = FIG2_TOL_SMOKE if smoke else FIG2_TOL
    topologies = ["server", "ring"] if smoke else \
        ["server", "ring", "gossip", "async_stale", "none"]
    codecs = ["fp32", "int8"] if smoke else \
        ["fp32", "fp16", "bf16", "int8", "topk"]
    t_values = [16] if smoke else [4, 16]

    params, batch = make_feasibility()
    layout = packing.layout_of(params)
    sweep = {}
    for topo in topologies:
        for codec in codecs:
            if topo == "async_stale" and codec == "topk":
                continue   # refused: staleness drops rounds, EF assumes
                           # delivery (DESIGN.md §8)
            if topo == "none" and codec != "fp32":
                continue   # no wire -> codecs are skipped entirely; one
                           # baseline row is enough
            for t in t_values:
                cell = run_cell(params, batch, layout, topo, codec, t,
                                rounds, gsq_tol=gsq_tol)
                sweep[f"{topo}/{codec}/T{t}"] = cell
                print(f"  {topo:11s} {codec:5s} T={t:<3d} "
                      f"wire {cell['wire_bytes_per_round']:>6,}B/round "
                      f"gsq {cell['gsq_final']:.2e} "
                      f"{'ok' if cell['converged'] else '--'}", flush=True)

    t_head = t_values[-1]
    fp32 = sweep[f"server/fp32/T{t_head}"]
    i8 = sweep[f"server/int8/T{t_head}"]
    reduction = fp32["wire_bytes_per_round"] / i8["wire_bytes_per_round"]
    fig2 = {c: run_fig2(c, fig2_rounds, tol=fig2_tol)
            for c in ("fp32", "int8")}
    for c, r in fig2.items():
        print(f"  fig2 {c}: slope {r['loglog_slope']:.2f} "
              f"gsq_last {r['gsq_last']:.2e} "
              f"{'ok' if r['pass'] else '--'}", flush=True)

    # ---- multi-stream frontier: moment codecs (DESIGN.md §10) ----------
    mopts = MOMENT_OPTS_SMOKE if smoke else MOMENT_OPTS
    mcodecs = ["fp32", "int8"] if smoke else ["fp32", "bf16", "int8"]
    moments = {}
    for opt_name, hp in mopts.items():
        for mc in mcodecs:
            cell = run_moment_cell(params, batch, layout, opt_name, mc,
                                   t_head, hp["lr"], hp["rounds"],
                                   hp["tol"])
            moments[f"server/{opt_name}/params-int8/moments-{mc}"] = cell
            print(f"  {opt_name:9s} moments={mc:5s} T={t_head:<3d} "
                  f"wire {cell['wire_bytes_per_round']:>6,}B/round "
                  f"(moments {cell['moment_bytes_per_round']:>6,}B) "
                  f"gsq {cell['gsq_final']:.2e} "
                  f"{'ok' if cell['converged'] else '--'}", flush=True)
    # ---- exchange engine: hop bytes + fused epilogue (DESIGN.md §11) ---
    from benchmarks import exchange_latency
    exch = exchange_latency.run(smoke=smoke)
    print(f"  exchange: ring G=16 hop bytes "
          f"{exch['headline']['ring_hop_bytes_reduction_G16']:.1f}x "
          f"under all_gather (bar {exch['headline']['bar']}); fused "
          f"epilogue server/int8 "
          f"{exch['headline']['fused_epilogue_speedup_server_int8']:.2f}x"
          f" {'ok' if exch['pass'] else '--'}", flush=True)

    a_fp32 = moments["server/adamw/params-int8/moments-fp32"]
    a_i8 = moments["server/adamw/params-int8/moments-int8"]
    moment_reduction = (a_fp32["wire_bytes_per_round"]
                        / a_i8["wire_bytes_per_round"])
    # EVERY swept moment cell must converge (momentum absolutely, adamw
    # to its optimizer floor), and every lossy adamw row — bf16 included
    # — must match the moments-fp32 floor within 2x
    moments_ok = bool(
        all(c["converged"] for c in moments.values())
        and all(moments[f"server/adamw/params-int8/moments-{mc}"]
                ["gsq_final"] <= 2.0 * max(a_fp32["gsq_final"], 1e-12)
                for mc in mcodecs if mc != "fp32"))

    payload = {
        "G": G, "dim": D, "lr": LR, "gsq_tol": gsq_tol,
        "problem": "consistent least squares over G nodes (Sec 2.3 "
                   "feasibility geometry); fig2 = Beck-Teboulle, T=10",
        "accounting": "exact per-stream payload bytes, up+down totals "
                      "(Exchange.wire_bytes_by_stream, DESIGN.md §8/§10)",
        "sweep": sweep,
        "fig2": fig2,
        "moments": moments,
        "headline": {
            "topology": "server", "T": t_head,
            "int8_reduction_vs_fp32": reduction, "bar": 3.5,
            "fp32_gsq": fp32["gsq_final"], "int8_gsq": i8["gsq_final"],
        },
        "headline_moments": {
            "topology": "server", "T": t_head, "opt": "adamw",
            "int8_moments_reduction_vs_fp32_moments": moment_reduction,
            "bar": 2.5,
            "fp32_moments_gsq": a_fp32["gsq_final"],
            "int8_moments_gsq": a_i8["gsq_final"],
        },
        "exchange_latency": exch,
        "headline_exchange": exch["headline"],
        "pass": bool(reduction >= 3.5 and fp32["converged"]
                     and i8["converged"] and fig2["int8"]["pass"]
                     and moment_reduction >= 2.5 and moments_ok
                     and exch["pass"]),
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    # smoke runs get their own artifact so they never clobber the
    # committed full-run results under experiments/bench/
    save_result("comm_bytes_smoke" if smoke else "comm_bytes", payload)
    if not smoke:
        # the committed wire-byte-frontier artifact — full runs only
        (REPO_ROOT / "BENCH_comm_bytes.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    r = main()
    print(json.dumps(r["headline"], indent=1))
    sys.exit(0 if r["pass"] else 1)
