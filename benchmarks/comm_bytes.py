"""Wire-byte frontier of the comm subsystem: T x codec x topology sweep.

The paper's claim is rounds-vs-bytes (arXiv:2102.01583 frames exactly this
resource); this benchmark prices it EXACTLY with the comm subsystem's
wire accounting (repro.comm, DESIGN.md §8) instead of post-hoc HLO
analysis. Two experiments, both through the packed round engine:

  sweep   convex feasibility (consistent least squares over G nodes,
          paper Sec 2.3 geometry) run to convergence for every
          (topology x codec x T) cell: exact payload bytes per round,
          cumulative bytes, and the final mean ||grad_i||^2 — showing
          the frontier (e.g. int8 cuts bytes ~3.9x at equal T with
          convergence preserved; delta coding makes quantization noise
          vanish as rounds converge).
  fig2    the paper's Fig-2(a) Beck-Teboulle feasibility re-run with the
          fp32 and int8 wire: the log-log slope of ||grad f(x_n)||^2 and
          the final residual must survive quantized communication.

Headline (the acceptance bar): server topology, T=16 — int8 wire bytes
>= 3.5x under fp32 AND int8 converges to the same tolerance; fig2 keeps
slope < -0.5 and gsq_last < 1e-6 under int8.

Writes experiments/bench/comm_bytes.json and the committed
perf-trajectory artifact BENCH_comm_bytes.json on full runs.
COMM_BYTES_SMOKE=1 runs a reduced sweep for CI with proportionally
relaxed convergence bars (so CI fails on real regressions, not just
crashes) and writes only comm_bytes_smoke.json — it never clobbers the
full-run artifacts. Exit code reflects the pass flag.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro import comm as comm_mod
from repro import optim
from repro.core import localsgd as lsgd
from repro.optim import packing

G = 4
D = 400          # model dim: int8 @ chunk=256 -> 4N/(N + 4*ceil(N/256))
LR = 0.4
GSQ_TOL = 1e-10  # converged: mean per-group ||grad_i||^2 at the result
# smoke runs use far fewer rounds, so the convergence bars scale with
# them — the CI step then FAILS (nonzero exit) on a real regression
# instead of only guarding against crashes
GSQ_TOL_SMOKE = 1e-5
FIG2_TOL, FIG2_TOL_SMOKE = 1e-6, 1e-4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_feasibility(seed: int = 0, rows: int = 20):
    """Consistent least squares split over G nodes: every node's system
    is satisfiable at w*, so the intersection is non-empty and Alg 1
    converges (paper Sec 2.3 geometry)."""
    rng = np.random.RandomState(seed)
    A = rng.randn(G, rows, D).astype(np.float32) / np.sqrt(D)
    w_star = rng.randn(D).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(D).astype(np.float32))}
    return params, batch


def run_cell(params, batch, layout, topology: str, codec: str, t_inner: int,
             rounds: int, gsq_tol: float = GSQ_TOL) -> dict:
    ex = comm_mod.get_exchange(topology, codec, G, staleness=1)
    cfg = lsgd.LocalSGDConfig(
        n_groups=G, inner_steps=t_inner,
        average_opt_state=topology != "async_stale")
    opt = optim.packed("sgd", LR, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                            exchange=ex)
    m = None
    for _ in range(rounds):
        state, m = rnd(state, batch)
    wire = int(m["wire_bytes"])
    # the metric must agree with the exchange's static accounting
    assert wire == ex.wire_bytes_per_round(layout.size), (
        wire, ex.wire_bytes_per_round(layout.size))
    gsq = float(jnp.mean(m["grad_sq"]))
    return {
        "wire_bytes_per_round": wire,
        "cumulative_wire_mb": wire * rounds / 1e6,
        "gsq_final": gsq,
        "loss_final": float(jnp.mean(m["loss"])),
        "converged": bool(gsq < gsq_tol),
        "rounds": rounds,
    }


# ---------------------------------------------------------------------------
# Fig-2(a)-style check: Beck-Teboulle feasibility through the quantized wire
# ---------------------------------------------------------------------------


def bt_loss(params, batch):
    """The two Beck-Teboulle losses as ONE batch-indexed loss so the
    standard G-axis round runs them (group i gets batch["i"] == i)."""
    x, y = params["w"][0], params["w"][1]
    f1 = jnp.maximum(jnp.sqrt(x ** 2 + (y - 1.0) ** 2 + 1e-30) - 1.0,
                     0.0) ** 2
    f2 = jnp.maximum(y, 0.0) ** 2
    return jnp.where(batch["i"] == 0, f1, f2)


def run_fig2(codec: str, rounds: int, tol: float = FIG2_TOL) -> dict:
    m_nodes, T = 2, 10
    params = {"w": jnp.array([1.5, 0.8], jnp.float32)}
    layout = packing.layout_of(params)
    batch = {"i": jnp.arange(m_nodes)}
    ex = comm_mod.get_exchange("server", codec, m_nodes, chunk=256)
    cfg = lsgd.LocalSGDConfig(n_groups=m_nodes, inner_steps=T)
    opt = optim.packed("sgd", 0.4, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(bt_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    state = lsgd.init_state(params, opt, n_groups=m_nodes, layout=layout,
                            exchange=ex)

    @jax.jit
    def global_gsq(w):   # ||grad of the AVERAGE objective||^2, as fig2a
        g = (jax.grad(lambda w: bt_loss({"w": w}, {"i": 0}))(w)
             + jax.grad(lambda w: bt_loss({"w": w}, {"i": 1}))(w)) / 2.0
        return jnp.sum(g ** 2)

    gsq, wire = [], 0
    for _ in range(rounds):
        state, m = rnd(state, batch)
        wire += int(m["wire_bytes"])
        gsq.append(float(global_gsq(state["params"][0])))
    n = np.arange(1, rounds + 1)
    tail = slice(rounds // 10, None)
    slope = float(np.polyfit(np.log(n[tail]),
                             np.log(np.maximum(gsq, 1e-300))[tail], 1)[0])
    return {"codec": codec, "rounds": rounds, "T": T,
            "wire_bytes_total": wire,
            "gsq_first": gsq[0], "gsq_last": gsq[-1],
            "loglog_slope": slope,
            "pass": bool(slope < -0.5 and gsq[-1] < tol)}


def main() -> dict:
    smoke = bool(int(os.environ.get("COMM_BYTES_SMOKE", "0")))
    rounds = 15 if smoke else 120
    fig2_rounds = 150 if smoke else 2000
    gsq_tol = GSQ_TOL_SMOKE if smoke else GSQ_TOL
    fig2_tol = FIG2_TOL_SMOKE if smoke else FIG2_TOL
    topologies = ["server", "ring"] if smoke else \
        ["server", "ring", "gossip", "async_stale", "none"]
    codecs = ["fp32", "int8"] if smoke else \
        ["fp32", "fp16", "bf16", "int8", "topk"]
    t_values = [16] if smoke else [4, 16]

    params, batch = make_feasibility()
    layout = packing.layout_of(params)
    sweep = {}
    for topo in topologies:
        for codec in codecs:
            if topo == "async_stale" and codec == "topk":
                continue   # refused: staleness drops rounds, EF assumes
                           # delivery (DESIGN.md §8)
            if topo == "none" and codec != "fp32":
                continue   # no wire -> codecs are skipped entirely; one
                           # baseline row is enough
            for t in t_values:
                cell = run_cell(params, batch, layout, topo, codec, t,
                                rounds, gsq_tol=gsq_tol)
                sweep[f"{topo}/{codec}/T{t}"] = cell
                print(f"  {topo:11s} {codec:5s} T={t:<3d} "
                      f"wire {cell['wire_bytes_per_round']:>6,}B/round "
                      f"gsq {cell['gsq_final']:.2e} "
                      f"{'ok' if cell['converged'] else '--'}", flush=True)

    t_head = t_values[-1]
    fp32 = sweep[f"server/fp32/T{t_head}"]
    i8 = sweep[f"server/int8/T{t_head}"]
    reduction = fp32["wire_bytes_per_round"] / i8["wire_bytes_per_round"]
    fig2 = {c: run_fig2(c, fig2_rounds, tol=fig2_tol)
            for c in ("fp32", "int8")}
    for c, r in fig2.items():
        print(f"  fig2 {c}: slope {r['loglog_slope']:.2f} "
              f"gsq_last {r['gsq_last']:.2e} "
              f"{'ok' if r['pass'] else '--'}", flush=True)

    payload = {
        "G": G, "dim": D, "lr": LR, "gsq_tol": gsq_tol,
        "problem": "consistent least squares over G nodes (Sec 2.3 "
                   "feasibility geometry); fig2 = Beck-Teboulle, T=10",
        "accounting": "uplink-only exact payload bytes "
                      "(Exchange.wire_bytes_per_round, DESIGN.md §8)",
        "sweep": sweep,
        "fig2": fig2,
        "headline": {
            "topology": "server", "T": t_head,
            "int8_reduction_vs_fp32": reduction, "bar": 3.5,
            "fp32_gsq": fp32["gsq_final"], "int8_gsq": i8["gsq_final"],
        },
        "pass": bool(reduction >= 3.5 and fp32["converged"]
                     and i8["converged"] and fig2["int8"]["pass"]),
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    # smoke runs get their own artifact so they never clobber the
    # committed full-run results under experiments/bench/
    save_result("comm_bytes_smoke" if smoke else "comm_bytes", payload)
    if not smoke:
        # the committed wire-byte-frontier artifact — full runs only
        (REPO_ROOT / "BENCH_comm_bytes.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    r = main()
    print(json.dumps(r["headline"], indent=1))
    sys.exit(0 if r["pass"] else 1)
