"""Round-engine throughput: packed flat-buffer vs per-leaf pytree rounds.

Measures steps/sec (T inner steps per local round, G groups) and bytes
moved for the implementations of the paper's hot path — the T-step local
loop + one server averaging (core.localsgd):

  pytree       the seed engine as shipped: per-leaf python-zipped updates
               and per-step loss/||grad||^2 trajectory metrics
  packed       the flat-buffer engine, default contract: one (G, N) f32
               buffer per state part, one fused update pass per step, one
               flat mean over G, metrics evaluated ONCE on the round's
               result (the fixed-T algorithm needs no per-step
               diagnostics), donated buffers
  packed_traj  the flat-buffer engine in metric-parity mode (per-step
               trajectories like the seed) — separates the two sources of
               the win: fused flat updates vs the leaner metric contract.
               On this 2-core CPU container XLA already fuses the per-leaf
               chains to the bandwidth floor, so packed_traj ties the seed
               (~1.0x) and the headline win comes from not materializing
               T per-step trajectories; on TPU the fused Pallas kernels
               are expected to widen both numbers.

The probe loss is separable (grad_i = p_i - target, leaf by leaf), so its
forward/backward is the SAME per-leaf work in both engines: what the
numbers compare is exactly the round engine this PR rewires (optimizer
update + metrics + averaging). A full model fwd/bwd is identical code in
both paths and would only dilute the signal. HONEST CAVEAT — the BENCH
JSON's ``real_model`` row, measured with the actual transformer loss on
this CPU container, shows packed at ~0.8-1.0x: fwd/bwd dominates there
and the per-step grad pack adds passes, so on this backend --packed is
NOT a real-model win; the engine targets the round-overhead portion and
the TPU fused path.

Sweeps sgd / momentum / adamw at several model sizes and T values.
Headline (the acceptance bar): sgd — the paper's local GD — on the
reduced paper-lenet config at T=16, packed ≥ 1.5x pytree steps/sec.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
import warnings
from pathlib import Path

# the pytree round's int32 step counters can't always be aliased — noise
warnings.filterwarnings("ignore", message="Some donated buffers")

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_trace, save_result
from repro import optim
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.models import build_model
from repro.optim import packing

G = 4


def probe_loss(params, batch):
    """Separable quadratic: pulls every param toward the group target."""
    c = batch["c"]
    return sum(0.5 * jnp.sum(jnp.square(p.astype(jnp.float32) - c))
               for p in jax.tree.leaves(params)) * 1e-6


def _params_for(cfg):
    model = build_model(cfg, schedule="rect")
    return jax.tree.map(lambda s: jnp.full(s.shape, 0.1, s.dtype),
                        model.abstract())


class _Runner:
    """Holds one jitted variant's state so timing blocks of the variants
    can be interleaved (container timing drifts; interleaving keeps the
    comparison fair)."""

    def __init__(self, round_fn, state, batch):
        self.fn, self.state, self.batch = round_fn, state, batch
        self.times = []
        self.state = self.fn(self.state, self.batch)[0]   # compile + warm
        jax.block_until_ready(self.state)

    def run_block(self, reps):
        for _ in range(reps):
            t0 = time.time()
            self.state, _ = self.fn(self.state, self.batch)
            jax.block_until_ready(self.state)
            self.times.append(time.time() - t0)

    def median_s(self):
        return float(np.median(self.times))


def _bytes_accessed(fn, donate, *abstract_args):
    try:
        jitted = jax.jit(fn, donate_argnums=(0,) if donate else ())
        cost = jitted.lower(*abstract_args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        by = cost.get("bytes accessed")
        return None if by is None else float(by)
    except Exception:
        return None


def measure_pair(params, layout, loss_fn, opt_name, t_inner, batch_t,
                 batch_p, reps):
    """One (opt, T) cell: three engine variants.

      pytree       the seed round as shipped (per-step traj metrics)
      packed       the flat-buffer round, default contract (fused updates,
                   metrics evaluated once on the round's result)
      packed_traj  the flat-buffer round in metric-parity mode (per-step
                   trajectories like the seed) — isolates how much of the
                   win is fused updates vs the leaner metric contract
    """
    opt_t = optim.get(opt_name, 0.05)
    opt_p = optim.get(opt_name, 0.05, packed=True)
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    lcfg_traj = dataclasses.replace(lcfg, metrics="traj")

    variants = {
        "pytree": (lsgd.make_local_round(loss_fn, opt_t, lcfg), opt_t,
                   None, batch_t),
        "packed": (lsgd.make_local_round(loss_fn, opt_p, lcfg,
                                         layout=layout), opt_p, layout,
                   batch_p),
        "packed_traj": (lsgd.make_local_round(loss_fn, opt_p, lcfg_traj,
                                              layout=layout), opt_p,
                        layout, batch_p),
    }
    runners = {}
    for vname, (rnd, opt, lay, batch) in variants.items():
        # every variant gets donated buffers: the comparison is engine vs
        # engine, not donation vs no-donation
        jitted = jax.jit(rnd, donate_argnums=(0,))
        state = lsgd.init_state(params, opt, n_groups=G, layout=lay)
        runners[vname] = _Runner(jitted, state, batch)
    block = max(2, reps // 3)
    done = 0
    while done < reps:                 # interleave the variants' timing
        for r in runners.values():
            r.run_block(min(block, reps - done))
        done += block

    out = {}
    for vname, (rnd, opt, lay, batch) in variants.items():
        sec = runners[vname].median_s()
        st_abs = jax.eval_shape(
            lambda o=opt, l=lay: lsgd.init_state(params, o, n_groups=G,
                                                 layout=l))
        out[vname] = {"round_s": sec, "steps_per_s": t_inner / sec,
                      "bytes_accessed": _bytes_accessed(rnd, True, st_abs,
                                                        batch)}
    out["speedup"] = out["pytree"]["round_s"] / out["packed"]["round_s"]
    out["speedup_traj_parity"] = (out["pytree"]["round_s"]
                                  / out["packed_traj"]["round_s"])
    by_t = out["pytree"]["bytes_accessed"]
    by_p = out["packed"]["bytes_accessed"]
    if by_t and by_p:
        out["bytes_moved_ratio"] = by_t / by_p
    return out


def _sharded_row(reps: int) -> dict:
    """Runs INSIDE the forced-8-device child (--sharded-child): the same
    packed T=16 sgd round on a (data=4, model=2) host mesh, executed two
    ways on the SAME padded ShardedLayout —

      replicated  buffer replicated within a group (the pre-shardexec
                  mesh path), GSPMD partitions the jnp fusion
      sharded     buffer split over "model", fused update + exchange in
                  shard_map blocks (DESIGN.md §9)

    Timed with impl="jnp" on both (the Pallas kernels only COMPILE on
    TPU; interpret mode would time the emulator, not the engine). The
    per-device state bytes are the memory headline: sharded cuts them by
    n_shards. Wall-clock on a host-platform CPU mesh mostly measures
    collective emulation — reported honestly, the win is the TPU path."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.sharding import shardexec as shx

    cfg = get_config("paper-lenet").reduced()
    params = _params_for(cfg)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                ("data", "model"))
    sexec = shx.plan_for(mesh)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    t_inner = 16
    batch = {"c": jnp.linspace(0.0, 1.0, G)}
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    out = {"mesh": [4, 2], "n_flat": layout.size,
           "n_flat_padded": layout.padded, "n_shards": sexec.n_shards,
           "T": t_inner, "opt": "sgd"}
    runners, per_dev = {}, {}
    for tag, sx in (("replicated", None), ("sharded", sexec)):
        opt = optim.get("sgd", 0.05, packed=True, impl="jnp")
        rnd = lsgd.make_local_round(probe_loss, opt, lcfg, layout=layout,
                                    shardexec=sx)
        spec = sexec.buf_spec() if sx is not None else P("data")
        buf_sh = NamedSharding(mesh, spec)
        rep_sh = NamedSharding(mesh, P())
        state = lsgd.init_state(params, opt, n_groups=G, layout=layout)
        state = jax.tree.map(
            lambda x: jax.device_put(
                x, buf_sh if (x.ndim == 2 and x.shape[-1] == layout.padded)
                else rep_sh), state)
        # per-device bytes of ONE (G, Np) state buffer under this
        # placement (sgd: just params; momentum/adamw moments scale the
        # same way) — the memory-scaling headline
        per_dev[tag] = int(np.prod(
            buf_sh.shard_shape((G, layout.padded)))) * 4
        runners[tag] = _Runner(jax.jit(rnd, donate_argnums=(0,)), state,
                               batch)
    block = max(2, reps // 3)
    done = 0
    while done < reps:
        for r in runners.values():
            r.run_block(min(block, reps - done))
        done += block
    for tag, r in runners.items():
        out[tag] = {"round_s": r.median_s(),
                    "steps_per_s": t_inner / r.median_s(),
                    "state_buf_bytes_per_device": per_dev[tag]}
    out["speedup_sharded_vs_replicated"] = (
        out["replicated"]["round_s"] / out["sharded"]["round_s"])
    out["per_device_state_reduction"] = (
        per_dev["replicated"] / per_dev["sharded"])
    return out


def _run_sharded_subprocess(reps: int) -> dict:
    """Fork a child with 8 forced host devices (the parent runs on the
    real single device; jax locks the count at init) and collect the
    sharded-vs-replicated row it prints as its last stdout line."""
    import subprocess

    from benchmarks.common import child_env

    r = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--sharded-child",
         str(reps)],
        env=child_env(force_devices=8), capture_output=True, text=True,
        timeout=1800)
    if r.returncode != 0:
        return {"error": (r.stderr or r.stdout)[-2000:]}
    return json.loads(r.stdout.strip().splitlines()[-1])


def _trace_overhead_row(reps: int, bar: float) -> dict:
    """Trace overhead (ISSUE 7 acceptance): the packed T=16 sgd headline
    round run two ways, interleaved —

      bare    fenced timing only (block_until_ready, no sink)
      traced  the full obs.Trace path every round: TraceAnnotation'd
              phase, fence, emit_round to a real JSONL sink

    throughput_ratio = bare_round_s / traced_round_s (1.0 == free). The
    bar gates via run.py --check: tracing must keep ≥ 95% of headline
    round throughput (85% in smoke — 3-rep medians on a noisy 2-core
    container)."""
    cfg = get_config("paper-lenet").reduced()
    params = _params_for(cfg)
    layout = packing.layout_of(params)
    t_inner = 16
    batch = {"c": jnp.linspace(0.0, 1.0, G)}
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    opt = optim.get("sgd", 0.05, packed=True)
    rnd = jax.jit(lsgd.make_local_round(probe_loss, opt, lcfg,
                                        layout=layout), donate_argnums=(0,))
    tr = bench_trace("trace_overhead",
                     meta={"config": cfg.name, "T": t_inner, "opt": "sgd"})

    class _TracedRunner(_Runner):
        n = 0

        def run_block(self, reps):
            for _ in range(reps):
                t0 = time.time()
                with tr.phase("round") as f:
                    self.state, m = f(self.fn(self.state, self.batch))
                tr.emit_round(_TracedRunner.n, m)
                _TracedRunner.n += 1
                self.times.append(time.time() - t0)

    runners = {}
    for tag, klass in (("bare", _Runner), ("traced", _TracedRunner)):
        state = lsgd.init_state(params, opt, n_groups=G, layout=layout)
        runners[tag] = klass(rnd, state, batch)
    block = max(2, reps // 3)
    done = 0
    while done < reps:
        for r in runners.values():
            r.run_block(min(block, reps - done))
        done += block
    tr.close()
    bare_s = runners["bare"].median_s()
    traced_s = runners["traced"].median_s()
    return {"config": cfg.name, "T": t_inner, "opt": "sgd",
            "bare_round_s": bare_s, "traced_round_s": traced_s,
            "trace_records": tr.n_records,
            "throughput_ratio": bare_s / traced_s, "bar": bar}


def _real_model_row(reps):
    """Supplementary: the same comparison with the REAL transformer loss
    (fwd/bwd dominates on CPU; expect ~1x — reported for honesty)."""
    cfg = get_config("paper-lenet").reduced()
    model = build_model(cfg, schedule="rect")
    params = model.init(jax.random.PRNGKey(0))
    layout = packing.layout_of(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (G, 1, 64)), jnp.int32)}
    return measure_pair(params, layout, model.loss, "sgd", 16,
                        batch, batch, max(2, reps // 2))


def main() -> dict:
    smoke = bool(int(os.environ.get("ROUND_THROUGHPUT_SMOKE", "0")))
    reps = 3 if smoke else 9

    lenet_red = get_config("paper-lenet").reduced()
    sizes = {
        "paper-lenet-reduced": lenet_red,
    }
    if not smoke:
        sizes["paper-lenet-reduced-d128"] = dataclasses.replace(
            lenet_red, name="paper-lenet-reduced-d128", d_model=128,
            d_ff=256, n_heads=4, n_kv_heads=2, head_dim=32)
        sizes["paper-lenet-reduced-d512"] = dataclasses.replace(
            lenet_red, name="paper-lenet-reduced-d512", d_model=512,
            d_ff=1024, n_heads=4, n_kv_heads=2, head_dim=128)
    t_values = [16] if smoke else [4, 16]
    opts = ["sgd"] if smoke else ["sgd", "momentum", "adamw"]

    batch = {"c": jnp.linspace(0.0, 1.0, G)}
    results = {}
    for cname, cfg in sizes.items():
        params = _params_for(cfg)
        layout = packing.layout_of(params)
        per_cfg = {"n_flat": layout.size, "n_leaves": len(layout.shapes),
                   "results": {}}
        for t_inner in t_values:
            for opt_name in opts:
                cell = measure_pair(params, layout, probe_loss, opt_name,
                                    t_inner, batch, batch, reps)
                per_cfg["results"][f"T{t_inner}/{opt_name}"] = cell
                print(f"  {cname} T={t_inner} {opt_name}: "
                      f"pytree {cell['pytree']['steps_per_s']:.1f} st/s, "
                      f"packed {cell['packed']['steps_per_s']:.1f} st/s "
                      f"({cell['speedup']:.2f}x; traj-parity "
                      f"{cell['speedup_traj_parity']:.2f}x)", flush=True)
        results[cname] = per_cfg

    head = results["paper-lenet-reduced"]["results"]["T16/sgd"]
    payload = {
        "G": G,
        "probe_loss": "separable quadratic (engine-isolating; see module "
                      "docstring)",
        "configs": results,
        "headline": {"config": "paper-lenet-reduced", "T": 16,
                     "opt": "sgd", "speedup": head["speedup"],
                     "bar": 1.5},
        "pass": head["speedup"] >= 1.5,
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    if not smoke:
        payload["real_model"] = _real_model_row(reps)
    # sharded-vs-replicated on a forced 8-device host mesh (DESIGN.md §9)
    # — runs in smoke too so CI exercises the shard_map wiring; a broken
    # child must FAIL the run, not record an error blob and stay green
    payload["sharded"] = _run_sharded_subprocess(max(3, reps // 2))
    # smoke runs get their own artifact so they never clobber the
    # committed full-run results (same rule as comm_bytes)
    artifact = "round_throughput_smoke" if smoke else "round_throughput"
    if "error" in payload["sharded"]:
        save_result(artifact, payload)
        raise SystemExit("sharded round-throughput child failed:\n"
                         + payload["sharded"]["error"])
    s = payload["sharded"]
    print(f"  sharded(4x2) T={s['T']} {s['opt']}: replicated "
          f"{s['replicated']['steps_per_s']:.1f} st/s, sharded "
          f"{s['sharded']['steps_per_s']:.1f} st/s "
          f"({s['speedup_sharded_vs_replicated']:.2f}x; state/device "
          f"1/{s['per_device_state_reduction']:.0f})", flush=True)
    # trace overhead on the same headline cell (ISSUE 7 acceptance:
    # per-round telemetry must keep >= 95% of bare round throughput)
    trow = _trace_overhead_row(reps, bar=0.85 if smoke else 0.95)
    payload["trace_overhead"] = trow
    payload["headline_trace"] = {
        "config": trow["config"], "T": trow["T"], "opt": trow["opt"],
        "throughput_ratio": trow["throughput_ratio"], "bar": trow["bar"]}
    payload["pass"] = bool(payload["pass"]
                           and trow["throughput_ratio"] >= trow["bar"])
    print(f"  trace overhead T={trow['T']} {trow['opt']}: bare "
          f"{trow['bare_round_s']*1e3:.1f}ms, traced "
          f"{trow['traced_round_s']*1e3:.1f}ms (throughput ratio "
          f"{trow['throughput_ratio']:.3f}, bar {trow['bar']})", flush=True)
    save_result(artifact, payload)
    if not smoke:
        # the committed perf-trajectory artifact — full runs only, so CI
        # smoke runs never clobber it with reduced data
        (REPO_ROOT / "BENCH_round_throughput.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-child":
        reps_ = int(sys.argv[2]) if len(sys.argv) > 2 else 5
        print(json.dumps(_sharded_row(reps_), default=float))
        sys.exit(0)
    r = main()
    print(json.dumps(r["headline"], indent=1))
