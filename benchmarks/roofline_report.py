"""Roofline summary benchmark: re-derives the three terms for every
(arch x shape) from the cached dry-run records and reports aggregate
statistics (deliverable g; full table in EXPERIMENTS.md)."""
from benchmarks.common import save_result

from repro.launch.roofline import load_records, roofline_row


def main() -> dict:
    rows = [r for r in (roofline_row(rec) for rec in load_records())
            if r]
    assert rows, "run `python -m repro.launch.dryrun --all` first"
    dominant = {}
    for r in rows:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    worst = min(rows, key=lambda r: r["useful_frac"])
    res = {
        "name": "roofline-summary",
        "pairs": len(rows),
        "dominant_counts": dominant,
        "not_fitting_hbm": [f"{r['arch']}x{r['shape']}" for r in rows
                            if not r["fits_hbm"]],
        "worst_useful_frac": {
            "pair": f"{worst['arch']}x{worst['shape']}",
            "useful_frac": worst["useful_frac"]},
        "pass": len(rows) == 40,
    }
    save_result("roofline_summary", res)
    return res


if __name__ == "__main__":
    print(main())
