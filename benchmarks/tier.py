"""Tiered fault domains: the hierarchical two-tier exchange priced and
stressed on its own links (ISSUE 10 / DESIGN.md §16).

Flat topologies treat every link the same; real clusters don't — the
intra-pod fabric (ICI) is fast and reliable, the cross-pod link (DCN) is
slow and lossy. The hierarchical exchange factors G = n_pods x pod_size,
runs an intra-pod consensus hop plus a cross-pod push-sum between pod
leaders, and carries an independent codec and an independent FaultPlan
per tier. Four sections price the claims:

  wire    the cross-tier codec: quantizing ONLY the DCN payload (int8
          inter codec) shrinks the cross-pod bytes ~3.9x while the
          intra-pod bytes stay untouched fp32 — per-tier accounting via
          ``wire_bytes_by_tier`` — plus an executed training sanity cell
          proving the quantized inter link still converges.
  sweep   hierarchical training cells through the packed round engine at
          0 / 7.5% DCN loss: the lossy cell must land within 10x of the
          lossless one (cross-tier push-sum conserves mass; loss only
          delays it).
  bias    the §16 design choice, mixing-only: at the SAME loss rate a
          flat masked-gossip hop drifts the group mean (consensus on a
          wrong point) while the tiered exchange's cross-pod push-sum
          ratio consensus stays unbiased to float32 resolution — the
          unbias factor is ~1e5 (bar 1e4).
  rejoin  graceful cross-tier degradation as exact booleans: a pod whose
          DCN uplink dies for a window degrades to local-only rounds
          (its pod mean frozen), total mass + queued backlog stays
          EXACTLY G every round, and after rejoin the drained backlog
          pulls every node to the true global mean.
  sharded (subprocess, 16 forced host devices: G=8 data shards x 2
          model shards — the tests/test_faults.py child pattern) the
          lossless-vs-lossy-DCN comparison re-run
          through the shard_map execution layer; tier masks are drawn
          outside shard_map, so the sharded cells replay the replicated
          schedule.

Headline (all bigger-is-better for run.py --check):

  cross_tier_wire_reduction  fp32 inter bytes / int8 inter bytes on the
                             same hierarchical exchange (>= 3.5).
  tier_unbias_factor         flat-gossip mean bias / tiered mean bias
                             under equal loss (>= 1e4).
  tier_gsq_margin            10x floored lossless gsq over the
                             7.5%-DCN-loss gsq (>= 1.0), replicated AND
                             sharded.

Writes experiments/bench/tier.json and the committed artifact
BENCH_tier.json on full runs. TIER_SMOKE=1 (or --smoke) runs the
reduced CI lane — fewer rounds, relaxed floors, still including the
forced-16-device sharded child — writing only tier_smoke.json. Exit
code reflects the pass flag.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # standalone invocation
    sys.path.insert(0, str(REPO_ROOT))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import child_env, save_result
from repro import comm as comm_mod
from repro import optim
from repro.core import localsgd as lsgd
from repro.optim import packing

G = 8
PODS = 4
D = 400
LR = 0.4
DCN_DROP = 0.075     # headline cross-pod loss rate (mid 5-10% band)
FAULT_SEED = 0       # training cells; the bias cell pins its own seed
BIAS_SEED = 2
GSQ_FLOOR = 1e-7             # converged-to-tolerance floor (full runs;
#                              G=8 fp32 rounds plateau at gsq ~1e-8)
GSQ_FLOOR_SMOKE = 1e-4
UNBIAS_BAR = 1e4
WIRE_BAR = 3.5


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_feasibility(seed: int = 0, rows: int = 20):
    rng = np.random.RandomState(seed)
    A = rng.randn(G, rows, D).astype(np.float32) / np.sqrt(D)
    w_star = rng.randn(D).astype(np.float32)
    batch = {"A": jnp.asarray(A),
             "b": jnp.asarray(np.einsum("grd,d->gr", A, w_star))}
    params = {"w": jnp.asarray(rng.randn(D).astype(np.float32))}
    return params, batch


def hier(codec: str = "fp32", **kw):
    kw.setdefault("fault_seed", FAULT_SEED)
    return comm_mod.get_exchange("hierarchical", codec, G, n_pods=PODS,
                                 **kw)


def run_cell(params, batch, layout, ex, t_inner: int, rounds: int,
             shardexec=None) -> dict:
    """One hierarchical training cell through the packed round engine."""
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner)
    opt = optim.packed("sgd", LR, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex,
                                        shardexec=shardexec))
    state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                            exchange=ex)
    m = None
    for _ in range(rounds):
        state, m = rnd(state, batch)
    by_tier = ex.wire_bytes_by_tier(layout.padded)
    wire = int(m["wire_bytes"])
    assert wire == by_tier["intra"] + by_tier["inter"], (wire, by_tier)
    return {
        "wire_bytes_per_round": wire,
        "wire_bytes_intra": int(by_tier["intra"]),
        "wire_bytes_inter": int(by_tier["inter"]),
        "delivery_rate_intra": ex.delivery_rate_intra,
        "delivery_rate_inter": ex.delivery_rate_inter,
        "participation_inter": float(m["participation_inter"]),
        "gsq_final": float(jnp.mean(m["grad_sq"])),
        "loss_final": float(jnp.mean(m["loss"])),
        "rounds": rounds, "comm": ex.name,
    }


def bias_cell(drop: float, iters: int = 60) -> dict:
    """Mixing-only consensus: flat gossip vs the tiered exchange under
    the same loss rate — where does each land relative to the true
    mean?"""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (G, 20)) * 3.0
    mean0 = np.asarray(jnp.mean(x, axis=0))
    cells = {
        "gossip_flat": comm_mod.get_exchange(
            "gossip", "fp32", G, mix_rounds=1, drop_rate=drop,
            fault_seed=BIAS_SEED),
        "hier_push_sum": hier(drop_rate=drop, fault_seed=BIAS_SEED),
    }
    out = {}
    for tag, ex in cells.items():
        st = ex.init(x)
        fn = jax.jit(ex.params)
        xs0 = x if ex.lossy_stream("params") else None
        y = x
        for _ in range(iters):
            y, st = fn(y, xs0, st)
        o = np.asarray(y)
        out[tag] = {
            "mean_bias": float(np.abs(o.mean(axis=0) - mean0).max()),
            "consensus_spread": float(np.abs(o - o.mean(axis=0)).max()),
            "iters": iters, "drop_rate": drop, "seed": BIAS_SEED,
            "comm": ex.name,
        }
    return out


def rejoin_cell(rounds: int = 24) -> dict:
    """Pod 1 (lanes 2-3) loses its DCN uplink for rounds [2, 5): exact
    degradation/rejoin booleans for the pass flag."""
    x = jax.random.normal(jax.random.PRNGKey(1), (G, 32))
    true_mean = np.asarray(x).mean(0)
    ex = hier(dropouts=((2, 2, 5), (3, 2, 5)), fault_seed=1)
    st = ex.init(x)
    fn = jax.jit(ex.params)
    y = x
    mass_ok, frozen_ok, pod1 = True, True, None
    for rnd in range(rounds):
        y, st = fn(y, None, st)
        mass = float(jnp.sum(st["mass"]) + jnp.sum(st["backlog_w"]))
        mass_ok = mass_ok and abs(mass - G) < 1e-3
        cur = np.asarray(y)[2:4].mean(0)
        if rnd == 2:
            pod1 = cur
        elif rnd in (3, 4):     # degraded: pod-local rounds only
            frozen_ok = frozen_ok and bool(
                np.allclose(cur, pod1, rtol=1e-5, atol=1e-6))
    final_bias = float(np.abs(np.asarray(y).mean(0) - true_mean).max())
    return {
        "mass_conserved_every_round": bool(mass_ok),
        "degraded_pod_mean_frozen": bool(frozen_ok),
        "rejoin_mean_bias": final_bias,
        "rejoin_exact": bool(mass_ok and frozen_ok and final_bias < 1e-3),
        "dropouts": [[2, 2, 5], [3, 2, 5]], "rounds": rounds,
    }


def _margin(gsq_lossless: float, gsq_faulty: float, floor: float) -> float:
    """>= 1.0 iff the lossy-DCN cell's gsq is within 10x of the lossless
    one, both floored at the convergence tolerance."""
    return 10.0 * max(gsq_lossless, floor) / max(gsq_faulty, floor)


# ---------------------------------------------------------------------------
# sharded child: the same comparison through the shard_map layer
# ---------------------------------------------------------------------------


def _child_main(rounds: int) -> dict:
    from jax.sharding import Mesh

    from repro.sharding import shardexec as shx

    out = {"n_devices": jax.device_count()}
    # groups map onto the data axis: G=8 data shards x 2 model shards
    mesh = Mesh(np.array(jax.devices()[:16]).reshape(8, 2),
                ("data", "model"))
    sexec = shx.plan_for(mesh)
    params, batch = make_feasibility()
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    for tag, kw in (("lossless", {}),
                    ("dcn_loss", dict(drop_rate=DCN_DROP))):
        out[tag] = run_cell(params, batch, layout, hier(**kw),
                            t_inner=16, rounds=rounds, shardexec=sexec)
    return out


def main() -> dict:
    smoke = bool(int(os.environ.get("TIER_SMOKE", "0"))) \
        or "--smoke" in sys.argv
    rounds = 15 if smoke else 120
    child_rounds = 15 if smoke else 120
    bias_iters = 30 if smoke else 60
    floor = GSQ_FLOOR_SMOKE if smoke else GSQ_FLOOR

    # -- wire: per-tier codec accounting + executed int8-inter sanity ----
    ex_f = hier(intra_topology="server", inter_topology="server")
    ex_q = hier(intra_topology="server", inter_topology="server",
                inter_codec="int8")
    bt_f = ex_f.wire_bytes_by_tier(D)
    bt_q = ex_q.wire_bytes_by_tier(D)
    wire_reduction = bt_f["inter"] / bt_q["inter"]
    assert bt_f["intra"] == bt_q["intra"], (bt_f, bt_q)  # intra untouched
    print(f"  wire: inter fp32 {bt_f['inter']:,}B -> int8 "
          f"{bt_q['inter']:,}B ({wire_reduction:.2f}x), intra "
          f"{bt_f['intra']:,}B both", flush=True)

    params, batch = make_feasibility()
    layout = packing.layout_of(params)
    sweep = {}
    for tag, ex in (
            ("lossless", hier()),
            ("dcn_loss", hier(drop_rate=DCN_DROP)),
            ("dcn_and_ici_loss", hier(drop_rate=DCN_DROP,
                                      intra_drop_rate=0.05)),
            ("int8_inter", ex_q)):
        cell = run_cell(params, batch, layout, ex, t_inner=16,
                        rounds=rounds)
        sweep[tag] = cell
        print(f"  {tag:17s} {cell['comm']:34s} "
              f"inter {cell['wire_bytes_inter']:>6,}B/round "
              f"gsq {cell['gsq_final']:.2e}", flush=True)
    margin = _margin(sweep["lossless"]["gsq_final"],
                     sweep["dcn_loss"]["gsq_final"], floor)

    bias = bias_cell(DCN_DROP, iters=bias_iters)
    unbias = (bias["gossip_flat"]["mean_bias"]
              / max(bias["hier_push_sum"]["mean_bias"], 1e-12))
    print(f"  bias@{DCN_DROP:g}: gossip "
          f"{bias['gossip_flat']['mean_bias']:.3f} tiered "
          f"{bias['hier_push_sum']['mean_bias']:.2e} "
          f"-> unbias factor {unbias:.0f}x", flush=True)

    rejoin = rejoin_cell()
    print(f"  rejoin: mass_conserved={rejoin['mass_conserved_every_round']}"
          f" frozen={rejoin['degraded_pod_mean_frozen']} "
          f"bias {rejoin['rejoin_mean_bias']:.1e}", flush=True)

    # -- forced-8-device shard_map path (same masks, same schedule) ------
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           str(child_rounds)]
    r = subprocess.run(cmd, env=child_env(16), capture_output=True,
                       text=True, timeout=1800, cwd=str(REPO_ROOT))
    if r.returncode != 0:
        sharded = {"error": (r.stderr or "")[-2000:]}
        sharded_margin = 0.0
    else:
        sharded = json.loads(r.stdout.strip().splitlines()[-1])
        sharded_margin = _margin(sharded["lossless"]["gsq_final"],
                                 sharded["dcn_loss"]["gsq_final"], floor)
        print(f"  sharded: lossless gsq "
              f"{sharded['lossless']['gsq_final']:.2e} dcn@{DCN_DROP:g} "
              f"{sharded['dcn_loss']['gsq_final']:.2e} "
              f"-> margin {sharded_margin:.1f}x", flush=True)

    payload = {
        "G": G, "n_pods": PODS, "dim": D, "lr": LR,
        "fault_seed": FAULT_SEED, "gsq_floor": floor,
        "problem": "consistent least squares over G nodes (Sec 2.3 "
                   "feasibility geometry)",
        "fault_model": "TieredFaultPlan: independent seed lanes per tier "
                       "(fault_seed_for), DCN loss on the inter tier "
                       "(DESIGN.md §16)",
        "wire": {"inter_fp32": int(bt_f["inter"]),
                 "inter_int8": int(bt_q["inter"]),
                 "intra_both": int(bt_f["intra"]),
                 "comm_fp32": ex_f.name, "comm_int8": ex_q.name},
        "sweep": sweep,
        "bias": bias,
        "rejoin": rejoin,
        "sharded": sharded,
        "headline": {
            "dcn_drop_rate": DCN_DROP, "T": 16,
            "cross_tier_wire_reduction": wire_reduction,
            "wire_bar": WIRE_BAR,
            "tier_unbias_factor": unbias, "unbias_bar": UNBIAS_BAR,
            "tier_gsq_margin": margin, "bar": 1.0,
            "lossless_gsq": sweep["lossless"]["gsq_final"],
            "dcn_loss_gsq": sweep["dcn_loss"]["gsq_final"],
            "gossip_bias": bias["gossip_flat"]["mean_bias"],
        },
        "headline_sharded": {
            "tier_gsq_margin": sharded_margin, "bar": 1.0,
        },
        "pass": bool(margin >= 1.0 and sharded_margin >= 1.0
                     and unbias >= UNBIAS_BAR
                     and wire_reduction >= WIRE_BAR
                     and rejoin["rejoin_exact"]
                     and sweep["lossless"]["gsq_final"] < floor
                     and sweep["int8_inter"]["gsq_final"] < floor),
        "backend": jax.default_backend(),
        "smoke": smoke,
    }
    save_result("tier_smoke" if smoke else "tier", payload)
    if not smoke:
        # the committed tiered-fault-domain artifact — full runs only
        (REPO_ROOT / "BENCH_tier.json").write_text(
            json.dumps(payload, indent=1, default=float))
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        n = int(sys.argv[sys.argv.index("--child") + 1])
        print(json.dumps(_child_main(rounds=n), default=float))
        sys.exit(0)
    res = main()
    print(json.dumps(res["headline"], indent=1))
    sys.exit(0 if res["pass"] else 1)
