"""The paper's headline claim, quantified at the HLO level on the
production mesh: local-SGD (T inner steps + ONE model all-reduce) vs the
conventional sync-DP baseline (gradient all-reduce EVERY step).

Reads/produces dry-run records (cached in experiments/dryrun): the sync
baseline is compiled with --mode sync (tag 'sync'); the local-SGD round
with t_inner=T. Both are normalized to the same token budget, then
collective bytes per token are compared."""
import json
import subprocess
import sys
from pathlib import Path

from benchmarks.common import child_env, save_result

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"

ARCHS = ["granite-moe-1b-a400m", "qwen3-32b", "xlstm-1.3b"]
SHAPE = "train_4k"


def ensure_record(arch: str, mode: str, tag: str, t_inner: int = 4):
    name = f"{arch}_{SHAPE}_pod16x16{('_' + tag) if tag else ''}.json"
    p = DRY / name
    if p.exists() and json.loads(p.read_text()).get("status") == "ok":
        return json.loads(p.read_text())
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", SHAPE, "--mode", mode, "--t-inner", str(t_inner)]
    if tag:
        cmd += ["--tag", tag]
    # inherit the full environment (venv interpreters, PATH, XLA flags)
    # and only PREPEND our src to PYTHONPATH — the shared helper
    subprocess.run(cmd, check=True, capture_output=True, text=True,
                   cwd=str(ROOT), env=child_env(), timeout=3600)
    return json.loads(p.read_text())


def main() -> dict:
    res = {"name": "communication-reduction", "shape": SHAPE, "archs": {}}
    for arch in ARCHS:
        local = ensure_record(arch, "localsgd", "")      # T=4 + averaging
        sync = ensure_record(arch, "sync", "sync")
        t = local["meta"]["t_inner"]
        # per-compiled-step collective bytes (per device). "slow" = the
        # cross-group links (the data axis / pod boundary): the traffic
        # the paper's algorithm amortizes. Intra-group tensor-parallel
        # collectives are identical between the two schedules.
        cb_local = local["hlocost"]["collective_bytes"]
        cb_sync = sync["hlocost"]["collective_bytes"]
        sl_local = local["hlocost"].get("collective_bytes_slowlink", 0)
        sl_sync = sync["hlocost"].get("collective_bytes_slowlink", 0)
        # same token budget: one local round == t sync steps
        reduction = (t * cb_sync) / cb_local if cb_local else float("inf")
        slow_reduction = (t * sl_sync) / sl_local if sl_local else \
            float("inf")
        res["archs"][arch] = {
            "t_inner": t,
            "collective_bytes_local_round": cb_local,
            "collective_bytes_sync_step": cb_sync,
            "slowlink_bytes_local_round": sl_local,
            "slowlink_bytes_sync_step": sl_sync,
            "reduction_factor_total": reduction,
            "reduction_factor": slow_reduction,
            "n_collectives_local": local["hlocost"]["collective_count"],
            "n_collectives_sync_x_t": t * sync["hlocost"][
                "collective_count"],
        }
    res["pass"] = all(v["reduction_factor"] > 1.5
                      for v in res["archs"].values())
    save_result("comm_reduction", res)
    return res


if __name__ == "__main__":
    r = main()
    print({a: round(v["reduction_factor"], 2) for a, v in r["archs"].items()},
          "pass:", r["pass"])
