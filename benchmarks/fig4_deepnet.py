"""Paper Fig 4 / Sec 3.2.2: deep networks — larger (or infinite) T_i
decreases the training loss per communication round.

The paper trains LeNet/MNIST and ResNet18/CIFAR on 1000 samples; datasets
are offline-unavailable here, so we use the framework's own transformer
('paper-mlp' config, over-parameterized for the 1000-sequence synthetic
token set) trained with the REAL production path: core.localsgd rounds
(vmapped groups + averaging), m=4 nodes, T in {1, 10, 50, threshold}."""
from benchmarks.common import save_result

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.data.synthetic import fixed_group_batches
from repro.models import build_model


def run(T, threshold, model, params0, batch, G, rounds, lr):
    opt = optim.sgd(lr)
    cfg = lsgd.LocalSGDConfig(
        n_groups=G, inner_steps=T if T else 1, threshold=threshold,
        max_inner=100)
    rnd = jax.jit(lsgd.make_local_round(model.loss, opt, cfg))
    state = lsgd.init_state(params0, opt, n_groups=G)
    losses, inners = [], []
    for _ in range(rounds):
        state, m = rnd(state, batch)
        losses.append(float(jnp.mean(m["loss"])))
        inners.append(int(jnp.max(m["inner_steps"])))
    return losses, inners


def main(rounds: int = 10) -> dict:
    cfg = get_config("paper-mlp")
    model = build_model(cfg, schedule="rect")
    params0 = model.init(jax.random.PRNGKey(0))
    G, b, S = 4, 4, 64   # 16 sequences x 64 tokens, over-parameterized
    batch = {"tokens": jnp.asarray(fixed_group_batches(
        cfg.vocab_size, S, G, b, seed=0)["tokens"])}

    res = {"figure": "4", "rounds": rounds, "curves": {}, "inner": {}}
    for label, T, thr in [("T=1", 1, None), ("T=10", 10, None),
                          ("T=50", 50, None),
                          ("threshold", None, 3e-2)]:
        losses, inners = run(T, thr, model, params0, batch, G, rounds,
                             lr=0.05)
        res["curves"][label] = losses
        res["inner"][label] = inners
    final = {k: v[-1] for k, v in res["curves"].items()}
    res["final_loss"] = final
    # paper's qualitative claim: loss-per-round improves with more local work
    res["pass"] = bool(final["T=50"] < final["T=10"] < final["T=1"]
                       and final["threshold"] < final["T=1"])
    save_result("fig4_deepnet", res)
    return res


if __name__ == "__main__":
    r = main()
    print({"final_loss": r["final_loss"], "pass": r["pass"]})
