"""Paper Fig 2(a): Beck-Teboulle synthetic feasibility, T_i = 10.

The separation condition fails (the two optimal sets meet tangentially at
the origin), so only the general-convex guarantee applies: ||grad f(x_n)||^2
vanishes at ~ C/n. We fit the tail slope on log-log axes and report it —
the paper's reference line has slope -1."""
from benchmarks.common import run_alg1, save_result

import jax.numpy as jnp
import numpy as np

from repro.data.convex import beck_teboulle_losses


def main(rounds: int = 2000) -> dict:
    losses = beck_teboulle_losses()
    out = run_alg1(losses, jnp.array([1.5, 0.8]), lr=0.4, T=10,
                   rounds=rounds)
    gsq = np.asarray(out["gsq"])
    n = np.arange(1, rounds + 1)
    tail = slice(rounds // 10, None)
    slope = float(np.polyfit(np.log(n[tail]), np.log(gsq[tail]), 1)[0])
    res = {
        "figure": "2a",
        "rounds": rounds,
        "gsq_first": gsq[0], "gsq_last": gsq[-1],
        "loglog_slope": slope,
        "paper_reference_slope": -1.0,
        "final_x": [float(v) for v in out["w"]],
        "gsq_curve_sample": gsq[:: max(rounds // 100, 1)].tolist(),
        # Theorem 2 guarantees residuals vanish AT LEAST as fast as ~1/n
        # (the paper's reference line); our lr/T give a faster power law —
        # consistent with the bound being an upper bound.
        "pass": bool(slope < -0.5 and gsq[-1] < 1e-6),
    }
    save_result("fig2a_feasibility", res)
    return res


if __name__ == "__main__":
    print(main())
