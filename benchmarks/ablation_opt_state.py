"""Beyond-paper ablation: the paper's Alg 1 averages the MODEL; with
adaptive optimizers the runtime must decide whether to also average the
optimizer state (moments). We compare both on the production local-SGD
path with AdamW — averaging the moments tracks centralized training more
closely and avoids stale-moment drift after each combination."""
from benchmarks.common import save_result

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.data.synthetic import fixed_group_batches
from repro.models import build_model


def run(average_opt_state, model, params0, batch, G, rounds=8, T=5):
    opt = optim.adamw(3e-3)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=T,
                              average_opt_state=average_opt_state)
    rnd = jax.jit(lsgd.make_local_round(model.loss, opt, cfg))
    state = lsgd.init_state(params0, opt, n_groups=G)
    losses = []
    for _ in range(rounds):
        state, m = rnd(state, batch)
        losses.append(float(jnp.mean(m["loss"])))
    return losses


def main() -> dict:
    cfg = get_config("paper-mlp").reduced()
    model = build_model(cfg, schedule="rect")
    params0 = model.init(jax.random.PRNGKey(0))
    G, b, S = 4, 2, 32
    batch = {"tokens": jnp.asarray(
        fixed_group_batches(cfg.vocab_size, S, G, b)["tokens"])}

    with_avg = run(True, model, params0, batch, G)
    without = run(False, model, params0, batch, G)
    res = {
        "name": "ablation-average-opt-state",
        "optimizer": "adamw",
        "loss_with_avg": with_avg,
        "loss_without_avg": without,
        "final_with": with_avg[-1],
        "final_without": without[-1],
        # both must train; report which is better (finding, not a gate)
        "avg_better": with_avg[-1] <= without[-1],
        "pass": bool(with_avg[-1] < with_avg[0] * 0.9
                     and without[-1] < without[0] * 0.9),
    }
    save_result("ablation_opt_state", res)
    return res


if __name__ == "__main__":
    r = main()
    print({k: r[k] for k in ("final_with", "final_without", "avg_better",
                             "pass")})
