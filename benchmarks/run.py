"""Benchmark driver: one entry per paper table/figure + the HLO-level
communication/roofline reports. Prints ``name,seconds,derived`` CSV and
writes JSON per benchmark into experiments/bench/."""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (ablation_opt_state, comm_bytes, comm_reduction,
                        fig2a_feasibility, fig2b_linear_rate,
                        fig3_intersection, fig4_deepnet, fig5_quartic,
                        fig67_nodes, roofline_report, round_throughput)

BENCHES = [
    ("fig2a_feasibility", fig2a_feasibility.main,
     lambda r: f"slope={r['loglog_slope']:.2f} (paper: -1)"),
    ("fig2b_linear_rate", fig2b_linear_rate.main,
     lambda r: "rounds_to_tol=" + str(r["rounds_to_tol"])),
    ("fig3_intersection", fig3_intersection.main,
     lambda r: "f_gap(intersected)="
               f"{r['cases']['intersected']['f_gap_vs_centralized']:.4f}"
               " nonintersected_gsq="
               f"{r['cases']['non_intersected']['gsq_10node']:.2e}"),
    ("fig4_deepnet", fig4_deepnet.main,
     lambda r: "final_loss=" + str({k: round(v, 3) for k, v in
                                    r["final_loss"].items()})),
    ("fig5_quartic", fig5_quartic.main,
     lambda r: "T*_lin={linear_formula:.1f} T*_sub={sublinear_formula:.1f}"
               .format(**r["t_star"])),
    ("fig67_nodes", fig67_nodes.main,
     lambda r: "rate(m)=" + str({m: round(v["rate"], 3)
                                 for m, v in r["by_m"].items()})),
    ("comm_reduction", comm_reduction.main,
     lambda r: "reduction=" + str({a: round(v["reduction_factor"], 2)
                                   for a, v in r["archs"].items()})),
    ("roofline_summary", roofline_report.main,
     lambda r: f"pairs={r['pairs']} dominant={r['dominant_counts']}"),
    ("ablation_opt_state", ablation_opt_state.main,
     lambda r: f"adamw final loss avg={r['final_with']:.3f} "
               f"no-avg={r['final_without']:.3f}"),
    ("round_throughput", round_throughput.main,
     lambda r: f"packed vs pytree headline="
               f"{r['headline']['speedup']:.2f}x (bar 1.5x)"),
    ("comm_bytes", comm_bytes.main,
     lambda r: f"int8 wire reduction="
               f"{r['headline']['int8_reduction_vs_fp32']:.2f}x (bar 3.5x)"
               f" fig2_int8={'ok' if r['fig2']['int8']['pass'] else 'FAIL'}"),
]


def main() -> None:
    print("name,seconds,derived")
    failures = []
    for name, fn, fmt in BENCHES:
        t0 = time.time()
        try:
            r = fn()
            dt = time.time() - t0
            status = "PASS" if r.get("pass") else "CHECK"
            print(f"{name},{dt:.1f},{status} {fmt(r)}", flush=True)
            if not r.get("pass"):
                failures.append(name)
        except Exception as e:  # pragma: no cover
            dt = time.time() - t0
            print(f"{name},{dt:.1f},ERROR {type(e).__name__}: {e}",
                  flush=True)
            failures.append(name)
    if failures:
        print(f"# {len(failures)} benchmark(s) flagged: {failures}")
        sys.exit(1)
    print("# all benchmarks reproduce the paper's claims")


if __name__ == "__main__":
    main()
