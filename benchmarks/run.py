"""Benchmark driver: one entry per paper table/figure + the HLO-level
communication/roofline reports. Prints ``name,seconds,derived`` CSV and
writes JSON per benchmark into experiments/bench/.

``--check`` is the perf-regression gate (ISSUE 5 satellite): it (1)
validates the COMMITTED BENCH_*.json artifacts — their pass flags and
every headline-vs-bar pair — and (2) re-runs the smoke benchmarks fresh
in subprocesses, exiting nonzero if either the artifacts or the fresh
numbers regress. CI's smoke job runs this, so a perf claim in the
committed artifacts can't silently rot.
"""
import json
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT))

from benchmarks import (ablation_opt_state, comm_bytes, comm_reduction,
                        fault_tolerance, fig2a_feasibility,
                        fig2b_linear_rate, fig3_intersection, fig4_deepnet,
                        fig5_quartic, fig67_nodes, overlap,
                        roofline_report, round_throughput, serve_latency,
                        tier)

BENCHES = [
    ("fig2a_feasibility", fig2a_feasibility.main,
     lambda r: f"slope={r['loglog_slope']:.2f} (paper: -1)"),
    ("fig2b_linear_rate", fig2b_linear_rate.main,
     lambda r: "rounds_to_tol=" + str(r["rounds_to_tol"])),
    ("fig3_intersection", fig3_intersection.main,
     lambda r: "f_gap(intersected)="
               f"{r['cases']['intersected']['f_gap_vs_centralized']:.4f}"
               " nonintersected_gsq="
               f"{r['cases']['non_intersected']['gsq_10node']:.2e}"),
    ("fig4_deepnet", fig4_deepnet.main,
     lambda r: "final_loss=" + str({k: round(v, 3) for k, v in
                                    r["final_loss"].items()})),
    ("fig5_quartic", fig5_quartic.main,
     lambda r: "T*_lin={linear_formula:.1f} T*_sub={sublinear_formula:.1f}"
               .format(**r["t_star"])),
    ("fig67_nodes", fig67_nodes.main,
     lambda r: "rate(m)=" + str({m: round(v["rate"], 3)
                                 for m, v in r["by_m"].items()})),
    ("comm_reduction", comm_reduction.main,
     lambda r: "reduction=" + str({a: round(v["reduction_factor"], 2)
                                   for a, v in r["archs"].items()})),
    ("roofline_summary", roofline_report.main,
     lambda r: f"pairs={r['pairs']} dominant={r['dominant_counts']}"),
    ("ablation_opt_state", ablation_opt_state.main,
     lambda r: f"adamw final loss avg={r['final_with']:.3f} "
               f"no-avg={r['final_without']:.3f}"),
    ("round_throughput", round_throughput.main,
     lambda r: f"packed vs pytree headline="
               f"{r['headline']['speedup']:.2f}x (bar 1.5x) trace_ratio="
               f"{r['headline_trace']['throughput_ratio']:.2f}"),
    ("comm_bytes", comm_bytes.main,
     lambda r: f"int8 wire reduction="
               f"{r['headline']['int8_reduction_vs_fp32']:.2f}x (bar 3.5x)"
               f" fig2_int8={'ok' if r['fig2']['int8']['pass'] else 'FAIL'}"
               f" hop_bytes="
               f"{r['headline_exchange']['ring_hop_bytes_reduction_G16']:.1f}x"
               " (bar 3x)"),
    ("fault_tolerance", fault_tolerance.main,
     lambda r: f"push_sum@5%drop margin="
               f"{r['headline']['push_sum_gsq_margin']:.1f}x (bar 1) "
               f"sharded={r['headline_sharded']['push_sum_gsq_margin']:.1f}x"
               f" unbias={r['headline']['push_sum_unbias_factor']:.0f}x"
               " (bar 100)"),
    ("tier", tier.main,
     lambda r: f"cross-tier wire reduction="
               f"{r['headline']['cross_tier_wire_reduction']:.2f}x "
               "(bar 3.5) unbias="
               f"{r['headline']['tier_unbias_factor']:.0f}x (bar 1e4) "
               f"sharded margin="
               f"{r['headline_sharded']['tier_gsq_margin']:.1f}x"
               " rejoin="
               + ("ok" if r["rejoin"]["rejoin_exact"] else "FAIL")),
    ("overlap", overlap.main,
     lambda r: "overlap modeled speedup T=4="
               f"{r['headline']['modeled_speedup_T4']:.2f}x (bar 1.15) "
               "online-T wire ratio={:.2f}x (bar 1)".format(
                   r["headline_online_t"]
                   ["wire_ratio_static_over_online"])),
    ("serve_latency", serve_latency.main,
     lambda r: "continuous/static tok/s="
               f"{r['headline']['tokens_per_s_ratio']:.2f}x (bar 1.1) "
               "p99 ratio="
               f"{r['headline']['p99_ratio_static_over_continuous']:.2f}x"
               " (bar 1.3) parity="
               + ("ok" if r["token_parity_static_vs_continuous"]
                  else "FAIL")),
]


# committed perf-trajectory artifacts: (section, value_key, bar_key)
# pairs the --check gate compares. The bar rides IN the artifact, so a
# regenerated artifact carries its own acceptance threshold.
HEADLINE_BARS = {
    "BENCH_round_throughput.json": [
        ("headline", "speedup", "bar"),
        # per-round telemetry must be ~free (ISSUE 7): tracing keeps
        # >= 95% of the bare headline round throughput
        ("headline_trace", "throughput_ratio", "bar"),
    ],
    "BENCH_comm_bytes.json": [
        ("headline", "int8_reduction_vs_fp32", "bar"),
        ("headline_moments",
         "int8_moments_reduction_vs_fp32_moments", "bar"),
        ("headline_exchange", "ring_hop_bytes_reduction_G16", "bar"),
    ],
    "BENCH_fault.json": [
        ("headline", "push_sum_gsq_margin", "bar"),
        ("headline", "push_sum_unbias_factor", "unbias_bar"),
        ("headline_sharded", "push_sum_gsq_margin", "bar"),
    ],
    "BENCH_tier.json": [
        ("headline", "cross_tier_wire_reduction", "wire_bar"),
        ("headline", "tier_unbias_factor", "unbias_bar"),
        ("headline", "tier_gsq_margin", "bar"),
        ("headline_sharded", "tier_gsq_margin", "bar"),
    ],
    "BENCH_overlap.json": [
        ("headline", "modeled_speedup_T4", "bar"),
        ("headline_online_t", "wire_ratio_static_over_online", "bar"),
    ],
    "BENCH_serve.json": [
        ("headline", "tokens_per_s_ratio", "bar"),
        ("headline", "p99_ratio_static_over_continuous", "p99_bar"),
    ],
}

# fresh smoke re-runs: (name, script, env toggles). Each script exits
# nonzero when its (proportionally relaxed) smoke bars fail.
SMOKE_RUNS = [
    ("round_throughput", "benchmarks/round_throughput.py",
     {"ROUND_THROUGHPUT_SMOKE": "1"}),
    ("comm_bytes", "benchmarks/comm_bytes.py",
     {"COMM_BYTES_SMOKE": "1"}),
    ("fault_tolerance", "benchmarks/fault_tolerance.py",
     {"FAULT_SMOKE": "1"}),
    ("tier", "benchmarks/tier.py", {"TIER_SMOKE": "1"}),
    ("overlap", "benchmarks/overlap.py", {"OVERLAP_SMOKE": "1"}),
    ("serve_latency", "benchmarks/serve_latency.py", {"SERVE_SMOKE": "1"}),
]


def check() -> int:
    """The regression gate: committed artifacts meet their own bars AND
    fresh smoke runs still pass. Returns the number of failures."""
    from benchmarks.common import child_env

    failures = 0
    print("== committed artifacts vs their bars ==")
    for fname, pairs in HEADLINE_BARS.items():
        path = REPO_ROOT / fname
        if not path.exists():
            print(f"  MISSING {fname}")
            failures += 1
            continue
        art = json.loads(path.read_text())
        ok = bool(art.get("pass"))
        rows = []
        for section, vkey, bkey in pairs:
            sec = art.get(section, {})
            val, bar = sec.get(vkey), sec.get(bkey)
            if val is None or bar is None:
                rows.append(f"{section}.{vkey}: MISSING")
                ok = False
                continue
            meets = float(val) >= float(bar)
            ok = ok and meets
            rows.append(f"{section}.{vkey}={float(val):.2f} "
                        f"(bar {float(bar)}) "
                        f"{'ok' if meets else 'REGRESSED'}")
        print(f"  {'PASS' if ok else 'FAIL'} {fname}: " + "; ".join(rows))
        if not ok:
            failures += 1
    print("== fresh smoke runs ==")
    for name, script, env_extra in SMOKE_RUNS:
        env = child_env()
        env.update(env_extra)
        t0 = time.time()
        r = subprocess.run([sys.executable, str(REPO_ROOT / script)],
                           env=env, capture_output=True, text=True,
                           timeout=3600, cwd=str(REPO_ROOT))
        dt = time.time() - t0
        if r.returncode != 0:
            failures += 1
            tail = ((r.stdout or "") + (r.stderr or ""))[-1500:]
            print(f"  FAIL {name} ({dt:.0f}s)\n{tail}")
        else:
            print(f"  PASS {name} ({dt:.0f}s)")
    if failures:
        print(f"# {failures} perf-regression check(s) failed")
    else:
        print("# committed perf claims hold and smoke numbers reproduce")
    return failures


def main() -> None:
    if "--check" in sys.argv:
        sys.exit(1 if check() else 0)
    from benchmarks.common import bench_trace

    print("name,seconds,derived")
    failures = []
    # every bench cell lands in the shared JSONL sink too, so the driver
    # and --trace runs report through one schema (DESIGN.md §13)
    tr = bench_trace("run")
    for name, fn, fmt in BENCHES:
        t0 = time.time()
        try:
            with tr.phase(name):
                r = fn()
            dt = tr.take_phases().get(name, time.time() - t0)
            status = "PASS" if r.get("pass") else "CHECK"
            tr.emit("bench", name=name, seconds=round(dt, 3),
                    status=status)
            print(f"{name},{dt:.1f},{status} {fmt(r)}", flush=True)
            if not r.get("pass"):
                failures.append(name)
        except Exception as e:  # pragma: no cover
            dt = time.time() - t0
            tr.emit("bench", name=name, seconds=round(dt, 3),
                    status="ERROR")
            print(f"{name},{dt:.1f},ERROR {type(e).__name__}: {e}",
                  flush=True)
            failures.append(name)
    tr.close()
    if failures:
        print(f"# {len(failures)} benchmark(s) flagged: {failures}")
        sys.exit(1)
    print("# all benchmarks reproduce the paper's claims")


if __name__ == "__main__":
    main()
