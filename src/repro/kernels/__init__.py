# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax as _jax
import jax.numpy as _jnp


def use_interpret() -> bool:
    """Single decision point for kernel dispatch: Pallas interpret mode
    everywhere except a real TPU backend (compiled VMEM kernels)."""
    return _jax.default_backend() != "tpu"


def pallas_supported() -> bool:
    """Backends the Pallas kernels actually execute on: compiled VMEM
    kernels on TPU, interpret-mode (kernel-body validation) on CPU. Other
    backends (e.g. an untested GPU lowering) must REFUSE an explicit
    impl="pallas" rather than silently running something else."""
    return _jax.default_backend() in ("tpu", "cpu")


def resolve_impl(impl: str) -> str:
    """Shared impl="auto" resolution for everything that fronts a Pallas
    kernel with a jnp fallback (packed optimizers, comm codecs): "jnp"
    everywhere except a real TPU backend. An EXPLICIT impl="pallas" on a
    backend the kernels don't support raises instead of silently falling
    back to jnp — callers asked for the kernels, not an approximation."""
    if impl == "auto":
        return "jnp" if use_interpret() else "pallas"
    if impl not in ("pallas", "jnp"):
        raise ValueError(
            f"unknown impl {impl!r} (have 'auto', 'jnp', 'pallas')")
    if impl == "pallas" and not pallas_supported():
        raise NotImplementedError(
            f"impl='pallas' requested on backend "
            f"{_jax.default_backend()!r}: the fused/quantize kernels "
            "compile on TPU and run in interpret mode on CPU only — pass "
            "impl='jnp' (same math, one XLA fusion) or impl='auto'")
    return impl


def pad_to_block(block: int, *xs):
    """Shared 1-D blocking prep for the flat-buffer kernels: clamp the
    block to n, zero-pad every array to a block multiple.

    Returns (block, grid, padded_arrays, n) — slice outputs back to n."""
    n = xs[0].shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        xs = tuple(_jnp.pad(x, (0, pad)) for x in xs)
    return block, (xs[0].shape[0] // block,), xs, n
