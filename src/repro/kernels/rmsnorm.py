"""Fused RMSNorm Pallas kernel: one pass over rows in VMEM blocks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                 # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = 128,
            interpret: bool = True):
    """x (..., D), w (D,). Rows are tiled into VMEM blocks of block_rows."""
    shape = x.shape
    D = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xf = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    # pad rows to a block multiple
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
