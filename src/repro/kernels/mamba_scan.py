"""Pallas kernel for the Mamba2 SSD *intra-chunk* computation.

Grid (B, H, c): each program handles one (batch, head, chunk) tile entirely
in VMEM — cumulative decays, the masked (L,L) intra-chunk matmul chain and
the per-chunk summarized state. The cheap inter-chunk linear recurrence
stays in jnp (`lax.scan`) — it is O(S/L) tiny state updates, not a
hot spot. MXU-aligned shapes: L=chunk (128), N=state (64), P=headdim (64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xh_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, st_ref, dec_ref,
            cum_ref, *, L):
    h = pl.program_id(1)
    xh = xh_ref[0, :, 0].astype(jnp.float32)      # (L,P)
    bm = b_ref[0].astype(jnp.float32)             # (L,N)
    cm = c_ref[0].astype(jnp.float32)             # (L,N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (L,)
    a = a_ref[0]                                  # scalar for this head

    da = dt * a                                   # (L,)
    cum = jnp.cumsum(da)                          # (L,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    w = jnp.where(rows >= cols,
                  jnp.exp(cum[:, None] - cum[None, :]) * dt[None, :], 0.0)
    cb = cm @ bm.T                                # (L,L)
    y = (cb * w) @ xh                             # (L,P)

    last = cum[L - 1]
    w_state = jnp.exp(last - cum) * dt            # (L,)
    st = (bm * w_state[:, None]).T @ xh           # (N,P)

    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0] = st
    dec_ref[0, 0] = jnp.exp(last)
    cum_ref[0, :, 0] = cum


def mamba_chunk(xh, bmat, cmat, dt, a, *, interpret: bool = True):
    """Intra-chunk SSD for all chunks.

    xh (B,c,L,H,P), bmat (B,c,L,N), cmat (B,c,L,N), dt (B,c,L,H), a (H,).
    Returns (y_intra (B,c,L,H,P), states (B,c,H,N,P), chunk_decay (B,c,H),
             cum (B,c,L,H)).
    """
    B, c, L, H, P = xh.shape
    N = bmat.shape[-1]
    # layout with (b*c) leading, heads as a grid dim
    xh_r = xh.reshape(B * c, L, H, P)
    b_r = bmat.reshape(B * c, L, N)
    c_r = cmat.reshape(B * c, L, N)
    dt_r = dt.reshape(B * c, L, H)

    y, st, dec, cum = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(B * c, H),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, L, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, L, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
            pl.BlockSpec((1, L, 1), lambda b, h: (b, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * c, L, H, P), xh.dtype),
            jax.ShapeDtypeStruct((B * c, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B * c, H), jnp.float32),
            jax.ShapeDtypeStruct((B * c, L, H), jnp.float32),
        ],
        interpret=interpret,
    )(xh_r, b_r, c_r, dt_r, a)
    return (y.reshape(B, c, L, H, P), st.reshape(B, c, H, N, P),
            dec.reshape(B, c, H), cum.reshape(B, c, L, H))
