"""Int8 per-chunk quantize/dequantize Pallas kernels (comm codecs).

The communication subsystem (repro.comm, DESIGN.md §8) compresses the
packed (G, N) model buffer before exchange. The int8 codec quantizes each
``chunk``-element slice with its own fp32 scale ``max|x| / 127`` and
unbiased stochastic rounding; the wire payload is 1 byte/element plus one
scale per chunk (~3.9x under fp32 at chunk=256).

Layout contract: callers reshape the flat buffer to ``(rows, chunk)``
(``optim.packing.chunk_rows``) — one grid row per chunk, so the scale
reduction, the rounding, and the cast are a single VMEM pass per chunk.
Stochastic-rounding noise ``u`` (uniform [0,1)) is generated OUTSIDE with
``jax.random`` and passed in: the kernel stays deterministic given its
inputs, and the jnp reference path (codecs.py) consumes the same bits so
the two impls agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, u_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    # unbiased stochastic rounding: E[floor(v + u)] = v for u ~ U[0,1)
    q = jnp.floor(x / scale + u_ref[...].astype(jnp.float32))
    q_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)


def quantize_int8(x, u, *, interpret: bool = True):
    """(rows, chunk) f32 + uniform noise -> (q int8 (rows, chunk),
    scales f32 (rows, 1)); one scale per row."""
    rows, chunk = x.shape
    return pl.pallas_call(
        _quantize_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((rows, chunk), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x, u)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def dequantize_int8(q, scales, *, interpret: bool = True):
    """(rows, chunk) int8 + (rows, 1) scales -> (rows, chunk) f32."""
    rows, chunk = q.shape
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, chunk), jnp.float32),
        interpret=interpret,
    )(q, scales)
