"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal=True):
    """q,k,v (B,H,S,hd) -> (B,H,S,hd). Plain softmax attention."""
    S = q.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(q.shape[-1])
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


def rmsnorm_ref(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def adamw_ref(p, g, m, v, *, count, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """One fused AdamW step on flat arrays; count is the post-increment step."""
    c = jnp.asarray(count, jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c
    g = g.astype(jnp.float32)
    m_ = b1 * m + (1 - b1) * g
    v_ = b2 * v + (1 - b2) * jnp.square(g)
    upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
    new_p = p - lr * (upd + wd * p)
    return new_p.astype(p.dtype), m_, v_


def sgd_ref(p, g, *, lr):
    """One SGD step on flat arrays."""
    return (p - lr * g.astype(jnp.float32)).astype(p.dtype)


def momentum_ref(p, g, mu, *, lr, beta=0.9):
    """One heavy-ball step on flat arrays. Returns (new_p, new_mu)."""
    mu_ = beta * mu + g.astype(jnp.float32)
    return (p - lr * mu_).astype(p.dtype), mu_


def sq_norm_ref(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def mamba_chunk_ref(xh, bmat, cmat, dt, a):
    """Single-chunk SSD oracle.

    xh (L,H,P), bmat (L,N), cmat (L,N), dt (L,H), a (H,) negative.
    Returns (y_intra (L,H,P), state (H,N,P), chunk_decay (H,),
             cum (L,H)) — matching the Pallas kernel outputs.
    """
    L = xh.shape[0]
    da = dt * a                             # (L,H)
    cum = jnp.cumsum(da, axis=0)
    mask = jnp.tril(jnp.ones((L, L), bool))
    w_ij = jnp.where(mask[:, :, None],
                     jnp.exp(cum[:, None, :] - cum[None, :, :]), 0.0)
    w_ij = w_ij * dt[None, :, :]            # (i,j,H)
    cb = cmat @ bmat.T                      # (L,L)
    y = jnp.einsum("lm,lmh,mhp->lhp", cb, w_ij, xh)
    last = cum[-1]                          # (H,)
    w_state = jnp.exp(last[None] - cum) * dt    # (L,H)
    state = jnp.einsum("ln,lh,lhp->hnp", bmat, w_state, xh)
    return y, state, jnp.exp(last), cum
