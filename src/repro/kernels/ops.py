"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend — the
same call sites work on this CPU container (interpret mode validates the
kernel bodies) and on the production mesh (compiled VMEM kernels).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adamw as _ad
from repro.kernels import mamba_scan as _ms
from repro.kernels import rmsnorm as _rn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                               interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = 128):
    return _rn.rmsnorm(x, w, eps=eps, block_rows=block_rows,
                       interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd"))
def fused_adamw(p, g, m, v, count, lr: float, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0):
    return _ad.fused_adamw(p, g, m, v, count=count, lr=lr, b1=b1, b2=b2,
                           eps=eps, wd=wd, interpret=not _on_tpu())


@jax.jit
def mamba_chunk(xh, bmat, cmat, dt, a):
    return _ms.mamba_chunk(xh, bmat, cmat, dt, a, interpret=not _on_tpu())
