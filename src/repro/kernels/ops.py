"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend — the
same call sites work on this CPU container (interpret mode validates the
kernel bodies) and on the production mesh (compiled VMEM kernels).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adamw as _ad
from repro.kernels import fused_momentum as _mo
from repro.kernels import fused_sgd as _sg
from repro.kernels import mamba_scan as _ms
from repro.kernels import quantize as _qz
from repro.kernels import rmsnorm as _rn
from repro.kernels import sq_norm as _sq
from repro.kernels import use_interpret


@partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                               interpret=use_interpret())


@partial(jax.jit, static_argnames=("page_size", "n_kv"))
def paged_decode_attention(q, pool, rows_k, rows_v, lengths,
                           page_size: int, n_kv: int):
    return _da.paged_decode_attention(q, pool, rows_k, rows_v, lengths,
                                      page_size=page_size, n_kv=n_kv,
                                      interpret=use_interpret())


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = 128):
    return _rn.rmsnorm(x, w, eps=eps, block_rows=block_rows,
                       interpret=use_interpret())


# The optimizer-update wrappers donate their state operands (p, and the
# moment buffers) so direct callers update in place instead of
# double-buffering. CAUTION: donation means callers must not reuse a
# donated input after the call, nor pass the same array as a donated and
# non-donated argument (e.g. fused_adamw(p, p, ...)). NOTE: the packed
# training round does NOT go through these wrappers (it calls the kernel
# modules inside its own jit and gets in-place updates from the outer
# jit's donate_argnums in launch/); these are the public single-update
# entry points.


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd"),
         donate_argnums=(0, 2, 3))
def fused_adamw(p, g, m, v, count, lr: float, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0):
    return _ad.fused_adamw(p, g, m, v, count=count, lr=lr, b1=b1, b2=b2,
                           eps=eps, wd=wd, interpret=use_interpret())


@partial(jax.jit, static_argnames=("lr",), donate_argnums=(0,))
def fused_sgd(p, g, lr: float):
    return _sg.fused_sgd(p, g, lr=lr, interpret=use_interpret())


@partial(jax.jit, static_argnames=("lr", "beta"), donate_argnums=(0, 2))
def fused_momentum(p, g, mu, lr: float, beta: float = 0.9):
    return _mo.fused_momentum(p, g, mu, lr=lr, beta=beta,
                              interpret=use_interpret())


@jax.jit
def sq_norm(x):
    return _sq.sq_norm(x, interpret=use_interpret())


@jax.jit
def sq_norm_groups(x):
    return _sq.sq_norm_groups(x, interpret=use_interpret())


@jax.jit
def mamba_chunk(xh, bmat, cmat, dt, a):
    return _ms.mamba_chunk(xh, bmat, cmat, dt, a, interpret=use_interpret())


# Comm-codec kernels (repro.comm, DESIGN.md §8): per-chunk int8
# quantize/dequantize of the packed model buffer before exchange.


@jax.jit
def quantize_int8(x, u):
    return _qz.quantize_int8(x, u, interpret=use_interpret())


@jax.jit
def dequantize_int8(q, scales):
    return _qz.dequantize_int8(q, scales, interpret=use_interpret())
