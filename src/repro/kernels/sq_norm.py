"""Fused squared-L2-norm reduction Pallas kernel.

``grad_sq_norm`` is evaluated every local step (it drives the paper's
threshold mode and the Sec-4 adaptive-T controller). On a pytree that
materializes one partial sum per leaf; on the packed flat buffer it is a
single blocked reduction — the accumulator lives in a (1, 1) output block
and the sequential TPU grid accumulates into it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def sq_norm(x, *, block: int = 65536, interpret: bool = True) -> jax.Array:
    """Sum of squares of a flat 1-D array -> f32 scalar."""
    return sq_norm_groups(x[None], block=block, interpret=interpret)[0]


def _kernel_groups(x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(x * x)


def sq_norm_groups(x, *, block: int = 65536,
                   interpret: bool = True) -> jax.Array:
    """Per-group sum of squares of a (G, N) array -> (G,) f32."""
    g, n = x.shape
    block = min(block, n)
    pad = (-n) % block
    xx = x if not pad else jnp.pad(x, ((0, 0), (0, pad)))  # zeros: sum ok

    out = pl.pallas_call(
        _kernel_groups,
        grid=(g, xx.shape[1] // block),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 1), jnp.float32),
        interpret=interpret,
    )(xx)
    return out[:, 0]
