"""Pallas TPU flash attention (causal, online softmax).

Grid (B*H, nq, nk) with the KV block index innermost (sequential on TPU), so
VMEM scratch (m, l, acc) persists across kv steps of the same q block — the
canonical TPU flash schedule. BlockSpec tiles q/k/v into (block, head_dim)
VMEM blocks; the causal structure is exploited with ``pl.when`` (blocks
strictly above the diagonal do no work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, block_q, block_k, scale, nk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip kv blocks fully above the diagonal (block sizes may differ)
    @pl.when(kj * block_k < (qi + 1) * block_q)
    def _work():
        q = q_ref[0].astype(jnp.float32)       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)       # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * scale                  # (bq, bk)

        # intra-diagonal-block causal mask
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q,k,v (B,H,S,hd) -> (B,H,S,hd), causal. H == KV heads (pre-repeated).

    interpret=True runs the kernel body on CPU (this container); on TPU pass
    interpret=False for the compiled VMEM-tiled kernel.
    """
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    bh = B * H
    qr = q.reshape(bh, S, hd)
    kr = k.reshape(bh, S, hd)
    vr = v.reshape(bh, S, hd)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k,
        scale=1.0 / (hd ** 0.5), nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)
