"""Fused codec-mix exchange epilogue kernels (DESIGN.md §11).

Once T is large the exchange phase IS the hot path (the paper prices
communication rounds as the scarce resource), yet the staged lossy
exchange makes 3-4 separate full-buffer passes per round: encode the
delta, decode it, mix over G, and (error-feedback codecs) update the
residual. These kernels collapse that chain into ONE pass over the flat
(G, N) buffer:

  ``codec_mix``     the whole replicated epilogue — encode + decode +
                    mean/W-row mixing (+ per-hop recompression for
                    ring/gossip, + EF residual update for the threshold
                    codec) — one Pallas grid over chunk-aligned column
                    blocks, every hop's work done while the block is in
                    VMEM.
  ``qdq_int8``      fused quantize+dequantize on (rows, chunk) — the
                    shard_map exchange's per-shard codec step (the mixing
                    there is a real collective between devices, so only
                    the codec fuses; previously two pallas_calls).

Kinds: ``int8`` (per-chunk scale + stochastic rounding, noise passed in
— same contract as kernels/quantize.py), ``bf16``/``fp16`` (cast),
``thresh`` (threshold selection with an error-feedback residual — the
element-wise part of top-k once the per-group threshold is known;
mean-mixing only).

Numerics contract: ``codec_mix(..., impl="jnp")`` is the STAGED
reference arranged as one function — the exact op sequence of
``comm.Exchange``'s staged path — and the Pallas kernel is bit-identical
to it (tests/test_exchange_engine.py): the per-block math is the same
jnp ops on the same shapes, the G-mean and the (G,G)x(G,B) W contraction
reduce in the same order per element.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KINDS = ("int8", "bf16", "fp16", "thresh")

# column block of the codec_mix grid: a multiple of every codec chunk in
# use keeps per-chunk scales block-local (the int8 chunk is 256)
BLOCK_COLS = 2048


def _encode_decode(kind: str, d, u, chunk: int):
    """The codec's quantize+dequantize on a (G, B) delta block — the same
    element-wise math as the staged codecs (comm/codecs.py), so slicing
    columns before or after commutes bit-for-bit."""
    if kind in ("bf16", "fp16"):
        dt = jnp.bfloat16 if kind == "bf16" else jnp.float16
        return d.astype(dt).astype(d.dtype)
    assert kind == "int8", kind
    g = d.shape[0]
    rows = d.reshape(g, -1, chunk)
    amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.floor(rows / scale + u.reshape(rows.shape)),
                 -127.0, 127.0).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).reshape(d.shape)


def _mix_block(y, w):
    """One mixing application on a (G, B) block: exact mean+broadcast
    (w None — the server ops, bit-exact with ``average_groups``) or the
    W-row contraction (ring/gossip)."""
    if w is None:
        m = jnp.mean(y, axis=0, keepdims=True)
        return jnp.broadcast_to(m, y.shape)
    return jnp.tensordot(w, y, axes=[[1], [0]])


def _epilogue_block(kind, hops, chunk, x, x0, u, w, res, tau):
    """The whole fused epilogue on a (G, B) column block. Returns
    (mixed, residual_out) — residual_out is None except for ``thresh``."""
    if kind == "thresh":
        c = (x - x0) + res
        keep = (jnp.abs(c) >= tau) & (jnp.abs(c) > 0.0)
        d_hat = jnp.where(keep, c, 0.0)
        return _mix_block(x0 + d_hat, w), c - d_hat
    y, ref = x, x0
    for h in range(hops):
        d_hat = _encode_decode(kind, y - ref,
                               None if u is None else u[h], chunk)
        ref = ref + d_hat
        y = _mix_block(ref, w)
        if w is None:
            break  # mean mode: one compress + one exact mean
        # ring/gossip recompress per hop vs the transmitted payload (§8)
    return y, None


def codec_mix_ref(x, x0, *, kind: str, u=None, w=None, hops: int = 1,
                  chunk: int = 0, residual=None, tau=None
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Staged-op reference of the fused epilogue on the full (G, N)
    buffer. ``u``: (hops, G, N/chunk, chunk) stochastic-rounding noise
    (int8); ``tau``: (G, 1) per-group selection threshold (thresh);
    ``residual``: (G, N) error-feedback carry (thresh)."""
    assert kind in KINDS, kind
    w = None if w is None else jnp.asarray(w, jnp.float32)
    return _epilogue_block(kind, hops, chunk, x, x0, u, w, residual, tau)


def codec_mix(x, x0, *, kind: str, u=None, w=None, hops: int = 1,
              chunk: int = 0, residual=None, tau=None,
              impl: str = "jnp", interpret: bool = True,
              block_cols: int = BLOCK_COLS
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Fused codec+mix epilogue over a (G, N) flat buffer.

    impl="jnp" runs the staged reference in one XLA fusion; "pallas"
    runs the single-pass kernel (bit-identical — same block math). The
    column axis is zero-padded to a block multiple; zero columns are a
    fixed point of every kind (zero chunks quantize to zero, thresh
    never selects |c| = 0), so the pad never leaks and outputs slice
    back to N.
    """
    assert kind in KINDS, kind
    if kind == "thresh":
        assert w is None, "thresh fuses mean mixing only (DESIGN.md §11)"
        assert residual is not None and tau is not None
    if kind == "int8":
        assert u is not None and chunk > 0
    if impl == "jnp":
        # mirror chunk_rows: zero-pad the column axis to a chunk multiple
        # (the staged codec sees the same tail zeros — bit-identical)
        n = x.shape[-1]
        cpad = (-n) % chunk if chunk else 0
        if cpad:
            def pc(a):
                return jnp.pad(a, ((0, 0), (0, cpad)))

            x, x0 = pc(x), pc(x0)
            residual = None if residual is None else pc(residual)
        mixed, res_out = codec_mix_ref(x, x0, kind=kind, u=u, w=w,
                                       hops=hops, chunk=chunk,
                                       residual=residual, tau=tau)
        if cpad:
            mixed = mixed[:, :n]
            res_out = None if res_out is None else res_out[:, :n]
        return mixed, res_out

    g, n = x.shape
    bc = max(chunk, 1) * max(1, block_cols // max(chunk, 1))
    bc = min(bc, ((n + max(chunk, 1) - 1) // max(chunk, 1))
             * max(chunk, 1))
    pad = (-n) % bc
    padded = n + pad

    def padcols(a):
        return jnp.pad(a, ((0, 0), (0, pad))) if pad else a

    xs, x0s = padcols(x), padcols(x0)
    mean = w is None
    n_hops = 1 if (mean and kind != "thresh") else hops
    grid = (padded // bc,)
    in_specs = [pl.BlockSpec((g, bc), lambda i: (0, i)),
                pl.BlockSpec((g, bc), lambda i: (0, i))]
    args = [xs, x0s]
    if kind == "int8":
        # noise at the STAGED rows shape (G·N/chunk, chunk) keeps bits
        # identical; pad rows get fresh zeros (any noise quantizes a zero
        # chunk to zero — the value never reaches the real columns)
        u3 = u.reshape(n_hops, g, -1, chunk)
        if pad:
            u3 = jnp.pad(u3, ((0, 0), (0, 0), (0, pad // chunk), (0, 0)))
        args.append(u3)
        in_specs.append(pl.BlockSpec((n_hops, g, bc // chunk, chunk),
                                     lambda i: (0, 0, i, 0)))
    if not mean:
        args.append(jnp.asarray(w, jnp.float32))
        in_specs.append(pl.BlockSpec((g, g), lambda i: (0, 0)))
    if kind == "thresh":
        args += [padcols(residual), jnp.asarray(tau, jnp.float32)]
        in_specs += [pl.BlockSpec((g, bc), lambda i: (0, i)),
                     pl.BlockSpec((g, 1), lambda i: (0, 0))]

    ef = kind == "thresh"
    out_specs = pl.BlockSpec((g, bc), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((g, padded), jnp.float32)
    if ef:
        out_specs = (out_specs, pl.BlockSpec((g, bc), lambda i: (0, i)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((g, padded), jnp.float32))

    def kernel(*refs):
        it = iter(refs)
        x_b, x0_b = next(it)[...], next(it)[...]
        u_b = next(it)[...] if kind == "int8" else None
        w_b = None if mean else next(it)[...]
        res_b = next(it)[...] if ef else None
        tau_b = next(it)[...] if ef else None
        outs = list(it)
        mixed, res_out = _epilogue_block(kind, n_hops, chunk, x_b, x0_b,
                                         u_b, w_b, res_b, tau_b)
        outs[0][...] = mixed
        if ef:
            outs[1][...] = res_out

    out = pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*args)
    if ef:
        mixed, res_out = out
        return mixed[:, :n], res_out[:, :n]
    return out[:, :n], None


# ---------------------------------------------------------------------------
# shard-local fused quantize+dequantize (the shard_map exchange's codec)
# ---------------------------------------------------------------------------


def _qdq_kernel(x_ref, u_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.floor(x / scale + u_ref[...].astype(jnp.float32)),
                 -127.0, 127.0).astype(jnp.int8)
    o_ref[...] = q.astype(jnp.float32) * scale


def qdq_int8(x, u, *, interpret: bool = True):
    """(rows, chunk) f32 + uniform noise -> decoded (rows, chunk) f32 in
    ONE VMEM pass (the staged pair kernels/quantize.py quantize_int8 +
    dequantize_int8 re-reads every row; same math, bit-identical)."""
    rows, chunk = x.shape
    return pl.pallas_call(
        _qdq_kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, chunk), lambda i: (i, 0)),
                  pl.BlockSpec((1, chunk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, chunk), jnp.float32),
        interpret=interpret,
    )(x, u)
