"""Fused SGD update Pallas kernel.

The paper's local GD inner loop (T steps per communication) is the hot
path; on the packed flat buffer (optim.packing) the whole parameter update
is one VMEM pass: read p and g, write p - lr*g.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pad_to_block


def _kernel(p_ref, g_ref, po_ref, *, lr):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    po_ref[...] = (p - lr * g).astype(po_ref.dtype)


def fused_sgd(p, g, *, lr, block: int = 65536, interpret: bool = True):
    """Flat 1-D arrays p, g. Returns new_p."""
    block, grid, (pp, gg), n = pad_to_block(block, p, g)

    new_p = pl.pallas_call(
        functools.partial(_kernel, lr=lr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, p.dtype),
        interpret=interpret,
    )(pp, gg)
    return new_p[:n] if new_p.shape[0] != n else new_p
