"""Fused heavy-ball momentum update Pallas kernel.

One VMEM pass over the packed flat buffer (optim.packing) per local step:
mu <- beta*mu + g; p <- p - lr*mu, with both outputs written from the same
block read — instead of one HLO fusion chain per pytree leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pad_to_block


def _kernel(p_ref, g_ref, mu_ref, po_ref, muo_ref, *, lr, beta):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mu = mu_ref[...]
    mu_new = beta * mu + g
    po_ref[...] = (p - lr * mu_new).astype(po_ref.dtype)
    muo_ref[...] = mu_new


def fused_momentum(p, g, mu, *, lr, beta=0.9, block: int = 65536,
                   interpret: bool = True):
    """Flat 1-D arrays p, g, mu. Returns (new_p, new_mu)."""
    block, grid, (pp, gg, mm), n = pad_to_block(block, p, g, mu)

    new_p, new_mu = pl.pallas_call(
        functools.partial(_kernel, lr=lr, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, p.dtype),
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(pp, gg, mm)
    if new_p.shape[0] != n:
        new_p, new_mu = new_p[:n], new_mu[:n]
    return new_p, new_mu
