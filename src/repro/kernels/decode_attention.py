"""Pallas TPU paged decode attention (single-token q, GQA, online softmax
over KV pages).

The serve engine (repro.serve) stores the KV cache as PAGES: rows of one
flat f32 pool ``(n_pages, page_elems)``, where a page holds ``page_size``
tokens x ``n_kv`` heads x ``head_dim`` (plus chunk-alignment padding).
Per-slot page tables map block j of request b to pool rows
``rows_k[b, j]`` / ``rows_v[b, j]``. This kernel computes one decode
step's attention for the whole batch directly against those pages.

Schedule: grid ``(B, nblk)`` with the page index innermost (sequential on
TPU), VMEM scratch (m, l, acc) carrying the online softmax across pages —
the decode-shaped sibling of ``flash_attention`` (same scratch dance,
q-block = one token). The page tables and lengths ride
``PrefetchScalarGridSpec`` scalar prefetch, so the BlockSpec index_map
DMAs exactly the page each grid step owns: block j of batch b streams
pool row ``rows_k[b, j]`` into VMEM — gathers never materialize.

Bit-identity contract: ``paged_decode_attention`` in interpret mode and
``paged_decode_attention_ref`` agree BIT-FOR-BIT (the parity tests assert
exact equality), which takes three deliberate choices shared via
``_cell_update``:

1. Every float sum (scores, p@v, sum(p)) goes through
   ``lax.dot_general`` — a library call XLA cannot re-associate. A plain
   ``jnp.sum`` is re-tiled per fusion context: the same reduction
   compiled inside the pallas grid body vs. inside a ``lax.scan`` body
   rounds differently (~1 ulp, data-dependent), and
   ``optimization_barrier`` does not stop it.
2. The online-softmax accumulates ``l*corr + sum(p)`` and
   ``acc*corr + pv`` add through ``_pair_add`` (stack the two addends,
   contract with ones(2)) so neither program can FMA-contract the
   multiply into the add.
3. The reference runs per batch row (``lax.map``) with exactly the
   kernel's cell shapes, and mirrors the kernel's past-length block skip
   with a ``where`` on the scan carry — processing a fully-masked block
   is NOT bit-transparent, so the ref must skip precisely the blocks the
   kernel's ``pl.when`` skips.

The contract is validated in interpret mode (the only mode this
container can run); on real TPU hardware the compiled kernel's rounding
is hardware-specific and only the allclose tests apply.

Inactive slots are routed to the reserved trash page (row 0) with
length 1 by the engine: they compute finite garbage that never crosses
slots (every op here is batch-elementwise over b).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pair_add(a, b):
    """``a + b`` with the add forced through dot_general so it cannot be
    FMA-contracted with whatever produced ``a`` or ``b``."""
    t = jnp.stack([a, b], axis=-1)
    return jax.lax.dot_general(
        t, jnp.ones((2,), jnp.float32), (((t.ndim - 1,), (0,)), ((), ())))


def _cell_update(q, k, v, cols, length, m_prev, l_prev, acc, scale):
    """One page of online softmax on kernel-cell shapes: q (KV, G, hd),
    k/v (page, KV, hd) token-major, cols (page,) absolute positions,
    length scalar. Returns updated (m, l, acc). Shared verbatim by the
    kernel body and the reference — see the module docstring for why
    every reduction is a dot_general."""
    kt = jnp.moveaxis(k, 0, 1)                     # (KV, page, hd)
    vt = jnp.moveaxis(v, 0, 1)
    s = jax.lax.dot_general(                       # (KV, G, page)
        q, kt, (((2,), (2,)), ((0,), (0,)))) * scale
    s = jnp.where((cols < length)[None, None, :], s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    pv = jax.lax.dot_general(                      # (KV, G, hd)
        p, vt, (((2,), (1,)), ((0,), (0,))))
    psum = jax.lax.dot_general(
        p, jnp.ones((p.shape[-1],), jnp.float32), (((2,), (0,)), ((), ())))
    l_new = _pair_add(l_prev * corr, psum)
    acc_new = _pair_add(acc * corr[..., None], pv)
    return m_new, l_new, acc_new


def _kernel(rk_ref, rv_ref, len_ref, q_ref, kp_ref, vp_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page_size, n_kv, g, used, nblk,
            scale):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # pages fully past the request's length are skipped; block 0 is
    # always valid (length >= 1), so m stays finite
    @pl.when(j * page_size < len_ref[b])
    def _work():
        hd = used // (page_size * n_kv)
        q = q_ref[0].astype(jnp.float32).reshape(n_kv, g, hd)
        k = kp_ref[0, :used].reshape(page_size, n_kv, hd)
        v = vp_ref[0, :used].reshape(page_size, n_kv, hd)
        cols = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)[0]
        m, l, acc = _cell_update(q, k, v, cols, len_ref[b], m_scr[...],
                                 l_scr[...], acc_scr[...], scale)
        m_scr[...] = m
        l_scr[...] = l
        acc_scr[...] = acc

    @pl.when(j == nblk - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(n_kv * g, -1).astype(o_ref.dtype)


def paged_decode_attention(q, pool, rows_k, rows_v, lengths, *,
                           page_size: int, n_kv: int,
                           interpret: bool = True):
    """q (B, H, hd); pool (n_pages, page_elems) f32; rows_k/rows_v
    (B, nblk) int32 pool-row tables; lengths (B,) int32 (>= 1).
    Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    assert H % n_kv == 0, (H, n_kv)
    g = H // n_kv
    nblk = rows_k.shape[1]
    used = page_size * n_kv * hd
    assert pool.shape[1] >= used, (pool.shape, used)
    kernel = functools.partial(
        _kernel, page_size=page_size, n_kv=n_kv, g=g, used=used,
        nblk=nblk, scale=1.0 / math.sqrt(hd))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nblk),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, rk, rv, ln: (b, 0, 0)),
            pl.BlockSpec((1, pool.shape[1]),
                         lambda b, j, rk, rv, ln: (rk[b, j], 0)),
            pl.BlockSpec((1, pool.shape[1]),
                         lambda b, j, rk, rv, ln: (rv[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd),
                               lambda b, j, rk, rv, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, g), jnp.float32),
            pltpu.VMEM((n_kv, g), jnp.float32),
            pltpu.VMEM((n_kv, g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(rows_k, rows_v, lengths, q, pool, pool)


def paged_decode_attention_ref(q, pool, rows_k, rows_v, lengths, *,
                               page_size: int, n_kv: int):
    """Pure-jnp reference, bit-identical to the interpret-mode kernel
    (same `_cell_update`, per-row lax.map so cell shapes match, skipped
    blocks masked on the carry — see module docstring). Also the
    impl='jnp' serve path."""
    B, H, hd = q.shape
    g = H // n_kv
    nblk = rows_k.shape[1]
    used = page_size * n_kv * hd
    scale = 1.0 / math.sqrt(hd)

    def one(args):
        qb, rk, rv, ln = args
        qf = qb.astype(jnp.float32).reshape(n_kv, g, hd)

        def step(carry, j):
            m_prev, l_prev, acc = carry
            k = pool[rk[j], :used].reshape(page_size, n_kv, hd)
            v = pool[rv[j], :used].reshape(page_size, n_kv, hd)
            cols = j * page_size + jnp.arange(page_size, dtype=jnp.int32)
            m, l, a = _cell_update(qf, k, v, cols, ln, m_prev, l_prev,
                                   acc, scale)
            valid = j * page_size < ln
            return (jnp.where(valid, m, m_prev),
                    jnp.where(valid, l, l_prev),
                    jnp.where(valid, a, acc)), None

        (m, l, acc), _ = jax.lax.scan(
            step,
            (jnp.full((n_kv, g), NEG_INF, jnp.float32),
             jnp.zeros((n_kv, g), jnp.float32),
             jnp.zeros((n_kv, g, hd), jnp.float32)),
            jnp.arange(nblk, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(H, hd).astype(qb.dtype)

    return jax.lax.map(one, (q, rows_k, rows_v, lengths))
