"""Fused AdamW update Pallas kernel.

The local-GD inner loop is the hot path of the paper's algorithm (T steps
per communication); this kernel fuses the whole element-wise update
(moment updates + bias correction + decay + write-back) into one VMEM pass
with three outputs, instead of the ~10 separate HLO element-wise ops."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pad_to_block


def _kernel(p_ref, g_ref, m_ref, v_ref, bc_ref, po_ref, mo_ref, vo_ref,
            *, lr, b1, b2, eps, wd):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    bc1 = bc_ref[0]
    bc2 = bc_ref[1]
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    po_ref[...] = (p - lr * (upd + wd * p)).astype(po_ref.dtype)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def fused_adamw(p, g, m, v, *, count, lr, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.0, block: int = 65536, interpret: bool = True):
    """Flat 1-D arrays p,g,m,v; count = post-increment step number.
    Returns (new_p, new_m, new_v)."""
    c = jnp.asarray(count, jnp.float32)
    bc = jnp.stack([1.0 - b1 ** c, 1.0 - b2 ** c])
    block, grid, (pp, gg, mm, vv), n = pad_to_block(block, p, g, m, v)

    new_p, new_m, new_v = pl.pallas_call(
        functools.partial(_kernel, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, p.dtype),
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(pp, gg, mm, vv, bc)
    if new_p.shape[0] != n:
        new_p, new_m, new_v = new_p[:n], new_m[:n], new_v[:n]
    return new_p, new_m, new_v
