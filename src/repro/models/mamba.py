"""Mamba2 (SSD) block — TPU-native chunked formulation.

The GPU reference implements SSD with warp-level scans; here the insight is
re-expressed as *chunked* matmuls (MXU-friendly): within a chunk of length L
the state-space kernel is a masked (L, L) matmul, and chunks are linked by a
`lax.scan` over per-chunk summarized states — the standard TPU adaptation
(intra-chunk quadratic + inter-chunk linear recurrence).

Shapes: d_inner = expand * d_model, split into H heads of head dim P=64
(P = d_inner for tiny smoke configs). B/C projections are shared across
heads (n_groups=1), state size N = cfg.ssm_state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import pdef, rms_norm

P_HEADDIM = 64


def mamba_dims(cfg):
    di = cfg.d_inner
    p = min(P_HEADDIM, di)
    h = di // p
    return di, h, p, cfg.ssm_state


def mamba_defs(cfg):
    d = cfg.d_model
    di, h, p, n = mamba_dims(cfg)
    return {
        "w_z": pdef((d, di), ("embed", "inner")),
        "w_x": pdef((d, di), ("embed", "inner")),
        "w_b": pdef((d, n), ("embed", None)),
        "w_c": pdef((d, n), ("embed", None)),
        "w_dt": pdef((d, h), ("embed", None)),
        "dt_bias": pdef((h,), (None,), init="zeros"),
        "a_log": pdef((h,), (None,), init="zeros"),
        "d_skip": pdef((h,), (None,), init="ones"),
        "conv_w": pdef((cfg.d_conv, di), (None, "inner"), scale=0.1),
        "conv_b": pdef((di,), ("inner",), init="zeros"),
        "norm": pdef((di,), ("inner",), init="ones"),
        "w_out": pdef((di, d), ("inner", "embed")),
    }


def _causal_conv(xc, conv_w, conv_b):
    """Depthwise causal conv, kernel K (small): sum of shifted inputs."""
    K = conv_w.shape[0]
    out = xc * conv_w[K - 1]
    for k in range(1, K):
        shifted = jnp.pad(xc, ((0, 0), (k, 0), (0, 0)))[:, : xc.shape[1]]
        out = out + shifted * conv_w[K - 1 - k]
    return out + conv_b


def _ssm_inputs(p, x, cfg):
    di, h, hp, n = mamba_dims(cfg)
    dt_ = x.dtype
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(dt_))
    xc = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(dt_))
    bmat = jnp.einsum("bsd,dn->bsn", x, p["w_b"].astype(dt_)).astype(jnp.float32)
    cmat = jnp.einsum("bsd,dn->bsn", x, p["w_c"].astype(dt_)).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative
    return z, xc, bmat, cmat, dt, a


def mamba_forward(p, x, cfg):
    """x (B,S,D) -> (B,S,D), S divisible by cfg.chunk_size."""
    B, S, D = x.shape
    di, H, P, N = mamba_dims(cfg)
    L = cfg.chunk_size
    assert S % L == 0, (S, L)
    c = S // L

    z, xc, bmat, cmat, dt, a = _ssm_inputs(p, x, cfg)
    xc = jax.nn.silu(_causal_conv(xc, p["conv_w"].astype(xc.dtype),
                                  p["conv_b"].astype(xc.dtype)))
    xh = xc.reshape(B, c, L, H, P).astype(jnp.float32)
    bmat = bmat.reshape(B, c, L, N)
    cmat = cmat.reshape(B, c, L, N)
    dt = dt.reshape(B, c, L, H)
    da = dt * a  # (B,c,L,H) negative
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic in L, masked) -----------------------------
    cb = jnp.einsum("bcln,bcmn->bclm", cmat, bmat)              # (B,c,L,L)
    mask = jnp.tril(jnp.ones((L, L), bool))
    # decay weight of input j on output i (i >= j), axes (B,c,i,j,H)
    w_ij = jnp.where(mask[None, None, :, :, None],
                     jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :]),
                     0.0)                                        # (B,c,i,j,H)
    w_ij = w_ij * dt[:, :, None, :, :]                           # * dt_j
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", cb, w_ij, xh)

    # ---- per-chunk summarized states --------------------------------------
    last = cum[:, :, -1:, :]                                     # (B,c,1,H)
    w_state = jnp.exp(last - cum) * dt                           # (B,c,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchnp", bmat, w_state, xh)
    chunk_decay = jnp.exp(last[:, :, 0])                         # (B,c,H)

    # ---- inter-chunk scan --------------------------------------------------
    def step(s_prev, inp):
        st, dec, cm, cu = inp  # (B,H,N,P), (B,H), (B,L,N), (B,L,H)
        y = jnp.einsum("bln,bhnp->blhp", cm, s_prev) * jnp.exp(cu)[..., None]
        s_next = dec[:, :, None, None] * s_prev + st
        return s_next, y

    s0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
          jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(cum, 1, 0))
    s_final, y_inter = jax.lax.scan(step, s0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)                        # (B,c,L,H,P)

    y = y_intra + y_inter + p["d_skip"][None, None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) state)
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg, batch: int, dtype):
    di, H, P, N = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_cache_shapes(cfg, batch: int, dtype):
    di, H, P, N = mamba_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, di), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, H, N, P), jnp.float32),
    }


def mamba_decode(p, x, cfg, cache):
    """One token: x (B,1,D) -> (y (B,1,D), new cache)."""
    B = x.shape[0]
    di, H, P, N = mamba_dims(cfg)
    z, xc, bmat, cmat, dt, a = _ssm_inputs(p, x, cfg)
    # conv over [state, x_t]
    window = jnp.concatenate([cache["conv"], xc], axis=1)  # (B,K,di)
    conv_w = p["conv_w"].astype(xc.dtype)
    xt = jnp.einsum("bki,ki->bi", window, conv_w) + p["conv_b"].astype(xc.dtype)
    xt = jax.nn.silu(xt)
    new_conv = window[:, 1:]

    xh = xt.reshape(B, H, P).astype(jnp.float32)
    dt1 = dt[:, 0]                                      # (B,H)
    da = jnp.exp(dt1 * a)                               # (B,H)
    s = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", bmat[:, 0], dt1, xh)
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0], s)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": s}
