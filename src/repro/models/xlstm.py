"""xLSTM blocks: mLSTM (matrix memory — chunked gated linear attention,
parallelizable) and sLSTM (scalar memory — sequential `lax.scan`).

TPU adaptation: the mLSTM recurrence  S_t = f_t S_{t-1} + i_t k_t v_t^T,
y_t = (q_t S_t) / max(|q_t n_t|, 1)  is computed chunkwise exactly like the
Mamba2 SSD (intra-chunk masked matmuls + inter-chunk state scan) — the same
MXU-friendly reformulation, since both are gated linear attentions.

Numerics: gates are computed in fp32 with the input gate clipped to
[-8, 8] instead of carrying the full xLSTM max-stabilizer state — a
documented simplification (DESIGN.md) that keeps the chunked form simple
while remaining bounded.  sLSTM uses diagonal recurrent weights (per-channel)
rather than block-diagonal head mixing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import pdef, rms_norm

ICLIP = 8.0


def xlstm_dims(cfg):
    di = cfg.expand * cfg.d_model
    h = cfg.n_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg):
    d = cfg.d_model
    di, h, p = xlstm_dims(cfg)
    return {
        "w_up": pdef((d, 2 * di), ("embed", "inner")),
        "w_q": pdef((di, di), ("inner", None)),
        "w_k": pdef((di, di), ("inner", None)),
        "w_v": pdef((di, di), ("inner", None)),
        "w_if": pdef((d, 2 * h), ("embed", None), scale=0.01),
        "b_if": pdef((2 * h,), (None,), init="zeros"),
        "norm": pdef((di,), ("inner",), init="ones"),
        "w_down": pdef((di, d), ("inner", "embed")),
    }


def _mlstm_qkvg(p, x, cfg):
    di, H, P = xlstm_dims(cfg)
    dt_ = x.dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(dt_))
    a, z = jnp.split(u, 2, axis=-1)
    q = jnp.einsum("bsi,ij->bsj", a, p["w_q"].astype(dt_))
    k = jnp.einsum("bsi,ij->bsj", a, p["w_k"].astype(dt_)) / jnp.sqrt(P).astype(dt_)
    v = jnp.einsum("bsi,ij->bsj", a, p["w_v"].astype(dt_))
    gates = (jnp.einsum("bsd,dg->bsg", x, p["w_if"].astype(dt_))
             .astype(jnp.float32) + p["b_if"])
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)           # (B,S,H)
    log_f = -jax.nn.softplus(-f_raw)                      # log sigmoid, <= 0
    ig = jnp.exp(jnp.clip(i_raw, -ICLIP, ICLIP))          # bounded input gate
    B, S, _ = x.shape
    shp = (B, S, H, P)
    return (q.reshape(shp), k.reshape(shp), v.reshape(shp), log_f, ig, z)


def mlstm_forward(p, x, cfg):
    """x (B,S,D) -> (B,S,D); S divisible by cfg.chunk_size."""
    B, S, D = x.shape
    di, H, P = xlstm_dims(cfg)
    L = cfg.chunk_size
    assert S % L == 0
    c = S // L
    q, k, v, log_f, ig, z = _mlstm_qkvg(p, x, cfg)

    qc = q.reshape(B, c, L, H, P).astype(jnp.float32)
    kc = k.reshape(B, c, L, H, P).astype(jnp.float32)
    vc = v.reshape(B, c, L, H, P).astype(jnp.float32)
    lf = log_f.reshape(B, c, L, H)
    igc = ig.reshape(B, c, L, H)
    cum = jnp.cumsum(lf, axis=2)

    # intra-chunk: weight of step j on step i (i >= j)
    mask = jnp.tril(jnp.ones((L, L), bool))
    w_ij = jnp.where(mask[None, None, :, :, None],
                     jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :]),
                     0.0) * igc[:, :, None, :, :]          # (B,c,i,j,H)
    qk = jnp.einsum("bclhp,bcmhp->bchlm", qc, kc)          # (B,c,H,L,L)
    wt = qk * w_ij.transpose(0, 1, 4, 2, 3)                # (B,c,H,i,j)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", wt, vc)

    # per-chunk summarized state & normalizer
    last = cum[:, :, -1:, :]
    w_st = jnp.exp(last - cum) * igc                        # (B,c,L,H)
    states = jnp.einsum("bclhp,bclh,bclhq->bchpq", kc, w_st, vc)
    nstates = jnp.einsum("bclhp,bclh->bchp", kc, w_st)
    chunk_decay = jnp.exp(last[:, :, 0])                    # (B,c,H)

    def step(carry, inp):
        s_prev, n_prev = carry
        st, nst, dec, qq, cu = inp
        expc = jnp.exp(cu)[..., None]                       # (B,L,H,1)
        y = jnp.einsum("blhp,bhpq->blhq", qq, s_prev) * expc
        n = jnp.einsum("blhp,bhp->blh", qq, n_prev)[..., None] * expc
        s_next = dec[:, :, None, None] * s_prev + st
        n_next = dec[:, :, None] * n_prev + nst
        return (s_next, n_next), (y, n)

    s0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (states, nstates, chunk_decay, qc, cum))
    (_, _), (y_inter, n_inter) = jax.lax.scan(step, (s0, n0), xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)
    n_inter = jnp.moveaxis(n_inter, 0, 1)

    n_intra = jnp.einsum("bchlm->bclh", wt)[..., None]      # sum_j wt
    y = y_intra + y_inter
    n = n_intra + n_inter
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_down"].astype(x.dtype))


def init_mlstm_cache(cfg, batch, dtype):
    di, H, P = xlstm_dims(cfg)
    return {"s": jnp.zeros((batch, H, P, P), jnp.float32),
            "n": jnp.zeros((batch, H, P), jnp.float32)}


def mlstm_cache_shapes(cfg, batch, dtype):
    di, H, P = xlstm_dims(cfg)
    return {"s": jax.ShapeDtypeStruct((batch, H, P, P), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, H, P), jnp.float32)}


def mlstm_decode(p, x, cfg, cache):
    B = x.shape[0]
    di, H, P = xlstm_dims(cfg)
    q, k, v, log_f, ig, z = _mlstm_qkvg(p, x, cfg)
    f = jnp.exp(log_f[:, 0])                                # (B,H)
    i_ = ig[:, 0]
    q1 = q[:, 0].astype(jnp.float32)
    k1 = k[:, 0].astype(jnp.float32)
    v1 = v[:, 0].astype(jnp.float32)
    s = f[:, :, None, None] * cache["s"] + i_[:, :, None, None] * \
        jnp.einsum("bhp,bhq->bhpq", k1, v1)
    n = f[:, :, None] * cache["n"] + i_[:, :, None] * k1
    y = jnp.einsum("bhp,bhpq->bhq", q1, s)
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", q1, n))[..., None]
    y = (y / jnp.maximum(den, 1.0)).reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_down"].astype(x.dtype))
    return out, {"s": s, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg):
    d = cfg.d_model
    di, _, _ = xlstm_dims(cfg)
    return {
        "w_gates": pdef((d, 4 * di), ("embed", "inner"), scale=0.01),
        "b_gates": pdef((4 * di,), ("inner",), init="zeros"),
        "r_gates": pdef((4, di), (None, "inner"), scale=0.01),  # diagonal rec.
        "norm": pdef((di,), ("inner",), init="ones"),
        "w_down": pdef((di, d), ("inner", "embed")),
    }


def _slstm_step(p_r, carry, g):
    """g: pre-activation gates (B,4*di) from the input; p_r: (4,di)."""
    h, cst, n = carry
    di = h.shape[-1]
    gz, gi, gf, go = jnp.split(g, 4, axis=-1)
    gz = gz + h * p_r[0]
    gi = gi + h * p_r[1]
    gf = gf + h * p_r[2]
    go = go + h * p_r[3]
    zt = jnp.tanh(gz)
    it = jnp.exp(jnp.clip(gi, -ICLIP, ICLIP))
    ft = jax.nn.sigmoid(gf)
    ot = jax.nn.sigmoid(go)
    c_new = ft * cst + it * zt
    n_new = ft * n + it
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new)


def slstm_forward(p, x, cfg):
    B, S, D = x.shape
    di, _, _ = xlstm_dims(cfg)
    g = (jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(x.dtype))
         .astype(jnp.float32) + p["b_gates"])
    r = p["r_gates"].astype(jnp.float32)

    def step(carry, gt):
        new = _slstm_step(r, carry, gt)
        return new, new[0]

    h0 = jnp.zeros((B, di), jnp.float32)
    carry0 = (h0, h0, h0)
    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(g, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)             # (B,S,di)
    hs = rms_norm(hs, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", hs, p["w_down"].astype(x.dtype))


def init_slstm_cache(cfg, batch, dtype):
    di, _, _ = xlstm_dims(cfg)
    z = jnp.zeros((batch, di), jnp.float32)
    return {"h": z, "c": z, "n": z}


def slstm_cache_shapes(cfg, batch, dtype):
    di, _, _ = xlstm_dims(cfg)
    sd = jax.ShapeDtypeStruct((batch, di), jnp.float32)
    return {"h": sd, "c": sd, "n": sd}


def slstm_decode(p, x, cfg, cache):
    g = (jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(x.dtype))
         .astype(jnp.float32) + p["b_gates"])[:, 0]
    r = p["r_gates"].astype(jnp.float32)
    h, c, n = _slstm_step(r, (cache["h"], cache["c"], cache["n"]), g)
    hs = rms_norm(h[:, None].astype(x.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", hs, p["w_down"].astype(x.dtype))
    return out, {"h": h, "c": c, "n": n}
