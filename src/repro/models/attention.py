"""GQA attention: training/prefill (blocked causal, online softmax) and
decode (full KV cache or sliding-window ring buffer).

Variants supported per ArchConfig: qkv bias (qwen1.5, whisper, internvl2),
qk-norm (qwen3), explicit head_dim (qwen3), non-causal self attention
(whisper encoder), cross attention (whisper decoder).

Two prefill schedules over (q-block, kv-block) pairs:
  * ``rect``: all nb*nb pairs with causal masking — the simple baseline;
    computes the full S x S rectangle (2x causal-optimal FLOPs).
  * ``tri``: static lower-triangular pair list — causal-optimal FLOPs.
    This is the §Perf hillclimb schedule.
Both are pure-JAX analogues of the Pallas ``flash_attention`` kernel in
``repro.kernels`` (the TPU-target implementation of the same algorithm).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, head_rms_norm, pdef

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_defs(cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        "wq": pdef((d, h, hd), ("embed", "heads", None)),
        "wk": pdef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": pdef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": pdef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = pdef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = pdef((kv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = pdef((kv, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = pdef((hd,), (None,), init="ones")
        defs["k_norm"] = pdef((hd,), (None,), init="ones")
    return defs


def project_qkv(p, x, x_kv, cfg, positions, kv_positions, use_rope=True):
    """Returns q (B,Sq,H,hd), k/v (B,Sk,KV,hd)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def output_proj(p, attn_out):
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(attn_out.dtype))


# ---------------------------------------------------------------------------
# Core attention math (GQA)
# ---------------------------------------------------------------------------


def _gqa_scores(q, k):
    """q (B,Sq,H,hd), k (B,Sk,KV,hd) -> scores (B,KV,G,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = jnp.asarray(1.0 / jnp.sqrt(hd), q.dtype)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * scale


def _gqa_out(probs, v):
    """probs (B,KV,G,Sq,Sk), v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, KV, G, Sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, KV * G, v.shape[-1])


def full_attention(q, k, v, mask):
    """Unblocked path (short sequences / encoder). mask broadcastable to
    (Sq, Sk) bool, True = attend; mask=None means attend everywhere."""
    s = _gqa_scores(q, k).astype(jnp.float32)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _gqa_out(p, v)


# ---------------------------------------------------------------------------
# Blocked causal attention (online softmax over (qblk, kvblk) pairs)
# ---------------------------------------------------------------------------


def blocked_causal_attention(q, k, v, block: int, schedule: str = "tri"):
    """q,k,v over the same S (self attention), causal.

    Scans a static list of (q-block, kv-block) index pairs, maintaining
    online-softmax state for every query block. ``tri`` visits only the
    lower triangle (causal-optimal); ``rect`` visits all pairs and masks.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % block == 0, (S, block)
    nb = S // block

    if schedule == "tri":
        pairs = [(i, j) for i in range(nb) for j in range(i + 1)]
    elif schedule == "rect":
        pairs = [(i, j) for i in range(nb) for j in range(nb)]
    else:
        raise ValueError(schedule)
    qi = jnp.array([p[0] for p in pairs], jnp.int32)
    kj = jnp.array([p[1] for p in pairs], jnp.int32)

    qb = q.reshape(B, nb, block, H, hd)
    kb = k.reshape(B, nb, block, KV, hd)
    vb = v.reshape(B, nb, block, KV, hd)

    # Intra-block causal mask, used when i == j.
    tri_mask = jnp.tril(jnp.ones((block, block), bool))

    m0 = jnp.full((nb, B, KV, G, block), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nb, B, KV, G, block), jnp.float32)
    a0 = jnp.zeros((nb, B, block, H, hd), jnp.float32)

    def step(carry, idx):
        m, l, acc = carry
        i, j = idx
        qi_ = jnp.take(qb, i, axis=1)           # (B,block,H,hd)
        kj_ = jnp.take(kb, j, axis=1)
        vj_ = jnp.take(vb, j, axis=1)
        s = _gqa_scores(qi_, kj_).astype(jnp.float32)  # (B,KV,G,bq,bk)
        # mask: full if j<i, triangular if j==i, empty if j>i (rect only)
        keep = jnp.where(j < i, jnp.ones_like(tri_mask),
                         jnp.where(j == i, tri_mask, jnp.zeros_like(tri_mask)))
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        mi, li, ai = m[i], l[i], acc[i]
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        corr = jnp.exp(mi - m_new)
        pblk = jnp.exp(s - m_new[..., None])
        l_new = li * corr + jnp.sum(pblk, axis=-1)
        pv = _gqa_out(pblk.astype(q.dtype), vj_).astype(jnp.float32)
        corr_q = corr.transpose(0, 3, 1, 2).reshape(B, block, H)[..., None]
        a_new = ai * corr_q + pv
        return (m.at[i].set(m_new), l.at[i].set(l_new), acc.at[i].set(a_new)), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (qi, kj))
    l_q = l.transpose(0, 1, 4, 2, 3).reshape(nb, B, block, H)[..., None]
    out = acc / jnp.maximum(l_q, 1e-30)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# High-level forward (training / prefill / encoder / cross)
# ---------------------------------------------------------------------------


def pallas_causal_attention(q, k, v, block: int):
    """Route through the Pallas TPU flash kernel (repro.kernels).

    q (B,S,H,hd), k/v (B,S,KV,hd): GQA KV heads are repeated to H (the
    kernel streams KV blocks from VMEM, so the repeat is a view on TPU).
    Runs in interpret mode on CPU."""
    from repro.kernels import ops

    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block, 128)
    out = ops.flash_attention(qt, kt, vt, block_q=bq, block_k=bq)
    return out.transpose(0, 2, 1, 3)


def attention_forward(p, x, cfg, *, causal=True, x_kv=None, use_rope=True,
                      positions=None, kv_positions=None,
                      schedule="tri", block=512, return_kv=False):
    """x (B,S,D) -> (B,S,D). Cross attention when x_kv is given.

    cfg.attn_impl selects the causal self-attention path: "blocked"
    (pure-JAX online-softmax scan — the dry-run/HLO path) or "pallas"
    (the VMEM-tiled TPU kernel, interpret-validated on CPU)."""
    B, S, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Sk = x_kv.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None]
    if kv_positions is None:
        kv_positions = jnp.arange(Sk)[None]
    q, k, v = project_qkv(p, x, x_kv, cfg, positions, kv_positions, use_rope)
    if causal and S == Sk and S % block == 0 and S // block >= 2 \
            and getattr(cfg, "attn_impl", "blocked") == "pallas" \
            and S % min(block, 128) == 0:
        out = pallas_causal_attention(q, k, v, block)
    elif causal and S == Sk and S % block == 0 and S // block >= 2:
        out = blocked_causal_attention(q, k, v, block, schedule)
    else:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((S, Sk), bool), k=Sk - S)
        out = full_attention(q, k, v, mask)
    y = output_proj(p, out)
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, cache_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
        # absolute position held by each slot; -1 = empty
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def kv_cache_shapes(cfg, batch: int, cache_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, cache_len, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, cache_len, kv, hd), dtype),
        "slot_pos": jax.ShapeDtypeStruct((cache_len,), jnp.int32),
    }


def decode_attention(p, x, cfg, cache, pos):
    """One-token decode. x (B,1,D); pos: scalar int32 absolute position.
    The cache is a ring buffer of length W (W >= context for decode_32k,
    W = sliding window for long_500k). RoPE is applied at absolute
    positions before caching, so ring overwrite is safe."""
    B = x.shape[0]
    W = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = project_qkv(p, x, x, cfg, positions, positions, True)
    slot = jnp.mod(pos, W)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    slot_pos = cache["slot_pos"].at[slot].set(pos)
    s = _gqa_scores(q, k).astype(jnp.float32)          # (B,KV,G,1,W)
    valid = slot_pos >= 0
    s = jnp.where(valid[None, None, None, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v)
    y = output_proj(p, out)
    return y, {"k": k, "v": v, "slot_pos": slot_pos}


def cross_attention_cache(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


def cross_attention_decode(p, x, cfg, k, v):
    """One-token cross attention against fixed encoder K/V (no rope)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    s = _gqa_scores(q, k).astype(jnp.float32)
    probs = jax.nn.softmax(s, axis=-1).astype(dt)
    return output_proj(p, _gqa_out(probs, v))
