"""Feed-forward variants: SwiGLU (llama/qwen/phi/granite/zamba), squared-ReLU
(nemotron-4), GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import pdef


def mlp_defs(cfg, d_ff=None):
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": pdef((d, f), ("embed", "ff")),
            "w_up": pdef((d, f), ("embed", "ff")),
            "w_down": pdef((f, d), ("ff", "embed")),
        }
    return {
        "w_up": pdef((d, f), ("embed", "ff")),
        "w_down": pdef((f, d), ("ff", "embed")),
    }


def mlp_forward(p, x, cfg):
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    elif cfg.mlp_type == "relu2":
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jnp.square(jax.nn.relu(u))
    elif cfg.mlp_type == "gelu":
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.gelu(u)
    else:
        raise ValueError(cfg.mlp_type)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
