"""Mixture-of-Experts layer.

Two implementations selected by ``cfg.moe_impl``:

* ``densemask`` — paper-era baseline: every expert processes every token and
  the top-k routing gate masks the combination. Computed as a scan over
  experts (memory-bounded) but HLO FLOPs are E/k times the useful work.
  This is the §Perf baseline.
* ``dispatch``  — capacity-based top-k dispatch: tokens are gathered into an
  (E, C, D) buffer via scatter, each (sharded) expert runs one matmul over
  its capacity slice, results are combined with the gates. HLO FLOPs drop by
  ~E/(k*capacity_factor). This is the hillclimbed path.

Experts are stacked on a leading "experts" axis which shards over the
"model" mesh axis (16/16 for phi3.5-moe, 32/16 for granite-moe).

A standard auxiliary load-balance loss (Switch-style) is returned by the
router so training examples can regularize routing.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import pdef


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {"w_router": pdef((d, e), ("embed", None))}
    if cfg.mlp_type == "swiglu":
        defs.update({
            "w_gate": pdef((e, d, f), ("experts", "embed", "ff")),
            "w_up": pdef((e, d, f), ("experts", "embed", "ff")),
            "w_down": pdef((e, f, d), ("experts", "ff", "embed")),
        })
    else:
        defs.update({
            "w_up": pdef((e, d, f), ("experts", "embed", "ff")),
            "w_down": pdef((e, f, d), ("experts", "ff", "embed")),
        })
    return defs


def _expert_ffn(p, x, cfg, e):
    """Run expert e's FFN on x (..., D)."""
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        g = x @ p["w_gate"][e].astype(dt)
        u = x @ p["w_up"][e].astype(dt)
        h = jax.nn.silu(g) * u
    else:
        u = x @ p["w_up"][e].astype(dt)
        h = jnp.square(jax.nn.relu(u)) if cfg.mlp_type == "relu2" else jax.nn.gelu(u)
    return h @ p["w_down"][e].astype(dt)


def router(p, x, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (top-k gates (B,S,k), top-k indices (B,S,k), aux loss)."""
    logits = jnp.einsum("bsd,de->bse", x, p["w_router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    E = cfg.n_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    one_hot = jax.nn.one_hot(idx.reshape(-1, cfg.top_k), E, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0) / cfg.top_k
    aux = E * jnp.sum(me * ce)
    return gates.astype(x.dtype), idx, aux


def moe_densemask(p, x, cfg):
    """Baseline: scan over experts; every expert sees every token."""
    gates, idx, aux = router(p, x, cfg)
    # (B,S,E) combine weights scattered from the top-k selection.
    combine = jnp.zeros(x.shape[:2] + (cfg.n_experts,), x.dtype)
    b_idx = jnp.arange(x.shape[0])[:, None, None]
    s_idx = jnp.arange(x.shape[1])[None, :, None]
    combine = combine.at[b_idx, s_idx, idx].add(gates)

    def body(e, acc):
        y = _expert_ffn(p, x, cfg, e)
        return acc + combine[..., e, None] * y

    out = jax.lax.fori_loop(0, cfg.n_experts, body, jnp.zeros_like(x))
    return out, aux


def moe_dispatch(p, x, cfg, capacity_factor: float = 1.25):
    """Optimized: capacity-based top-k dispatch with gather/scatter."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = max(int(K * T * capacity_factor / E), 1)
    # round capacity to an MXU-friendly multiple
    C = ((C + 127) // 128) * 128 if C > 128 else C

    gates, idx, aux = router(p, x, cfg)          # (B,S,K)
    xf = x.reshape(T, D)
    gf = gates.reshape(T, K)
    ef = idx.reshape(T, K)

    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(ef, E, dtype=jnp.int32)       # (T,K,E)
    pos_all = jnp.cumsum(onehot.reshape(T * K, E), axis=0) - 1
    pos = jnp.take_along_axis(
        pos_all.reshape(T, K, E), ef[..., None], axis=-1)[..., 0]  # (T,K)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C - 1)

    # scatter tokens into (E, C, D)
    disp = jnp.zeros((E, C, D), x.dtype)
    scale = keep.astype(x.dtype)                          # drop overflow
    for k in range(K):
        disp = disp.at[ef[:, k], safe_pos[:, k]].add(xf * scale[:, k, None])

    # per-expert FFN on the capacity buffer (experts axis sharded)
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(dt))
        u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(dt))
        h = jnp.square(jax.nn.relu(u)) if cfg.mlp_type == "relu2" else jax.nn.gelu(u)
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))

    # combine: gather each token's expert outputs back, weight by gate
    out = jnp.zeros((T, D), x.dtype)
    for k in range(K):
        contrib = eout[ef[:, k], safe_pos[:, k]]
        out = out + contrib * (gf[:, k] * scale[:, k])[:, None]
    return out.reshape(B, S, D), aux


def moe_forward(p, x, cfg):
    if cfg.moe_impl == "dispatch":
        return moe_dispatch(p, x, cfg)
    return moe_densemask(p, x, cfg)


def moe_decode(p, x, cfg):
    """One-token MoE (B,1,D): gather the top-k expert weights per token and
    apply them directly — no capacity machinery needed at batch*1 scale."""
    gates, idx, aux = router(p, x, cfg)        # (B,1,K)
    B = x.shape[0]
    dt = x.dtype
    xe = x[:, 0]                               # (B,D)

    def one_expert(k):
        e = idx[:, 0, k]                       # (B,)
        if cfg.mlp_type == "swiglu":
            wg = p["w_gate"][e].astype(dt)     # (B,D,F)
            wu = p["w_up"][e].astype(dt)
            h = jax.nn.silu(jnp.einsum("bd,bdf->bf", xe, wg)) * \
                jnp.einsum("bd,bdf->bf", xe, wu)
        else:
            u = jnp.einsum("bd,bdf->bf", xe, p["w_up"][e].astype(dt))
            h = jnp.square(jax.nn.relu(u)) if cfg.mlp_type == "relu2" \
                else jax.nn.gelu(u)
        return jnp.einsum("bf,bfd->bd", h, p["w_down"][e].astype(dt))

    out = jnp.zeros_like(xe)
    for k in range(cfg.top_k):
        out = out + gates[:, 0, k, None] * one_expert(k)
    return out[:, None], aux
