"""Model assembly: ArchConfig -> Model (init / forward / loss / prefill /
decode_step) for every assigned family.

Families:
  dense     embed -> [attn + mlp] x L -> norm -> lm_head
  moe       embed -> [attn + moe] x L
  hybrid    embed -> [mamba2] x L with one SHARED attention block applied
            every cfg.attn_every layers (zamba2)
  ssm       embed -> groups of (slstm_every-1 mLSTM + 1 sLSTM) (xlstm)
  vlm       stub patch embeddings prefixed to token embeddings -> dense stack
  audio     stub frame embeddings -> encoder stack; tokens -> decoder stack
            with cross attention (whisper)

All stacks scan over layers with stacked params + jax.checkpoint (bounded
HLO size and activation memory for 126-layer configs). The LM head / CE
loss is computed in sequence chunks (never materializes (B,S,V) logits).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import xlstm as xl
from repro.models.layers import (abstract_params, init_params, pdef, rms_norm,
                                 softmax_cross_entropy, stack_defs)

CE_CHUNK = 512
EMBED_CHUNK = 1024


# ---------------------------------------------------------------------------
# Embedding / LM head (vocab-sharded friendly)
# ---------------------------------------------------------------------------


def _embed_lookup(table, tokens, dtype, impl: str = "onehot"):
    """Embedding lookup.

    impl="onehot": one-hot matmul in sequence chunks — MXU-friendly and
    keeps a model-sharded vocab axis local, but costs 2*T*V*D flops
    (dominates useful flops for small-d / huge-vocab archs; §Perf).
    impl="gather": jnp.take — no flops; XLA resolves a sharded vocab axis
    with an all-gather of the (small, d-sharded) table slice or a
    distributed gather."""
    if impl == "gather":
        return jnp.take(table, tokens, axis=0).astype(dtype)
    B, S = tokens.shape
    V, D = table.shape
    chunk = min(EMBED_CHUNK, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    tc = tokens.reshape(B, n, chunk)

    def step(_, tok):
        oh = jax.nn.one_hot(tok, V, dtype=dtype)
        return None, jnp.einsum("bsv,vd->bsd", oh, table.astype(dtype))

    _, out = jax.lax.scan(step, None, jnp.moveaxis(tc, 1, 0))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, D)


def _chunked_ce(x, w_head, labels, mask=None, chunk=CE_CHUNK):
    """Mean next-token CE without materializing full logits.

    x (B,S,D) fp-activations, w_head (D,V), labels (B,S) int32.
    """
    B, S, D = x.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    if mask is None:
        mc = jnp.ones((n, B, chunk), jnp.float32)
    else:
        mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0).astype(jnp.float32)

    def step(acc, inp):
        xb, lb, mb = inp
        logits = jnp.einsum("bsd,dv->bsv", xb, w_head.astype(xb.dtype))
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mb
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Per-family block definitions
# ---------------------------------------------------------------------------


def _dense_block_defs(cfg):
    d = {"norm1": pdef((cfg.d_model,), ("embed",), init="ones"),
         "attn": attn.attention_defs(cfg),
         "norm2": pdef((cfg.d_model,), ("embed",), init="ones")}
    if cfg.is_moe:
        d["moe"] = moem.moe_defs(cfg)
    else:
        d["mlp"] = mlpm.mlp_defs(cfg)
    return d


def _dense_block(p, x, cfg, schedule, block):
    h = attn.attention_forward(p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                               cfg, schedule=schedule, block=block)
    x = x + h
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moem.moe_forward(p["moe"], h2, cfg)
    else:
        y, aux = mlpm.mlp_forward(p["mlp"], h2, cfg), jnp.zeros(())
    return x + y, aux


def _dense_block_decode(p, x, cfg, cache, pos):
    h, kv = attn.decode_attention(p["attn"],
                                  rms_norm(x, p["norm1"], cfg.norm_eps),
                                  cfg, cache, pos)
    x = x + h
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moem.moe_decode(p["moe"], h2, cfg)
    else:
        y = mlpm.mlp_forward(p["mlp"], h2, cfg)
    return x + y, kv


def _mamba_block(p, x, cfg):
    return x + mam.mamba_forward(p["mamba"],
                                 rms_norm(x, p["norm"], cfg.norm_eps), cfg)


def _mamba_block_decode(p, x, cfg, cache):
    y, new = mam.mamba_decode(p["mamba"],
                              rms_norm(x, p["norm"], cfg.norm_eps), cfg, cache)
    return x + y, new


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    defs: Any                                   # ParamDef tree
    forward: Callable                           # (params, batch) -> (x, aux)
    decode_fn: Callable                         # (params, cache, tok, pos, extras)

    # -- params -------------------------------------------------------------
    def init(self, key):
        return init_params(self.defs, key)

    def abstract(self):
        return abstract_params(self.defs)

    # -- training -----------------------------------------------------------
    def loss(self, params, batch):
        x, aux = self.forward(params, batch)
        labels = batch["tokens"]
        lab = jnp.concatenate([labels[:, 1:],
                               jnp.zeros_like(labels[:, :1])], axis=1)
        mask = jnp.ones_like(lab, jnp.float32).at[:, -1].set(0.0)
        head = self._head(params)
        ce = _chunked_ce(x, head, lab, mask)
        return ce + 0.01 * aux

    def logits(self, params, batch):
        """Full logits — for small/smoke configs only."""
        x, _ = self.forward(params, batch)
        return jnp.einsum("bsd,dv->bsv", x,
                          self._head(params).astype(x.dtype)).astype(jnp.float32)

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, abstract=False,
                   extras: Optional[Dict] = None):
        dtype = jnp.dtype(self.cfg.dtype)
        return _build_cache(self.cfg, batch, cache_len, dtype,
                            abstract=abstract, extras=extras)

    def decode_step(self, params, cache, tokens, pos, extras=None):
        """tokens (B,1); pos scalar int32 -> (logits (B,1,V), new cache)."""
        return self.decode_fn(params, cache, tokens, pos, extras)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _build_cache(cfg, batch, cache_len, dtype, abstract, extras=None):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    fam = cfg.family

    def kv(n_layers):
        c = attn.kv_cache_shapes(cfg, batch, cache_len, dtype)
        out = jax.tree.map(lambda s: mk((n_layers,) + s.shape, s.dtype), c)
        if not abstract:
            # empty slots marked -1
            out["slot_pos"] = jnp.full((n_layers, cache_len), -1, jnp.int32)
        return out

    if fam in ("dense", "moe", "vlm"):
        return {"kv": kv(cfg.n_layers)}
    if fam == "hybrid":
        mc = mam.mamba_cache_shapes(cfg, batch, dtype)
        mcache = jax.tree.map(
            lambda s: mk((cfg.n_layers,) + s.shape, s.dtype), mc)
        n_attn = cfg.n_layers // cfg.attn_every
        return {"mamba": mcache, "kv": kv(max(n_attn, 1))}
    if fam == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        ms = xl.mlstm_cache_shapes(cfg, batch, dtype)
        ss = xl.slstm_cache_shapes(cfg, batch, dtype)
        return {
            "mlstm": jax.tree.map(
                lambda s: mk((n_groups, n_m) + s.shape, s.dtype), ms),
            "slstm": jax.tree.map(
                lambda s: mk((n_groups,) + s.shape, s.dtype), ss),
        }
    if fam == "audio":
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        Ld = cfg.n_layers
        return {
            "kv": kv(Ld),
            "cross_k": mk((Ld, batch, cfg.n_frames, kvh, hd), dtype),
            "cross_v": mk((Ld, batch, cfg.n_frames, kvh, hd), dtype),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Builders per family
# ---------------------------------------------------------------------------


def _common_defs(cfg):
    defs = {
        "embed": pdef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                      scale=0.02),
        "final_norm": pdef((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = pdef((cfg.d_model, cfg.padded_vocab),
                               ("embed", "vocab"))
    return defs


def build_model(cfg: ArchConfig, schedule: str = "tri",
                attn_block: int = 512, layer_param_hook=None,
                layer_act_hook=None) -> Model:
    """layer_param_hook(per_layer_params) -> per_layer_params is applied
    INSIDE the scan-over-layers body. The fsdp policy uses it to place a
    with_sharding_constraint that all-gathers one layer's weights at a
    time (params stay fsdp-sharded at rest; the transpose inserts the
    matching grad reduce-scatter). layer_act_hook(x) -> x likewise pins
    the activation sharding (batch over "fsdp") so propagation cannot
    un-shard the batch between layers."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _build_decoder(cfg, schedule, attn_block, layer_param_hook,
                              layer_act_hook)
    if fam == "hybrid":
        return _build_hybrid(cfg, schedule, attn_block)
    if fam == "ssm":
        return _build_xlstm(cfg)
    if fam == "vlm":
        return _build_vlm(cfg, schedule, attn_block)
    if fam == "audio":
        return _build_whisper(cfg, schedule, attn_block)
    raise ValueError(fam)


# ---- dense / moe -----------------------------------------------------------


def _build_decoder(cfg, schedule, attn_block, layer_param_hook=None,
                   layer_act_hook=None):
    defs = _common_defs(cfg)
    defs["blocks"] = stack_defs(_dense_block_defs(cfg), cfg.n_layers)

    def forward(params, batch):
        tokens = batch["tokens"]
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)
        if layer_act_hook is not None:
            x = layer_act_hook(x)

        @jax.checkpoint
        def layer(x, p):
            if layer_param_hook is not None:
                p = layer_param_hook(p)
            if layer_act_hook is not None:
                x = layer_act_hook(x)
            x, aux = _dense_block(p, x, cfg, schedule, attn_block)
            if layer_act_hook is not None:
                x = layer_act_hook(x)
            return x, aux

        x, auxs = jax.lax.scan(layer, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.sum(auxs)

    def decode(params, cache, tokens, pos, extras):
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        def layer(x, pc):
            p, c = pc
            x, kv = _dense_block_decode(p, x, cfg, c, pos)
            return x, kv

        x, new_kv = jax.lax.scan(layer, x, (params["blocks"], cache["kv"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return logits.astype(jnp.float32), {"kv": new_kv}

    def prefill(params, batch, cache_len):
        """Batched prefill: ONE forward populates the KV cache for every
        prompt position (vs token-by-token decode). Returns
        (last-position logits (B,1,V), cache). Prompt length must be
        <= cache_len; positions land in ring slots pos % cache_len."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert S <= cache_len, (S, cache_len)
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        def layer(x, p):
            h, (k, v) = attn.attention_forward(
                p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
                schedule="tri", return_kv=True)
            x = x + h
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moem.moe_forward(p["moe"], h2, cfg)
            else:
                y = mlpm.mlp_forward(p["mlp"], h2, cfg)
            return x + y, (k, v)

        x, (ks, vs) = jax.lax.scan(layer, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            head.astype(x.dtype)).astype(jnp.float32)
        pad = cache_len - S
        kc = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        slot = jnp.pad(jnp.arange(S, dtype=jnp.int32), (0, pad),
                       constant_values=-1)
        slot_pos = jnp.broadcast_to(slot, (cfg.n_layers, cache_len))
        return logits, {"kv": {"k": kc, "v": vc, "slot_pos": slot_pos}}

    m = Model(cfg, defs, forward, decode)
    m.prefill = prefill
    return m


# ---- hybrid (zamba2) --------------------------------------------------------


def _build_hybrid(cfg, schedule, attn_block):
    defs = _common_defs(cfg)
    defs["blocks"] = stack_defs(
        {"norm": pdef((cfg.d_model,), ("embed",), init="ones"),
         "mamba": mam.mamba_defs(cfg)}, cfg.n_layers)
    # one SHARED attention block (zamba2's signature trick)
    defs["shared_attn"] = {
        "norm": pdef((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.attention_defs(cfg),
    }
    every = cfg.attn_every

    def forward(params, batch):
        tokens = batch["tokens"]
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)
        sh = params["shared_attn"]

        @jax.checkpoint
        def layer(carry, inp):
            x = carry
            p, idx = inp
            x = _mamba_block(p, x, cfg)
            use_attn = (idx % every) == (every - 1)

            def with_attn(x):
                h = attn.attention_forward(
                    sh["attn"], rms_norm(x, sh["norm"], cfg.norm_eps), cfg,
                    schedule=schedule, block=attn_block)
                return x + h

            x = jax.lax.cond(use_attn, with_attn, lambda x: x, x)
            return x, None

        idxs = jnp.arange(cfg.n_layers)
        x, _ = jax.lax.scan(layer, x, (params["blocks"], idxs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.zeros(())

    def decode(params, cache, tokens, pos, extras):
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)
        sh = params["shared_attn"]
        n_attn = max(cfg.n_layers // every, 1)

        def layer(carry, inp):
            x, kvs = carry
            p, mc, idx = inp
            x, new_mc = _mamba_block_decode(p, x, cfg, mc)
            use_attn = (idx % every) == (every - 1)
            slot = jnp.minimum(idx // every, n_attn - 1)
            kv_l = jax.tree.map(lambda a: a[slot], kvs)

            def with_attn(args):
                x, kv_l = args
                h, new_kv = attn.decode_attention(
                    sh["attn"], rms_norm(x, sh["norm"], cfg.norm_eps), cfg,
                    kv_l, pos)
                return x + h, new_kv

            x, kv_l = jax.lax.cond(use_attn, with_attn, lambda a: a,
                                   (x, kv_l))
            kvs = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one, slot, 0), kvs, kv_l)
            return (x, kvs), new_mc

        idxs = jnp.arange(cfg.n_layers)
        (x, new_kvs), new_mamba = jax.lax.scan(
            layer, (x, cache["kv"]), (params["blocks"], cache["mamba"], idxs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return logits.astype(jnp.float32), {"mamba": new_mamba, "kv": new_kvs}

    return Model(cfg, defs, forward, decode)


# ---- ssm (xlstm) ------------------------------------------------------------


def _build_xlstm(cfg):
    n_groups = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    defs = _common_defs(cfg)
    m_defs = {"norm": pdef((cfg.d_model,), ("embed",), init="ones"),
              "cell": xl.mlstm_defs(cfg)}
    s_defs = {"norm": pdef((cfg.d_model,), ("embed",), init="ones"),
              "cell": xl.slstm_defs(cfg)}
    defs["mlstm"] = stack_defs(stack_defs(m_defs, n_m, "sub"), n_groups)
    defs["slstm"] = stack_defs(s_defs, n_groups)

    def forward(params, batch):
        tokens = batch["tokens"]
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        @jax.checkpoint
        def group(x, p):
            def msub(x, pm):
                h = rms_norm(x, pm["norm"], cfg.norm_eps)
                return x + xl.mlstm_forward(pm["cell"], h, cfg), None

            x, _ = jax.lax.scan(msub, x, p["m"])
            h = rms_norm(x, p["s"]["norm"], cfg.norm_eps)
            x = x + xl.slstm_forward(p["s"]["cell"], h, cfg)
            return x, None

        x, _ = jax.lax.scan(group, x, {"m": params["mlstm"],
                                       "s": params["slstm"]})
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.zeros(())

    def decode(params, cache, tokens, pos, extras):
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        def group(x, inp):
            p, mc, sc = inp

            def msub(x, pc):
                pm, c = pc
                h = rms_norm(x, pm["norm"], cfg.norm_eps)
                y, new = xl.mlstm_decode(pm["cell"], h, cfg, c)
                return x + y, new

            x, new_mc = jax.lax.scan(msub, x, (p["m"], mc))
            h = rms_norm(x, p["s"]["norm"], cfg.norm_eps)
            y, new_sc = xl.slstm_decode(p["s"]["cell"], h, cfg, sc)
            return x + y, (new_mc, new_sc)

        x, (new_m, new_s) = jax.lax.scan(
            group, x, ({"m": params["mlstm"], "s": params["slstm"]},
                       cache["mlstm"], cache["slstm"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return logits.astype(jnp.float32), {"mlstm": new_m, "slstm": new_s}

    return Model(cfg, defs, forward, decode)


# ---- vlm (internvl2) ---------------------------------------------------------


def _build_vlm(cfg, schedule, attn_block):
    base = _build_decoder(cfg, schedule, attn_block)
    defs = dict(base.defs)
    # projector applied to the stub ViT patch embeddings
    defs["projector"] = {
        "w": pdef((cfg.d_model, cfg.d_model), ("embed", None)),
        "b": pdef((cfg.d_model,), (None,), init="zeros"),
    }

    def forward(params, batch):
        tokens = batch["tokens"]
        dtype = jnp.dtype(cfg.dtype)
        tok = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)
        if "patches" in batch:
            pr = params["projector"]
            pe = (batch["patches"].astype(dtype) @ pr["w"].astype(dtype)
                  + pr["b"].astype(dtype))
            # patch prefix replaces the first n_patches token slots so the
            # sequence length (and position ids) stay fixed for sharding.
            n = pe.shape[1]
            x = jnp.concatenate([pe, tok[:, n:]], axis=1)
        else:
            x = tok

        @jax.checkpoint
        def layer(x, p):
            x, aux = _dense_block(p, x, cfg, schedule, attn_block)
            return x, aux

        x, auxs = jax.lax.scan(layer, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.sum(auxs)

    return Model(cfg, defs, forward, base.decode_fn)


# ---- audio (whisper) ----------------------------------------------------------


def _build_whisper(cfg, schedule, attn_block):
    defs = _common_defs(cfg)
    enc_block = {
        "norm1": pdef((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.attention_defs(cfg),
        "norm2": pdef((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlpm.mlp_defs(cfg),
    }
    dec_block = {
        "norm1": pdef((cfg.d_model,), ("embed",), init="ones"),
        "self_attn": attn.attention_defs(cfg),
        "norm2": pdef((cfg.d_model,), ("embed",), init="ones"),
        "cross_attn": attn.attention_defs(cfg),
        "norm3": pdef((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlpm.mlp_defs(cfg),
    }
    defs["enc"] = stack_defs(enc_block, cfg.n_encoder_layers)
    defs["dec"] = stack_defs(dec_block, cfg.n_layers)
    defs["enc_norm"] = pdef((cfg.d_model,), ("embed",), init="ones")
    # projector on the stub conv/mel frame embeddings
    defs["frame_proj"] = {
        "w": pdef((cfg.d_model, cfg.d_model), ("embed", None)),
        "b": pdef((cfg.d_model,), (None,), init="zeros"),
    }

    def encode(params, frames):
        dtype = jnp.dtype(cfg.dtype)
        fp = params["frame_proj"]
        x = (frames.astype(dtype) @ fp["w"].astype(dtype)
             + fp["b"].astype(dtype))

        @jax.checkpoint
        def layer(x, p):
            h = attn.attention_forward(
                p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
                causal=False, use_rope=False)
            x = x + h
            x = x + mlpm.mlp_forward(p["mlp"],
                                     rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
            return x, None

        x, _ = jax.lax.scan(layer, x, params["enc"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def forward(params, batch):
        enc_out = encode(params, batch["frames"])
        tokens = batch["tokens"]
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        @jax.checkpoint
        def layer(x, p):
            h = attn.attention_forward(
                p["self_attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
                schedule=schedule, block=attn_block)
            x = x + h
            h = attn.attention_forward(
                p["cross_attn"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg,
                causal=False, x_kv=enc_out, use_rope=False)
            x = x + h
            x = x + mlpm.mlp_forward(p["mlp"],
                                     rms_norm(x, p["norm3"], cfg.norm_eps), cfg)
            return x, None

        x, _ = jax.lax.scan(layer, x, params["dec"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.zeros(())

    def decode(params, cache, tokens, pos, extras):
        dtype = jnp.dtype(cfg.dtype)
        x = _embed_lookup(params["embed"], tokens, dtype, cfg.embed_impl)

        def layer(x, pc):
            p, kv_l, ck, cv = pc
            h, new_kv = attn.decode_attention(
                p["self_attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
                kv_l, pos)
            x = x + h
            h = attn.cross_attention_decode(
                p["cross_attn"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg,
                ck, cv)
            x = x + h
            x = x + mlpm.mlp_forward(p["mlp"],
                                     rms_norm(x, p["norm3"], cfg.norm_eps), cfg)
            return x, new_kv

        x, new_kv = jax.lax.scan(
            layer, x, (params["dec"], cache["kv"],
                       cache["cross_k"], cache["cross_v"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return logits.astype(jnp.float32), {
            "kv": new_kv, "cross_k": cache["cross_k"],
            "cross_v": cache["cross_v"]}

    m = Model(cfg, defs, forward, decode)
    m.encode = partial_encode(encode)
    return m


def partial_encode(fn):
    return fn
