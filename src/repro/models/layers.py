"""Shared layer primitives + the ParamDef declaration system.

Params are declared as a pytree of ``ParamDef`` leaves carrying shape,
*logical* axis names and an init function. The same declaration tree yields:
  * materialized parameters        (``init_params``)
  * ShapeDtypeStructs for dry-runs (``abstract_params``)
  * ``PartitionSpec``s             (``repro.sharding.specs.resolve_specs``)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# ParamDef
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, same rank as shape
    init: str = "normal"             # normal | zeros | ones | small_normal
    scale: float = 0.02
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def pdef(shape, axes, init="normal", scale=0.02, dtype="float32") -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), init, scale, dtype)


def is_pdef(x) -> bool:
    return isinstance(x, ParamDef)


def _materialize(d: ParamDef, key) -> jax.Array:
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    scale = d.scale
    if d.init == "small_normal":
        scale = d.scale * 0.1
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)


def init_params(defs, key):
    """Materialize a ParamDef tree into an array pytree."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_pdef)
    keys = jax.random.split(key, len(leaves))
    arrays = [_materialize(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs):
    """ShapeDtypeStruct tree for .lower() dry-runs — no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=is_pdef)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading axis (for scan-over-layers)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes,
                           d.init, d.scale, d.dtype),
        defs, is_leaf=is_pdef)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, weight, eps: float = 1e-5):
    """qk-norm: RMS over the trailing head_dim of (..., H, hd)."""
    return rms_norm(x, weight, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, hd/2)
    sin = jnp.sin(ang)[..., None, :]                       # (..., S, 1, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return pe


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean next-token CE. logits (..., V) fp32; labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
