"""Trace summarizer / validator (DESIGN.md §13).

  PYTHONPATH=src python -m repro.obs.report out.jsonl          # summary
  PYTHONPATH=src python -m repro.obs.report out.jsonl --check  # validate

``--check`` is the schema gate CI runs on the trace smoke: meta header
present with a compatible schema version, round indices strictly
monotone, every round record carrying the full uniform metric key set
(``obs.round_metric_keys``), fenced phase durations, and the per-stream
wire splits summing exactly to the totals. Exit 1 with a problem list
on any violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

import numpy as np

from repro import obs


def load(path) -> Tuple[dict, List[dict]]:
    """Parse a JSONL trace -> (meta header, records in file order)."""
    meta, records = {}, []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("kind") == "meta" and not meta:
                meta = rec
            else:
                records.append(rec)
    return meta, records


def rounds_of(records) -> List[dict]:
    return [r for r in records if r.get("kind") == "round"]


def steps_of(records) -> List[dict]:
    """kind="step" records: the serve engine's per-scheduler-tick records
    (same phase_s contract as rounds, serve-specific metric keys)."""
    return [r for r in records if r.get("kind") == "step"]


def check(meta: dict, records: List[dict]) -> List[str]:
    """Schema problems (empty list == valid trace)."""
    problems = []
    if not meta:
        problems.append("no meta header record (kind='meta' first line)")
    elif meta.get("schema") != obs.SCHEMA_VERSION:
        problems.append(f"schema {meta.get('schema')!r} != "
                        f"{obs.SCHEMA_VERSION} (this reader)")
    rounds = rounds_of(records)
    steps = steps_of(records)
    if not rounds and not steps:
        problems.append("no round/step records")
    idx = [r.get("round") for r in rounds]
    if idx and any(b <= a for a, b in zip(idx, idx[1:])):
        problems.append(f"round indices not strictly monotone: {idx}")
    sidx = [r.get("round") for r in steps]
    if sidx and any(b <= a for a, b in zip(sidx, sidx[1:])):
        problems.append(f"step indices not strictly monotone: {sidx}")
    for r in steps:
        ph = r.get("phase_s", {})
        if any(v < 0 for v in ph.values()):
            problems.append(f"step {r.get('round')}: bad phase_s {ph}")
            break
    for r in rounds:
        m = r.get("metrics", {})
        required = obs.round_metric_keys(obs.streams_of(m) or ("params",))
        missing = sorted(set(required) - set(m))
        if missing:
            problems.append(f"round {r.get('round')}: missing metric "
                            f"keys {missing}")
            break                      # one report per failure class
    for r in rounds:
        ph = r.get("phase_s", {})
        if not ph or any(v < 0 for v in ph.values()):
            problems.append(f"round {r.get('round')}: bad phase_s {ph}")
            break
    overlap_meta = bool(meta.get("overlap"))
    for r in rounds:
        ph = r.get("phase_s", {})
        has = {"exchange_exposed" in ph, "exchange_total" in ph}
        if has == {True, False}:
            problems.append(
                f"round {r.get('round')}: exchange_exposed/exchange_total "
                "must appear together (obs.exchange_phases emits the "
                f"pair) — got {sorted(ph)}")
            break
        if overlap_meta and has == {False}:
            problems.append(
                f"round {r.get('round')}: overlap run without the "
                "exchange_exposed/exchange_total phase split — the "
                "overlap win is unmeasured (DESIGN.md §14)")
            break
        if (True in has
                and ph["exchange_exposed"] > ph["exchange_total"] + 1e-9):
            problems.append(
                f"round {r.get('round')}: exchange_exposed "
                f"{ph['exchange_exposed']} > exchange_total "
                f"{ph['exchange_total']} (total is floored at exposed)")
            break
    for r in rounds:
        m = r.get("metrics", {})
        split = sum(v for k, v in m.items()
                    if k.startswith("wire_bytes/"))
        if "wire_bytes" in m and int(split) != int(m["wire_bytes"]):
            problems.append(
                f"round {r.get('round')}: wire_bytes {m['wire_bytes']} "
                f"!= sum of per-stream splits {int(split)}")
            break
        up, down = m.get("wire_bytes_up"), m.get("wire_bytes_down")
        ti, tx = m.get("wire_bytes_intra"), m.get("wire_bytes_inter")
        allowed = set()
        if up is not None and down is not None:
            # total == up + down (server/async: distinct payloads) or
            # total == up == down (p2p edges count once) — DESIGN.md §13
            allowed |= {int(up) + int(down), int(up)}
        if ti is not None and tx is not None:
            # the per-tier identity (DESIGN.md §16): hierarchical rounds
            # mix p2p and server pricing across tiers, so the sum of the
            # tier totals is the authoritative decomposition
            allowed.add(int(ti) + int(tx))
        if ("wire_bytes" in m and allowed
                and int(m["wire_bytes"]) not in allowed):
            problems.append(
                f"round {r.get('round')}: wire_bytes {m['wire_bytes']} "
                f"is neither up+down ({up}+{down}), up ({up}), nor "
                f"intra+inter ({ti}+{tx})")
            break
        bad_part = next(
            (k for k in ("participation", "participation_intra",
                         "participation_inter", "delivery_rate",
                         "delivery_rate_intra", "delivery_rate_inter")
             if not 0.0 <= float(m.get(k, 1.0)) <= 1.0), None)
        if bad_part is not None:
            problems.append(f"round {r.get('round')}: {bad_part} "
                            f"{m.get(bad_part)} outside [0, 1]")
            break
    return problems


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, float), q))


def summarize(meta: dict, records: List[dict]) -> dict:
    """Per-phase p50/p99, wire totals by stream, consensus trajectory,
    participation — the reporting layer of DESIGN.md §13."""
    rounds = rounds_of(records)
    steps = steps_of(records)
    out = {"meta": {k: v for k, v in meta.items() if k != "kind"},
           "n_rounds": len(rounds), "n_steps": len(steps)}
    phases = {}
    for r in rounds + steps:     # serve step phases aggregate identically
        for k, v in r.get("phase_s", {}).items():
            phases.setdefault(k, []).append(float(v))
    out["phase_s"] = {
        k: {"p50": _pct(v, 50), "p99": _pct(v, 99),
            "total": float(np.sum(v)), "n": len(v)}
        for k, v in phases.items()}
    if "exchange_exposed" in phases and "exchange_total" in phases:
        exposed = float(np.sum(phases["exchange_exposed"]))
        total = float(np.sum(phases["exchange_total"]))
        # 1 - exposed/total: the fraction of exchange time the overlap
        # actually hid behind local compute (DESIGN.md §14); 0 on barrier
        # rounds (exposed == total by construction) and honestly ≈ 0 on
        # serial single-core backends
        out["overlap_efficiency"] = (1.0 - exposed / total
                                     if total > 0.0 else 0.0)
    wire = {}
    for r in rounds:
        for k, v in r.get("metrics", {}).items():
            if k.startswith("wire_bytes/"):
                wire[k[len("wire_bytes/"):]] = \
                    wire.get(k[len("wire_bytes/"):], 0) + int(v)
    out["wire_bytes_by_stream"] = wire
    out["wire_bytes_total"] = sum(
        int(r["metrics"].get("wire_bytes", 0)) for r in rounds)
    out["wire_bytes_by_tier"] = {
        t: sum(int(r["metrics"].get(f"wire_bytes_{t}", 0))
               for r in rounds)
        for t in ("intra", "inter")}
    # serve-engine admission counters (DESIGN.md §15/§16): queue depth
    # and FreeList backpressure across the kind="step" records
    queued = [float(r["metrics"]["queued"]) for r in steps
              if "queued" in r.get("metrics", {})]
    if queued:
        serve = {"queued_mean": float(np.mean(queued)),
                 "queued_max": float(max(queued))}
        deferred = [int(r["metrics"].get("deferred_total", 0))
                    for r in steps]
        serve["deferred_total"] = max(deferred) if deferred else 0
        free = [int(r["metrics"]["free_rows"]) for r in steps
                if "free_rows" in r.get("metrics", {})]
        if free:
            serve["free_rows_min"] = min(free)
        out["serve"] = serve
    cons = [float(np.mean(r["metrics"]["consensus_sq"])) for r in rounds
            if "consensus_sq" in r.get("metrics", {})]
    if cons:
        out["consensus_sq"] = {"first": cons[0], "last": cons[-1],
                               "max": max(cons), "trajectory": cons}
    parts = [float(r["metrics"]["participation"]) for r in rounds
             if "participation" in r.get("metrics", {})]
    if parts:
        out["participation"] = {"mean": float(np.mean(parts)),
                                "min": min(parts)}
    losses = [float(np.mean(r["metrics"]["loss"])) for r in rounds
              if "loss" in r.get("metrics", {})]
    if losses:
        out["loss"] = {"first": losses[0], "last": losses[-1]}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="JSONL trace file (train.py --trace)")
    ap.add_argument("--check", action="store_true",
                    help="validate the schema; exit 1 on any problem")
    ap.add_argument("--json", action="store_true",
                    help="print the full summary as JSON")
    args = ap.parse_args(argv)
    meta, records = load(args.trace)
    if args.check:
        problems = check(meta, records)
        for p in problems:
            print(f"PROBLEM: {p}")
        if problems:
            return 1
        rounds = rounds_of(records)
        streams = (list(obs.streams_of(rounds[0]["metrics"]))
                   if rounds else [])
        print(f"OK: {len(rounds)} round record(s), "
              f"{len(steps_of(records))} step record(s), "
              f"schema v{meta.get('schema')}, streams {streams}")
        return 0
    s = summarize(meta, records)
    if args.json:
        print(json.dumps(s, indent=1))
        return 0
    print(f"trace: {args.trace}  rounds: {s['n_rounds']}  "
          f"steps: {s['n_steps']}")
    for k, v in s.get("phase_s", {}).items():
        print(f"  phase {k:<12} p50 {v['p50']*1e3:8.1f}ms  "
              f"p99 {v['p99']*1e3:8.1f}ms  total {v['total']:.2f}s")
    if s.get("wire_bytes_by_stream"):
        tot = s["wire_bytes_total"]
        per = ", ".join(f"{k}={v:,}B"
                        for k, v in s["wire_bytes_by_stream"].items())
        print(f"  wire  total {tot:,}B  ({per})")
        tiers = s.get("wire_bytes_by_tier", {})
        if any(tiers.values()):
            print(f"  wire  by tier intra {tiers.get('intra', 0):,}B  "
                  f"inter {tiers.get('inter', 0):,}B")
    if "serve" in s:
        sv = s["serve"]
        line = (f"  serve queued mean {sv['queued_mean']:.1f}  "
                f"max {sv['queued_max']:.0f}  "
                f"deferred total {sv['deferred_total']}")
        if "free_rows_min" in sv:
            line += f"  free rows min {sv['free_rows_min']}"
        print(line)
    if "overlap_efficiency" in s:
        print(f"  overlap efficiency (1 - exposed/total exchange) "
              f"{s['overlap_efficiency']:.3f}")
    if "consensus_sq" in s:
        c = s["consensus_sq"]
        print(f"  consensus ||x_g - mean||^2: first {c['first']:.3e}  "
              f"last {c['last']:.3e}  max {c['max']:.3e}")
    if "participation" in s:
        print(f"  participation mean {s['participation']['mean']:.3f}  "
              f"min {s['participation']['min']:.3f}")
    if "loss" in s:
        print(f"  loss first {s['loss']['first']:.4f}  "
              f"last {s['loss']['last']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
