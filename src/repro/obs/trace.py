"""Phase-fenced tracing: honest wall-clock + a structured JSONL sink.

The problem this solves (ISSUE 7): jitted calls return BEFORE the work
finishes (async dispatch), so ``t0 = time.time(); state = step(...);
dt = time.time() - t0`` measures dispatch, not compute. Every phase
timer here fences with ``jax.block_until_ready`` on the values the
phase produced before reading the clock, and wraps the phase in
``jax.profiler.TraceAnnotation`` so a perfetto dump (``--profile``)
shows the same phase boundaries the JSONL records.

Sink format (one JSON object per line):

  {"kind": "meta", "schema": 1, ...caller meta...}        # first line
  {"kind": "round", "round": n, "phase_s": {...}, "metrics": {...}}
  {"kind": "step"|"bench"|"dryrun", ...}                  # other events

``Trace(path=None)`` is a null sink that still fences and times — the
launchers use one unconditionally so printed timings are honest even
when nothing is written.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Any, Dict, Optional


def to_jsonable(x):
    """Round metrics -> plain JSON: device arrays become floats/lists
    (forces a host transfer — callers fence first, so this is cheap and
    never blocks on in-flight work)."""
    if isinstance(x, dict):
        return {k: to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [to_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "ndim"):                  # jax/np array
        import numpy as np
        a = np.asarray(x)
        if a.ndim == 0:
            return (int(a) if np.issubdtype(a.dtype, np.integer)
                    else float(a))
        return a.astype(float).tolist()
    return float(x)


class PhaseTimer:
    """Fenced wall-clock timer: ``fence(x)`` registers values the phase
    produced; ``__exit__`` blocks until they are ready, THEN reads the
    clock. Usable standalone (``with PhaseTimer() as t: ...; t.seconds``)
    and as the engine under ``Trace.phase``."""

    def __init__(self):
        self.seconds = 0.0
        self._fence = None

    def __enter__(self):
        self._fence = None
        self.t0 = time.perf_counter()
        return self

    def fence(self, x):
        self._fence = x
        return x

    # make the timer callable so ``with trace.phase("round") as f:
    # state, m = f(rnd(state, batch))`` reads naturally
    __call__ = fence

    def __exit__(self, *exc):
        if self._fence is not None:
            import jax
            jax.block_until_ready(self._fence)
        self.seconds = time.perf_counter() - self.t0
        return False


class Trace:
    """Structured trace sink + phase fencing (DESIGN.md §13).

    ``path=None`` disables the file sink but keeps the fencing/timing
    behavior, so launchers run one code path. The meta header is written
    lazily on the first record so callers can build the trace before
    knowing every meta field (``meta.update`` is fine until then).
    """

    def __init__(self, path: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.path = Path(path) if path else None
        self.meta = dict(meta or {})
        self._phases: Dict[str, float] = {}
        self._fh = None
        self.n_records = 0

    # -- phases -----------------------------------------------------------

    @contextlib.contextmanager
    def phase(self, name: str):
        """Fenced, profiler-annotated phase. Durations accumulate under
        ``name`` until the next ``emit_round`` pops them — several
        phases (data, round, checkpoint) add up to one record."""
        import jax
        with jax.profiler.TraceAnnotation(name):
            with PhaseTimer() as t:
                yield t
        self._phases[name] = self._phases.get(name, 0.0) + t.seconds

    def phase_seconds(self, name: str) -> float:
        """Accumulated seconds of ``name`` since the last emit."""
        return self._phases.get(name, 0.0)

    def add_phase(self, name: str, seconds: float) -> None:
        """Record a DERIVED phase duration (e.g. the calibrated
        ``exchange_exposed``/``exchange_total`` split, DESIGN.md §14) so
        it rides the next ``emit_round`` like a fenced phase. Only for
        values computed FROM fenced measurements — raw ``time.time``
        deltas around jitted calls stay lies."""
        self._phases[name] = self._phases.get(name, 0.0) + float(seconds)

    def take_phases(self) -> Dict[str, float]:
        out, self._phases = self._phases, {}
        return out

    # -- the sink ---------------------------------------------------------

    def _write(self, rec: dict):
        self.n_records += 1
        if self.path is None:
            return
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "w")
            from repro import obs
            header = {"kind": "meta", "schema": obs.SCHEMA_VERSION}
            header.update(to_jsonable(self.meta))
            self._fh.write(json.dumps(header) + "\n")
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def emit_round(self, n: int, metrics: Optional[dict] = None,
                   kind: str = "round", **fields) -> dict:
        """One per-round record: accumulated phase durations + the
        round's metric dict (converted to JSON — callers fence first via
        ``phase``). Returns the record so launchers can print from it."""
        rec = {"kind": kind, "round": int(n),
               "phase_s": {k: round(v, 6)
                           for k, v in self.take_phases().items()},
               "metrics": to_jsonable(metrics or {})}
        rec.update(to_jsonable(fields))
        self._write(rec)
        return rec

    def emit(self, kind: str, **fields) -> dict:
        """A free-form event record (bench cells, dryrun phases)."""
        rec = {"kind": kind}
        rec.update(to_jsonable(fields))
        self._write(rec)
        return rec

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def exchange_phases(round_s: float, local_ref_s: float, exch_ref_s: float,
                    *, overlap: bool) -> Dict[str, float]:
    """The honest exchange-time split (DESIGN.md §14).

    Intra-graph fences cannot separate overlapped phases (XLA schedules
    them concurrently; on a serial CPU backend dispatch order would be
    reported as if it were concurrency). Instead the launcher calibrates
    two references ONCE — ``local_ref_s``: the same round built with
    comm='none' (pure local compute), ``exch_ref_s``: the exchange ops
    jitted standalone — and derives per round:

      exchange_exposed = max(0, round_s - local_ref_s)
          the exchange time actually ON the critical path this round;
      exchange_total   = the standalone exchange cost (overlap mode,
          floored at exposed so noise never reports >100% hiding), or
          == exposed for a barrier round (nothing is hidden by
          construction).

    Overlap efficiency = 1 - exposed/total. On a single-core host the
    backend executes serially, exposed ≈ total, and the efficiency is
    honestly ≈ 0 — the hiding is real only where the backend can run
    collectives concurrently with compute."""
    exposed = max(0.0, float(round_s) - float(local_ref_s))
    total = max(float(exch_ref_s), exposed) if overlap else exposed
    return {"exchange_exposed": exposed, "exchange_total": total}


@contextlib.contextmanager
def profile_span(path: Optional[str]):
    """Wrap a region in ``jax.profiler.start_trace`` (perfetto dump under
    ``path``); no-op when path is falsy. Profiler caveat (DESIGN.md §13):
    device annotations inside shard_map/jit come from XLA op metadata,
    so the host-side TraceAnnotations are the reliable phase boundaries
    on CPU."""
    if not path:
        yield
        return
    import jax
    Path(path).mkdir(parents=True, exist_ok=True)
    jax.profiler.start_trace(path)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
