"""Observability subsystem (DESIGN.md §13).

Three layers, one schema:

* device-side round metrics — the localsgd rounds emit a UNIFORM metric
  block every round (consensus distance, per-stream codec error mass,
  push-sum backlog mass, participation/delivery) regardless of
  topology/codec/fault configuration, so downstream consumers never
  branch on which keys exist;
* host-side phase tracing — ``Trace``/``Trace.phase`` fences with
  ``jax.block_until_ready`` before reading the clock (async dispatch
  makes unfenced deltas lies), annotates phases for the profiler, and
  appends structured JSONL records;
* reporting — ``repro.obs.report`` summarizes/validates a trace file;
  the benchmarks route their timing through the same sink.
"""
from repro.obs.trace import (PhaseTimer, Trace, exchange_phases,  # noqa: F401
                             profile_span, to_jsonable)

# bump when the JSONL record layout changes incompatibly; report.py
# refuses to --check traces from a different major schema
SCHEMA_VERSION = 1

# keys present in EVERY localsgd round's metrics dict, every
# configuration (the uniform contract, DESIGN.md §13). Per-stream keys
# ride alongside: wire_bytes/<stream> and codec_err/<stream> for every
# stream the round exchanges (params + averaged moment buffers).
ROUND_KEYS = (
    "loss", "grad_sq", "inner_steps",
    "wire_bytes", "wire_bytes_up", "wire_bytes_down",
    "wire_bytes_intra", "wire_bytes_inter",
    "consensus_sq", "consensus_sq_post",
    "backlog_mass", "participation", "delivery_rate",
    "participation_intra", "participation_inter",
    "delivery_rate_intra", "delivery_rate_inter",
)

# host-measured phase names the launchers emit (checkpoint only appears
# on rounds that save one; the exchange_* pair appears on calibrated
# localsgd runs — trace.exchange_phases, DESIGN.md §14: "exposed" is the
# exchange time on the round's critical path, "total" what the exchange
# costs standalone; overlap efficiency = 1 - exposed/total)
PHASES = ("data", "round", "step", "checkpoint",
          "exchange_exposed", "exchange_total")


def round_metric_keys(streams=("params",)):
    """The full uniform key set for a round exchanging ``streams``."""
    per = tuple(f"wire_bytes/{s}" for s in streams)
    per += tuple(f"codec_err/{s}" for s in streams)
    return ROUND_KEYS + per


def streams_of(metrics) -> tuple:
    """Recover the stream names from a round record's metric keys."""
    return tuple(sorted(k.split("/", 1)[1] for k in metrics
                        if k.startswith("wire_bytes/")))
