"""The paper's own experiment models (Sec 2.3, 3.2): small over-parameterized
networks used for the faithfulness experiments, expressed in the same config
system. ~100M 'deep learning driver' config included for examples/.
"""
from repro.configs.base import ArchConfig

CONFIGS = {
    # 1-layer net of Sec 3.2.1 / LeNet-scale stand-in: a small dense decoder
    # used by the deep-learning reproduction benchmarks.
    "paper-mlp": ArchConfig(
        name="paper-mlp",
        family="dense",
        source="[paper Sec 3.2.1]",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=1024,
        vocab_size=1024,
        dtype="float32",
    ),
    # ~100M-parameter config for the end-to-end local-SGD training example.
    "paper-lenet": ArchConfig(
        name="paper-lenet",
        family="dense",
        source="[paper Sec 3.2.2 scale-equivalent]",
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=32000,
        dtype="float32",
    ),
}
