"""Architecture configuration system.

Every assigned architecture gets one module in this package defining a
``CONFIG = ArchConfig(...)`` with the exact published shape, plus the
``reduced()`` method used by CPU smoke tests (2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (global, fixed).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A single architecture configuration.

    ``family`` selects the block builder in ``repro.models.api``:
      dense | moe | hybrid | ssm | vlm | audio
    """

    name: str
    family: str
    source: str  # citation, e.g. "[arXiv:2407.21783]"

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # Attention options -----------------------------------------------------
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # Sliding window used by the long_500k decode variant (see DESIGN.md).
    long_context_window: int = 8_192

    # MLP -------------------------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | relu2 | gelu

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "densemask"  # densemask (paper-era baseline) | dispatch

    # SSM / hybrid ----------------------------------------------------------
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    chunk_size: int = 128
    attn_every: int = 6  # hybrid: shared attention block every k mamba layers

    # xLSTM -----------------------------------------------------------------
    slstm_every: int = 4  # one sLSTM block per this many layers

    # Modality frontends (stubs) --------------------------------------------
    n_frames: int = 0   # audio: precomputed frame embeddings per example
    n_patches: int = 0  # vlm: precomputed patch embeddings per example
    n_encoder_layers: int = 0  # enc-dec (whisper)

    # Numerics / training ---------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    embed_impl: str = "onehot"  # onehot (baseline) | gather (§Perf)
    attn_impl: str = "blocked"  # blocked (pure-JAX) | pallas (TPU kernel)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ----------------------------------------------------------------- props
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (used for 6ND model FLOPs)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.mlp_type == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.is_moe:
            mlp_all = self.n_experts * mlp + d * self.n_experts  # + router
        else:
            mlp_all = mlp
        per_layer = attn + mlp_all
        if self.family == "ssm":
            per_layer = self._xlstm_layer_params()
        elif self.family == "hybrid":
            per_layer = self._mamba_layer_params()
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        per_exp = (3 if self.mlp_type == "swiglu" else 2) * d * self.d_ff
        per_layer = attn + self.top_k * per_exp + d * self.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    def _mamba_layer_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        return d * 2 * di + di * self.d_conv + di * (2 * n + 2) + di * d

    def _xlstm_layer_params(self) -> int:
        d = self.d_model
        di = self.expand * d
        return 2 * d * di + 4 * di + di * d  # rough: proj up/gates/down

    # ------------------------------------------------------------- reduction
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2))
        head_dim = d_model // n_heads
        n_experts = min(self.n_experts, 4) if self.is_moe else 0
        top_k = min(self.top_k, 2) if self.is_moe else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            n_experts=n_experts,
            top_k=top_k,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            chunk_size=8,
            attn_every=2,
            slstm_every=2,
            n_frames=min(self.n_frames, 16) if self.n_frames else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            long_context_window=64,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "phi3.5-moe-42b-a6.6b",
    "zamba2-7b",
    "internvl2-1b",
    "granite-moe-1b-a400m",
    "whisper-base",
    "llama3-405b",
    "qwen1.5-110b",
    "xlstm-1.3b",
    "qwen3-32b",
    "nemotron-4-15b",
]

_MODULE_FOR = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "zamba2-7b": "zamba2",
    "internvl2-1b": "internvl2",
    "granite-moe-1b-a400m": "granite_moe",
    "whisper-base": "whisper",
    "llama3-405b": "llama3_405b",
    "qwen1.5-110b": "qwen15_110b",
    "xlstm-1.3b": "xlstm",
    "qwen3-32b": "qwen3_32b",
    "nemotron-4-15b": "nemotron4_15b",
    # paper's own experiment models
    "paper-mlp": "paper",
    "paper-lenet": "paper",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    if hasattr(mod, "CONFIGS"):
        return mod.CONFIGS[arch_id]
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
