"""InternVL2-1B: InternViT (stubbed) + InternLM2/Qwen2-0.5B LM backbone.
[arXiv:2404.16821]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="[arXiv:2404.16821]",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,  # qwen2 backbone uses qkv bias
    n_patches=256,  # stub ViT patch embeddings per image
    mlp_type="swiglu",
)
