"""xLSTM-1.3B: stacked mLSTM blocks with interleaved sLSTM blocks.
[arXiv:2405.04517]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="[arXiv:2405.04517]",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,            # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    expand=2,
    slstm_every=4,     # one sLSTM per 4 layers (7:1-ish mix of the paper)
    chunk_size=128,
)
