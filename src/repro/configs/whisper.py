"""Whisper-base: enc-dec transformer; mel+conv frontend stubbed as
precomputed frame embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    source="[arXiv:2212.04356]",
    n_layers=6,            # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_frames=1500,         # stub conv frontend output length
    mlp_type="gelu",
    qkv_bias=True,
)
