"""Zamba2-7B: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    source="[arXiv:2411.15242]",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    expand=2,
    d_conv=4,
    attn_every=6,  # shared attention block applied every 6 mamba layers
    mlp_type="swiglu",
)
