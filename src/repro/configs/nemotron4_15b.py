"""Nemotron-4-15B: dense GQA, squared-ReLU MLP, 256k vocab.
[arXiv:2402.16819]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    source="[arXiv:2402.16819]",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="relu2",
    rope_theta=10_000.0,
)
