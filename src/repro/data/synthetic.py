"""Synthetic data pipeline.

* Token pipeline for LM training: deterministic PRNG batches with a
  Zipf-ish marginal and a learnable bigram structure (so training losses
  actually decrease), shaped for either the sync baseline (B, S) or
  local-SGD groups (G, T, b, S) / (G, b, S).
* Classification sets for the Fig-3 reproduction (intersected vs
  non-intersected 1-layer nets).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    seed: int = 0
    order: int = 1  # bigram structure

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # sparse-ish row-stochastic bigram table
        logits = rng.randn(v, 8)
        self._next = rng.randint(0, v, size=(v, 8))
        self._probs = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)

    def _sample_seq(self, rng) -> np.ndarray:
        v = self.vocab_size
        out = np.empty(self.seq_len, np.int32)
        t = rng.randint(v)
        for i in range(self.seq_len):
            out[i] = t
            j = rng.choice(8, p=self._probs[t])
            t = int(self._next[t, j])
        return out

    def batches(self, batch_shape: Tuple[int, ...],
                seed: Optional[int] = None) -> Iterator[dict]:
        """Yields {"tokens": int32 array of batch_shape + (seq_len,)}."""
        rng = np.random.RandomState(self.seed if seed is None else seed)
        n = int(np.prod(batch_shape))
        while True:
            toks = np.stack([self._sample_seq(rng) for _ in range(n)])
            yield {"tokens": toks.reshape(*batch_shape, self.seq_len)}


def fixed_group_batches(vocab_size: int, seq_len: int, n_groups: int,
                        per_group: int, seed: int = 0) -> dict:
    """A fixed (G, b, S) batch — each group's local dataset shard, for the
    paper-faithful full-batch local GD mode."""
    pipe = TokenPipeline(vocab_size, seq_len, seed)
    return next(pipe.batches((n_groups, per_group)))


# ---------------------------------------------------------------------------
# Fig 3: intersected vs non-intersected classification data
# ---------------------------------------------------------------------------


def gaussian_classification(n: int = 500, side: int = 28, n_classes: int = 10,
                            seed: int = 0):
    """MNIST-shaped synthetic set: class-conditional Gaussians on a
    side*side grid. Returns (x (n, side*side), labels (n,))."""
    rng = np.random.RandomState(seed)
    means = rng.randn(n_classes, side * side) * 2.0
    labels = rng.randint(0, n_classes, size=n)
    x = means[labels] + rng.randn(n, side * side)
    return x.astype(np.float32), labels.astype(np.int32)


def maxpool2x2_twice(x: np.ndarray, side: int = 28) -> np.ndarray:
    """The paper's 'Non-Intersected' variant: two 2x2 max-pools shrink the
    input to (side/4)^2 features so parameters (49*10=490) < samples (500)
    and the intersection assumption fails."""
    n = x.shape[0]
    img = x.reshape(n, side, side)
    for _ in range(2):
        s = img.shape[1] // 2
        img = img.reshape(n, s, 2, s, 2).max(axis=(2, 4))
    return img.reshape(n, -1)
