"""The paper's convex / analytic experiment problems.

* Beck–Teboulle synthetic feasibility (Sec 2.3.1): two losses on R^2 whose
  optimal sets touch only at the origin — the separation condition fails,
  so only the O(1/n) general-convex rate applies.
* Over-parameterized least squares (Sec 2.3.2): n=62 samples, d=2000
  features split over m nodes — every node interpolates, Assumptions 1-3
  hold, linear rate. The colon-cancer dataset is offline-unavailable, so we
  generate a synthetic matrix with the same (n, d) and conditioning style;
  the geometry (over-parameterized interpolation) is what the theory needs.
* Quartic loss variant (Sec 4 experiment): residual^4 — sub-linear local GD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Synthetic feasibility (Beck & Teboulle 2003 example, paper Fig 1-2a)
# ---------------------------------------------------------------------------


def beck_teboulle_losses() -> List[Callable]:
    """f1 = max(sqrt(x^2+(y-1)^2) - 1, 0)^2  (disk of radius 1 around (0,1))
    f2 = max(y, 0)^2                         (lower half plane y <= 0)
    S1 ∩ S2 = {(0,0)}; the sets meet tangentially (no separation)."""

    def f1(w):
        x, y = w[0], w[1]
        return jnp.maximum(jnp.sqrt(x ** 2 + (y - 1.0) ** 2 + 1e-30) - 1.0,
                           0.0) ** 2

    def f2(w):
        return jnp.maximum(w[1], 0.0) ** 2

    return [f1, f2]


# ---------------------------------------------------------------------------
# Over-parameterized regression (paper Fig 2b / Fig 5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RegressionProblem:
    xs: List[np.ndarray]   # per-node design matrices
    ys: List[np.ndarray]   # per-node targets
    power: int = 1         # loss = mean(residual^(2*power))

    @property
    def m(self) -> int:
        return len(self.xs)

    def local_losses(self) -> List[Callable]:
        fns = []
        for X, y in zip(self.xs, self.ys):
            Xj, yj = jnp.asarray(X), jnp.asarray(y)
            p = self.power

            def f(w, Xj=Xj, yj=yj, p=p):
                r = Xj @ w - yj
                return jnp.mean(jnp.square(r) ** p)

            fns.append(f)
        return fns

    def global_loss(self) -> Callable:
        fns = self.local_losses()

        def f(w):
            return sum(fn(w) for fn in fns) / len(fns)

        return f


def make_overparam_regression(n: int = 62, d: int = 2000, m: int = 2,
                              power: int = 1, seed: int = 0,
                              scale: float = 1.0) -> RegressionProblem:
    """Colon-cancer-shaped synthetic regression: n << d so each node's
    normal equations are degenerate and interpolating solutions exist
    (Assumption 1 holds: any w with X w = y on all nodes is common-optimal,
    and such w exist since rank(X) <= n < d)."""
    rng = np.random.RandomState(seed)
    X = rng.randn(n, d).astype(np.float64) * scale / np.sqrt(d)
    w_true = rng.randn(d)
    y = X @ w_true  # realizable -> zero-loss intersection non-empty
    idx = np.array_split(np.arange(n), m)
    return RegressionProblem(
        xs=[X[i] for i in idx], ys=[y[i] for i in idx], power=power)


# ---------------------------------------------------------------------------
# Random intersecting quadratics (for the hypothesis property tests)
# ---------------------------------------------------------------------------


def random_intersecting_quadratics(key, m: int, d: int, rank: int):
    """m quadratics f_i(w) = ||A_i (w - w*)||^2 / 2 sharing minimizer set
    containing w* (rank < d makes S_i affine subspaces through w*).
    Returns (losses, w_star, As)."""
    keys = jax.random.split(key, m + 1)
    w_star = jax.random.normal(keys[0], (d,))
    losses, mats = [], []
    for i in range(m):
        A = jax.random.normal(keys[i + 1], (rank, d)) / np.sqrt(d)
        mats.append(A)

        def f(w, A=A):
            r = A @ (w - w_star)
            return 0.5 * jnp.sum(r ** 2)

        losses.append(f)
    return losses, w_star, mats


def distance_to_intersection(w, mats, w_star):
    """d(w, S) where S = {w: A_i (w - w*) = 0 for all i}."""
    A = jnp.concatenate(mats, axis=0)
    # projection of (w - w*) onto row space of stacked A
    u, s, vt = jnp.linalg.svd(A, full_matrices=False)
    keep = s > 1e-8 * s.max()
    V = vt[keep]
    diff = w - w_star
    proj = V.T @ (V @ diff)
    return jnp.linalg.norm(proj)
