"""Reference host-side driver for Alg 1 on analytic (convex) problems.

Used by the paper-validation benchmarks and tests. The T local GD steps
run inside ONE jitted lax.scan / lax.while_loop per (node, round) — no
per-step Python dispatch, which matters for the paper's T=100..inf runs.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def make_local_T(f: Callable, lr: float, T: int):
    """w -> (w_T, gsq_traj (T,)) after T local GD steps."""
    g = jax.grad(f)

    @jax.jit
    def run(w):
        def step(w, _):
            gi = g(w)
            return w - lr * gi, jnp.sum(gi ** 2)

        return jax.lax.scan(step, w, None, length=T)

    return run


def make_local_threshold(f: Callable, lr: float, eps: float,
                         max_inner: int):
    """w -> (w_out, steps) : local GD until ||grad||^2 <= eps (T_i=inf)."""
    g = jax.grad(f)

    @jax.jit
    def run(w):
        def cond(c):
            w, n, gsq = c
            return jnp.logical_and(n < max_inner, gsq > eps)

        def body(c):
            w, n, _ = c
            gi = g(w)
            w = w - lr * gi
            gi2 = g(w)
            return w, n + 1, jnp.sum(gi2 ** 2)

        g0 = g(w)
        w, n, _ = jax.lax.while_loop(
            cond, body, (w, jnp.zeros((), jnp.int32), jnp.sum(g0 ** 2)))
        return w, n

    return run


def run_alg1(losses: List[Callable], w0, lr: float, T: Optional[int],
             rounds: int, threshold: Optional[float] = None,
             max_inner: int = 100_000, record_local_traj: bool = False,
             stop_below: Optional[float] = None) -> dict:
    """Model averaging (paper Alg 1) on a list of local losses.

    T=None + threshold=eps -> the paper's T_i = infinity mode.
    Returns per-round global ||grad f||^2, f values, inner-step counts,
    final iterate, and node 0's local gsq trajectory if requested."""
    if threshold is not None:
        runners = [make_local_threshold(f, lr, threshold, max_inner)
                   for f in losses]
    else:
        runners = [make_local_T(f, lr, T) for f in losses]
    grads = [jax.jit(jax.grad(f)) for f in losses]
    fvals = [jax.jit(f) for f in losses]

    w = jnp.asarray(w0)
    gsq, fs, inner, local_traj = [], [], [], []
    for _ in range(rounds):
        locals_, counts = [], []
        for i, run in enumerate(runners):
            if threshold is not None:
                wi, n = run(w)
                counts.append(int(n))
            else:
                wi, traj = run(w)
                counts.append(T)
                if record_local_traj and i == 0:
                    local_traj.extend(np.asarray(traj).tolist())
            locals_.append(wi)
        w = jnp.mean(jnp.stack(locals_), axis=0)
        g_glob = jnp.mean(jnp.stack([g(w) for g in grads]), axis=0)
        gsq.append(float(jnp.sum(g_glob ** 2)))
        fs.append(float(np.mean([fv(w) for fv in fvals])))
        inner.append(counts)
        if stop_below is not None and gsq[-1] <= stop_below:
            break
    return {"gsq": gsq, "f": fs, "inner": inner, "w": w,
            "local_traj": local_traj}


def rounds_to(gsq_list, tol) -> Optional[int]:
    for i, g in enumerate(gsq_list):
        if g <= tol:
            return i + 1
    return None
