"""Quantitative theory from the paper.

* Lemma 1 decrement bound, Theorem 3 linear rate rho.
* Sec 4 trade-off: cost-optimal number of local steps T* for
  - linearly convergent local GD  h(t) = beta^t     (Lambert-W closed form)
  - sub-linearly convergent       h(t) = (1+a t)^-beta (algebraic root)
* On-the-fly detection of the local decay order from a gradient-norm
  trajectory (used by core.controller.AdaptiveT).

Everything is plain numpy-compatible scalar math (host side).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Rates
# ---------------------------------------------------------------------------


def alpha(eta: float, L: float) -> float:
    """alpha_i = eta_i (2/L_i - eta_i) from Lemma 1; > 0 iff eta < 2/L."""
    return eta * (2.0 / L - eta)


def theorem3_rho(etas, Ls, mus, c: float) -> float:
    """Linear rate rho = sqrt(1 - c^{-1} min_i alpha_i mu_i^2)."""
    vals = [min(alpha(e, L) * mu ** 2, 1.0)
            for e, L, mu in zip(etas, Ls, mus)]
    return math.sqrt(max(1.0 - min(vals) / c, 0.0))


# ---------------------------------------------------------------------------
# Lambert W, negative real branch W_- on [-1/e, 0)
# ---------------------------------------------------------------------------


def lambert_w_neg(x: float, iters: int = 64) -> float:
    """W_-(x): the branch with W <= -1, solving W e^W = x for x in [-1/e, 0)."""
    if not (-1.0 / math.e <= x < 0.0):
        raise ValueError(f"W_- domain is [-1/e, 0), got {x}")
    if x == -1.0 / math.e:
        return -1.0
    # asymptotic init: W_- = log(-x) - log(-log(-x))
    lx = math.log(-x)
    w = lx - math.log(-lx) if lx < -1.0 else -1.5
    for _ in range(iters):  # Halley
        ew = math.exp(w)
        f = w * ew - x
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        if denom == 0.0:
            break
        w_new = w - f / denom
        if abs(w_new - w) < 1e-15:
            w = w_new
            break
        w = w_new
    return w


# ---------------------------------------------------------------------------
# Cost model (Sec 4):  C_total <= K * (1 + r T) / sum_{t<T} h(t)
# ---------------------------------------------------------------------------


def cost_bound(T: int, r: float, h) -> float:
    s = sum(h(t) for t in range(int(T)))
    return (1.0 + r * T) / max(s, 1e-300)


def t_star_linear(beta: float, r: float) -> float:
    """Exact T* for h(t)=beta^t via the paper's Lambert-W formula."""
    if not (0.0 < beta < 1.0):
        raise ValueError(beta)
    arg = -math.exp(-1.0) * beta ** (1.0 / r)
    if arg == 0.0:  # beta^(1/r) underflowed (r very small)
        return t_star_linear_asymptotic(beta, r)
    arg = max(arg, -1.0 / math.e)  # clamp fp error
    w = lambert_w_neg(arg)
    return (1.0 + w) / math.log(beta) - 1.0 / r


def t_star_linear_asymptotic(beta: float, r: float) -> float:
    """T* ~ log(1 + log(1/beta)/r) / log(1/beta) for r << 1.

    NOTE (reproduction erratum): the paper prints the asymptotic as
    ``log(1 + log(1/beta)/r) + o(1)``, but expanding its own exact
    Lambert-W expression,
        1 + W^-(-e^{-1} beta^{1/r}) = (1/r) log(beta)
                                      - log(1 + log(1/beta)/r) + o(1),
    so the 1/log(beta) prefactor does NOT cancel and
        T* = log(1 + log(1/beta)/r) / log(1/beta) + o(1).
    Brute-force minimization of the cost bound confirms the corrected
    form (see tests/test_theory.py and benchmarks/fig5_quartic.py)."""
    return math.log(1.0 + math.log(1.0 / beta) / r) / math.log(1.0 / beta)


def t_star_sublinear(a: float, beta: float, r: float,
                     t_max: float = 1e12) -> float:
    """T* for h(t)=(1+at)^-beta: unique positive root of
    r((1+aT)^beta - 1) - a(beta + beta r T - 1) = 0  (paper Eq. 6)."""
    if beta <= 1.0 or a <= 0.0:
        raise ValueError((a, beta))

    def g(T):
        return r * ((1.0 + a * T) ** beta - 1.0) - a * (beta + beta * r * T - 1.0)

    lo, hi = 0.0, 1.0
    while g(hi) < 0.0 and hi < t_max:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if g(mid) < 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def t_star_sublinear_asymptotic(a: float, beta: float, r: float) -> float:
    """T* ~ ((a(beta-1)/r)^{1/beta} - 1)/a for r << 1."""
    return ((a * (beta - 1.0) / r) ** (1.0 / beta) - 1.0) / a


def t_star_numeric(r: float, h, t_max: int = 1_000_000) -> int:
    """Brute-force argmin of the cost bound (for validating the formulas)."""
    best_t, best = 1, cost_bound(1, r, h)
    t, s = 1, h(0)
    cost_prev = best
    while t < t_max:
        s += h(t)
        t += 1
        c = (1.0 + r * t) / s
        if c < best:
            best, best_t = c, t
        if c > 4.0 * best and t > 4 * best_t:
            break
    return best_t


def quartic_h_params(l: int = 2) -> Tuple[float, float]:
    """For local loss ~ x^{2l}: h(t) ~ (1+at)^-beta with a = 2l-2,
    beta=(2l-1)/(2l-2) (paper Sec 4)."""
    a = 2.0 * l - 2.0
    beta = (2.0 * l - 1.0) / (2.0 * l - 2.0)
    return a, beta


# ---------------------------------------------------------------------------
# Decay-order detection (for the adaptive controller)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecayFit:
    kind: str          # "linear" | "sublinear"
    beta: float        # decay base (linear) or exponent (sublinear)
    a: float           # sublinear scale (1 for linear)
    r2_linear: float
    r2_sublinear: float


def _lstsq_r2(x: np.ndarray, y: np.ndarray) -> Tuple[float, float, float]:
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2)) or 1e-30
    return float(coef[0]), float(coef[1]), 1.0 - ss_res / ss_tot


def fit_decay(grad_sq_traj: Sequence[float]) -> Optional[DecayFit]:
    """Fit h(t) = g²(t)/g²(0) to linear (beta^t) vs sublinear (1+at)^-beta.

    Returns None if the trajectory is too short or degenerate.
    """
    g = np.asarray(grad_sq_traj, dtype=np.float64)
    g = g[np.isfinite(g) & (g > 0)]
    if g.size < 4 or not np.isfinite(g).all():
        return None
    # a clearly diverging trajectory has no decay order (noisy real-model
    # trajectories may end slightly above where they started — keep those)
    if g[-1] > 10.0 * g[0]:
        return None
    h = g / g[0]
    t = np.arange(g.size, dtype=np.float64)
    # linear: log h = t log beta
    slope_l, _, r2_l = _lstsq_r2(t, np.log(h))
    beta_lin = float(np.exp(min(slope_l, -1e-12)))
    # sublinear: log h = -beta log(1+a t); fit with a from curvature search
    best = (-np.inf, 1.0, 1.0)
    for a in (0.1, 0.3, 1.0, 2.0, 4.0, 10.0):
        slope_s, _, r2_s = _lstsq_r2(np.log1p(a * t), np.log(h))
        if r2_s > best[0]:
            best = (r2_s, a, max(-slope_s, 1.0 + 1e-6))
    r2_s, a_s, beta_s = best
    if not (math.isfinite(r2_l) or math.isfinite(r2_s)):
        return None
    if r2_l >= r2_s or not math.isfinite(r2_s):
        return DecayFit("linear", beta_lin, 1.0, r2_l, r2_s)
    return DecayFit("sublinear", max(beta_s, 1.0 + 1e-6), a_s, r2_l, r2_s)


def t_star_from_fit(fit: DecayFit, r: float) -> float:
    if fit.kind == "linear":
        return max(t_star_linear(min(max(fit.beta, 1e-9), 1 - 1e-9), r), 1.0)
    return max(t_star_sublinear(fit.a, fit.beta, r), 1.0)
