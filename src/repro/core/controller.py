"""Adaptive-T controller (paper Sec 4, 'detect the order of local convergence
on the fly, then use these estimates as a guideline to adjust T')."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import theory


@dataclasses.dataclass
class AdaptiveT:
    """Adjusts the number of local steps between communication rounds.

    r: cost ratio C_g / C_c (local step cost / communication cost). Two
    ways to instantiate it:

    * roofline estimate (the fallback): r = step_time_est /
      allreduce_time_est from the dry-run HLO terms (launch/roofline.py).
    * measured, codec-aware: ``AdaptiveT.from_comm_bytes`` takes the EXACT
      per-round wire bytes the round's Exchange reports
      (``metrics["wire_bytes"]`` / ``Exchange.wire_bytes_per_round``) and
      a link bandwidth — so switching codec (int8 cuts bytes ~4x) changes
      r, and with it the cost-optimal T*.
    """

    r: float
    t_min: int = 1
    t_max: int = 10_000
    ema: float = 0.5                    # smoothing of T across rounds
    _t: float = 10.0
    history: Optional[List] = None

    def __post_init__(self):
        self.history = []

    @classmethod
    def from_comm_bytes(cls, step_time_s: float, wire_bytes_per_round: float,
                        bandwidth_bytes_per_s: float,
                        **kw) -> "AdaptiveT":
        """r from MEASURED communication: C_c = wire_bytes / bandwidth.

        ``wire_bytes_per_round`` is the codec-aware payload the comm
        subsystem accounts per round; ``step_time_s`` the measured (or
        roofline) cost of one local step."""
        comm_s = wire_bytes_per_round / bandwidth_bytes_per_s
        if comm_s <= 0:
            raise ValueError(f"non-positive comm time {comm_s} "
                             "(zero wire bytes? the 'none' topology has "
                             "no communication cost to adapt T against)")
        return cls(r=step_time_s / comm_s, **kw)

    @classmethod
    def from_exchange(cls, step_time_s: float, exchange, n_params: int,
                      moment_sizes=None, *,
                      bandwidth_bytes_per_s: float = 50e9,
                      inter_bandwidth_bytes_per_s: Optional[float] = None,
                      delivery_rate: Optional[float] = None,
                      **kw) -> "AdaptiveT":
        """r priced from an Exchange's OWN stream-resolved accounting
        (DESIGN.md §10): the payload is the params through the params
        codec plus every moment stream through the moment codec —
        switching ``moment_codec`` (int8 moments cut adamw's dominant
        wire term ~4x) changes r, and with it the cost-optimal T*.
        ``moment_sizes``: {stream: elems} of the moment buffers the round
        averages (omit for params-only / average_opt_state=False).

        On a lossy network (DESIGN.md §12) a round's accounted bytes
        understate the cost of USEFUL communication: a payload that
        needed 1/delivery attempts (server retries from the pushed
        buffer) — or whose queued mass arrives a round late
        (push_sum's delivered-edge pricing) — buys less consensus per
        round. ``delivery_rate`` (default: the exchange's own FaultPlan
        expectation) divides the accounted bytes by the expected
        delivery fraction, so faults make communication more expensive
        per useful round, shrink r, and push T* UP — fewer, longer
        rounds on an unreliable network.

        Hierarchical exchanges (DESIGN.md §16) price the two tiers on
        their OWN links: the intra-pod bytes over
        ``bandwidth_bytes_per_s`` at the intra tier's delivery rate, the
        cross-pod bytes over ``inter_bandwidth_bytes_per_s`` (the slower
        DCN; defaults to the intra bandwidth) at the inter tier's — a
        lossy DCN raises only the cross-pod term, which is usually the
        dominant one, so T* still moves the right way."""
        if getattr(exchange, "hierarchical", False):
            by_tier = exchange.wire_bytes_by_tier(
                n_params, moment_sizes=moment_sizes)
            bw_x = inter_bandwidth_bytes_per_s or bandwidth_bytes_per_s
            d_i = exchange.delivery_rate_intra
            d_x = exchange.delivery_rate_inter
            if not (0.0 < d_i <= 1.0 and 0.0 < d_x <= 1.0):
                raise ValueError(f"per-tier delivery rates ({d_i}, {d_x}) "
                                 "not in (0, 1]")
            comm_s = (by_tier["intra"] / (bandwidth_bytes_per_s * d_i)
                      + by_tier["inter"] / (bw_x * d_x))
            if comm_s <= 0:
                raise ValueError(f"non-positive comm time {comm_s}")
            return cls(r=step_time_s / comm_s, **kw)
        wire = exchange.wire_bytes_per_round(n_params,
                                             moment_sizes=moment_sizes)
        if delivery_rate is None:
            delivery_rate = getattr(exchange, "delivery_rate", 1.0)
        if not 0.0 < delivery_rate <= 1.0:
            raise ValueError(f"delivery_rate {delivery_rate} not in (0, 1]")
        return cls.from_comm_bytes(step_time_s, wire / delivery_rate,
                                   bandwidth_bytes_per_s, **kw)

    @property
    def t(self) -> int:
        return int(np.clip(round(self._t), self.t_min, self.t_max))

    def update(self, grad_sq_traj) -> int:
        """Feed the last round's per-step local ||grad||^2 trajectory.
        Degenerate trajectories (diverged, constant, too short) leave T
        unchanged."""
        fit = theory.fit_decay(np.asarray(grad_sq_traj))
        if fit is not None:
            try:
                t_star = theory.t_star_from_fit(fit, self.r)
            except (ValueError, OverflowError):
                return self.t
            self._t = self.ema * self._t + (1.0 - self.ema) * t_star
            self.history.append((fit, t_star, self.t))
        return self.t


@dataclasses.dataclass
class OnlineT:
    """Per-round T controller driven by the measured round telemetry
    (``--adaptive-t online``, DESIGN.md §14).

    ``AdaptiveT`` prices the cost ratio r ONCE from static wire bytes and
    then only re-fits the local decay order. With the §13/§14 signal set
    complete — consensus distance pre/post exchange, per-stream codec
    error mass, and honestly fenced phase times — the tradeoff can be
    re-estimated every round from what actually happened:

    * **cost ratio online**: r̂ = EMA of (local_s / T) / exchange_s from
      the fenced phase times, so codec switches, overlap hiding, and
      real link speed all move r without a bandwidth guess;
    * **consensus guard**: γ̂ = EMA of (consensus_post + codec_err) /
      consensus_pre measures how much deviation one exchange actually
      retires. Weak mixing (γ̂ → 1: lossy codec, sparse gossip) means
      long local bursts drift apart faster than rounds can pull them
      back — T is scaled by (1 − γ̂);
    * **convergence relief**: as the run converges the groups agree,
      exchanges buy little, and rounds should lengthen — T is scaled by
      sqrt(c₀ / consensus_pre) (clipped to [1, relief_max]), which ramps
      T up as consensus distance falls below its initial mass c₀. Fewer
      rounds at the tail is where online-T beats static T* on total
      wire bytes;
    * **divergence guard** (DESIGN.md §14): the round map for consensus
      mass is c ← γ̂ · c · e^{a·T} — local steps grow deviation at a
      measured per-step exponent a (drift gain = consensus_pre of this
      round over consensus_post of the previous one, spread over the T
      steps between them), the exchange contracts it by γ̂. The map is
      stable only for T < ln(1/γ̂)/a; when the measured â is positive T
      is CLAMPED to guard_margin · ln(1/γ̂)/â. The multiplicative
      (1 − γ̂) factor slows T growth but cannot bound it when the
      relief/cost terms push harder; the clamp is what actually keeps
      aggressive-lr decentralized runs (the §14 divergent corner) from
      compounding consensus mass round over round.

    The cost-optimal core is still the paper's Sec-4 T* from the fitted
    decay order; the two telemetry factors multiply it, and the result
    is EMA-smoothed exactly like ``AdaptiveT``. Missing signals
    degrade gracefully: with no timing the ratio keeps its prior, with
    no consensus telemetry both factors stay 1 and the controller
    reduces to ``AdaptiveT`` with a measured r.
    """

    r: float = 1.0
    t_min: int = 1
    t_max: int = 10_000
    ema: float = 0.5            # smoothing of T across rounds
    r_ema: float = 0.7          # smoothing of the measured cost ratio
    guard_ema: float = 0.5      # smoothing of the consensus guard
    relief_max: float = 8.0     # cap on the convergence relief factor
    guard_margin: float = 0.5   # stay this far inside the stability edge
    _t: float = 10.0
    _gamma: float = 0.0
    _c0: Optional[float] = None
    _a: float = 0.0             # EMA'd per-step drift exponent â
    _prev_post: Optional[float] = None
    history: Optional[List] = None

    def __post_init__(self):
        self.history = []

    @property
    def t(self) -> int:
        return int(np.clip(round(self._t), self.t_min, self.t_max))

    def update(self, grad_sq_traj, *, t_used: int,
               local_s: Optional[float] = None,
               exchange_s: Optional[float] = None,
               consensus_pre: Optional[float] = None,
               consensus_post: Optional[float] = None,
               codec_err: float = 0.0) -> int:
        """Feed one round's telemetry; returns the next round's T.

        ``grad_sq_traj``: per-step local ||grad||² trajectory (metrics
        ``grad_sq_traj``, group-mean). ``t_used``: the T the round
        actually ran. ``local_s`` / ``exchange_s``: fenced phase times
        (``local_total_s``, ``exchange_total_s``). ``consensus_pre`` /
        ``consensus_post``: group-mean ``consensus_sq`` /
        ``consensus_sq_post``. ``codec_err``: summed group-mean
        ``codec_err/*`` mass."""
        # -- cost ratio from the fenced phase times -----------------------
        if (local_s is not None and exchange_s is not None
                and local_s > 0.0 and exchange_s > 0.0 and t_used >= 1):
            r_meas = (local_s / t_used) / exchange_s
            self.r = self.r_ema * self.r + (1.0 - self.r_ema) * r_meas
        # -- consensus guard ----------------------------------------------
        if (consensus_pre is not None and consensus_post is not None
                and consensus_pre > 0.0):
            gamma = float(np.clip(
                (consensus_post + codec_err) / consensus_pre, 0.0, 0.95))
            self._gamma = (self.guard_ema * self._gamma
                           + (1.0 - self.guard_ema) * gamma)
        # -- divergence guard: measured per-step drift exponent -----------
        if (consensus_pre is not None and self._prev_post is not None
                and self._prev_post > 0.0 and consensus_pre > 0.0
                and t_used >= 1):
            drift_gain = consensus_pre / self._prev_post
            a_meas = float(np.log(max(drift_gain, 1.0 + 1e-6))) / t_used
            self._a = (self.guard_ema * self._a
                       + (1.0 - self.guard_ema) * a_meas)
        if consensus_post is not None:
            self._prev_post = float(consensus_post)
        # -- convergence relief -------------------------------------------
        relief = 1.0
        if consensus_pre is not None and consensus_pre > 0.0:
            if self._c0 is None:
                self._c0 = float(consensus_pre)
            relief = float(np.clip(np.sqrt(self._c0 / consensus_pre),
                                   1.0, self.relief_max))
        # -- cost-optimal core (paper Sec 4) ------------------------------
        fit = theory.fit_decay(np.asarray(grad_sq_traj))
        t_cost = None
        if fit is not None:
            try:
                t_cost = theory.t_star_from_fit(fit, self.r)
            except (ValueError, OverflowError):
                t_cost = None
        if t_cost is None:
            t_cost = self._t
        target = t_cost * (1.0 - self._gamma) * relief
        self._t = self.ema * self._t + (1.0 - self.ema) * target
        # -- stability clamp: T < guard_margin * ln(1/γ̂) / â --------------
        t_guard = None
        if self._a > 0.0 and self._gamma > 0.0:
            t_guard = int(np.floor(
                self.guard_margin
                * np.log(1.0 / (self._gamma + 1e-6)) / self._a))
            self._t = min(self._t, float(max(t_guard, self.t_min)))
        self.history.append({"r": self.r, "gamma": self._gamma,
                             "relief": relief, "t_cost": t_cost,
                             "a": self._a, "t_guard": t_guard,
                             "t": self.t})
        return self.t
