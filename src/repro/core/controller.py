"""Adaptive-T controller (paper Sec 4, 'detect the order of local convergence
on the fly, then use these estimates as a guideline to adjust T')."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import theory


@dataclasses.dataclass
class AdaptiveT:
    """Adjusts the number of local steps between communication rounds.

    r: cost ratio C_g / C_c (local step cost / communication cost). On the
    production mesh this is instantiated from the dry-run roofline terms
    (see launch/roofline.py: r = step_time_est / allreduce_time_est).
    """

    r: float
    t_min: int = 1
    t_max: int = 10_000
    ema: float = 0.5                    # smoothing of T across rounds
    _t: float = 10.0
    history: Optional[List] = None

    def __post_init__(self):
        self.history = []

    @property
    def t(self) -> int:
        return int(np.clip(round(self._t), self.t_min, self.t_max))

    def update(self, grad_sq_traj) -> int:
        """Feed the last round's per-step local ||grad||^2 trajectory.
        Degenerate trajectories (diverged, constant, too short) leave T
        unchanged."""
        fit = theory.fit_decay(np.asarray(grad_sq_traj))
        if fit is not None:
            try:
                t_star = theory.t_star_from_fit(fit, self.r)
            except (ValueError, OverflowError):
                return self.t
            self._t = self.ema * self._t + (1.0 - self.ema) * t_star
            self.history.append((fit, t_star, self.t))
        return self.t
