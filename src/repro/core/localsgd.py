"""The paper's contribution as a composable JAX module (Alg 1).

Model-averaging distributed optimization:

    worker i:  pull x_n; run T_i local GD steps (or until ||grad||^2 <= eps,
               the paper's "Threshold" / T_i = infinity mode); push result
    server:    x_{n+1} = (1/m) sum_i x_n^{i,T_i}

SPMD mapping (see DESIGN.md): every state leaf carries a leading group axis
G sharded over the ("pod","data") mesh axes. Local steps are vmapped over G
— zero cross-group collectives. ``average_groups`` (mean over G + broadcast)
is the ONLY cross-pod/data communication and lowers to one all-reduce of the
model per round, instead of one gradient all-reduce per step (the
conventional baseline, also provided here as ``make_sync_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    n_groups: int                 # m in the paper
    inner_steps: int = 1          # T (uniform), or max T when t_i is set
    # Per-node T_i (paper Alg 1 allows a different count per worker i).
    # Tuple of length n_groups; each group runs its own T_i <= inner_steps
    # (implemented as a masked scan to the max — SPMD-friendly).
    t_i: Optional[Tuple[int, ...]] = None
    threshold: Optional[float] = None  # if set: T_i = inf mode, stop at
                                       # ||grad_i||^2 <= threshold
    max_inner: int = 1_000        # hard cap for threshold mode
    inner_mode: str = "fixed_batch"    # fixed_batch (paper GD) | microbatch
    average_opt_state: bool = True


class TrainState(dict):
    """{"params": pytree, "opt": pytree} — plain dict for pytree-ness."""


def replicate(tree, n_groups: int):
    """Tile a pytree with a leading group axis (all groups identical)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), tree)


def average_groups(tree):
    """Model averaging: mean over the leading G axis, broadcast back.

    This is the paper's server combination step and the ONLY cross-group
    collective in the local round.
    """
    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape)

    return jax.tree.map(avg, tree)


def grad_sq_norm(grads) -> jax.Array:
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(grads))


# ---------------------------------------------------------------------------
# Local round = T local steps (vmapped over groups) + one averaging step
# ---------------------------------------------------------------------------


def make_local_round(loss_fn: Callable, opt: Optimizer, cfg: LocalSGDConfig):
    """Build ``round(state_G, batch_G) -> (state_G, metrics)``.

    loss_fn(params, batch) -> scalar.
    state_G: {"params","opt"} with leading G axis on every leaf.
    batch_G: leaves with leading axes (G, ...) for fixed_batch or
             (G, T, ...) for microbatch mode.
    """
    vg = jax.value_and_grad(loss_fn)

    def fixed_batch_group(state, batch, t_i=None):
        """T_i steps of full-batch local GD on this group's shard.

        t_i: optional per-group scalar — steps beyond t_i keep the state
        unchanged (masked scan to cfg.inner_steps, the max)."""
        if cfg.threshold is not None:
            def cond(carry):
                state, t, gsq, _ = carry
                return jnp.logical_and(t < cfg.max_inner,
                                       gsq > cfg.threshold)

            def body(carry):
                state, t, _, loss0 = carry
                loss, g = vg(state["params"], batch)
                new_p, new_o = opt.step(state["params"], g, state["opt"])
                return ({"params": new_p, "opt": new_o}, t + 1,
                        grad_sq_norm(g), loss)

            loss0, g0 = vg(state["params"], batch)
            state, t, gsq, loss = jax.lax.while_loop(
                cond, body, (state, jnp.zeros((), jnp.int32),
                             grad_sq_norm(g0), loss0))
            return state, {"loss": loss, "inner_steps": t, "grad_sq": gsq}

        def inner(state, t):
            loss, g = vg(state["params"], batch)
            new_p, new_o = opt.step(state["params"], g, state["opt"])
            new = {"params": new_p, "opt": new_o}
            if t_i is not None:
                keep = t < t_i
                new = jax.tree.map(
                    lambda a, b: jnp.where(keep, a, b), new, state)
            return new, (loss, grad_sq_norm(g))

        state, (losses, gsqs) = jax.lax.scan(
            inner, state, jnp.arange(cfg.inner_steps))
        n_steps = jnp.asarray(cfg.inner_steps) if t_i is None else t_i
        return state, {"loss": losses[-1],
                       "inner_steps": n_steps,
                       "grad_sq": gsqs[-1],
                       "grad_sq_first": gsqs[0],
                       "grad_sq_traj": gsqs}

    def microbatch_group(state, batches):
        """T_i steps, one microbatch per step (practical local SGD)."""
        def inner(state, mb):
            loss, g = vg(state["params"], mb)
            new_p, new_o = opt.step(state["params"], g, state["opt"])
            return {"params": new_p, "opt": new_o}, (loss, grad_sq_norm(g))

        state, (losses, gsqs) = jax.lax.scan(inner, state, batches)
        return state, {"loss": losses[-1],
                       "inner_steps": jnp.asarray(cfg.inner_steps),
                       "grad_sq": gsqs[-1],
                       "grad_sq_first": gsqs[0],
                       "grad_sq_traj": gsqs}

    group_fn = fixed_batch_group if cfg.inner_mode == "fixed_batch" \
        else microbatch_group

    def round_(state_G, batch_G):
        if cfg.t_i is not None and cfg.inner_mode == "fixed_batch":
            assert len(cfg.t_i) == cfg.n_groups, cfg.t_i
            assert max(cfg.t_i) <= cfg.inner_steps, cfg.t_i
            t_vec = jnp.asarray(cfg.t_i, jnp.int32)
            state_G, metrics = jax.vmap(fixed_batch_group)(
                state_G, batch_G, t_vec)
        else:
            state_G, metrics = jax.vmap(group_fn)(state_G, batch_G)
        # ---- communication: the paper's server averaging ------------------
        new_params = average_groups(state_G["params"])
        if cfg.average_opt_state:
            new_opt = average_groups(state_G["opt"])
        else:
            new_opt = state_G["opt"]
        return {"params": new_params, "opt": new_opt}, metrics

    return round_


# ---------------------------------------------------------------------------
# Conventional baseline: synchronous data parallelism (all-reduce per step)
# ---------------------------------------------------------------------------


def make_sync_step(loss_fn: Callable, opt: Optimizer):
    """Standard DP: grads averaged across the whole batch every step.

    With params replicated and the batch sharded over ("pod","data"), XLA
    inserts a gradient all-reduce per step — the conventional schedule the
    paper compares against.
    """
    vg = jax.value_and_grad(loss_fn)

    def step(state, batch):
        loss, g = vg(state["params"], batch)
        new_p, new_o = opt.step(state["params"], g, state["opt"])
        return {"params": new_p, "opt": new_o}, {"loss": loss,
                                                 "grad_sq": grad_sq_norm(g)}

    return step


# ---------------------------------------------------------------------------
# Host-level driver (for real runs on small configs / examples)
# ---------------------------------------------------------------------------


def init_state(params, opt: Optimizer, n_groups: Optional[int] = None):
    state = {"params": params, "opt": opt.init(params)}
    if n_groups:
        state = replicate(state, n_groups)
    return state


def server_params(state_G):
    """The averaged (server) model from a grouped state."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), state_G["params"])
