"""The paper's contribution as a composable JAX module (Alg 1).

Model-averaging distributed optimization:

    worker i:  pull x_n; run T_i local GD steps (or until ||grad||^2 <= eps,
               the paper's "Threshold" / T_i = infinity mode); push result
    server:    x_{n+1} = (1/m) sum_i x_n^{i,T_i}

SPMD mapping (see DESIGN.md): every state leaf carries a leading group axis
G sharded over the ("pod","data") mesh axes. Local steps are vmapped over G
— zero cross-group collectives. The per-round model exchange is the ONLY
cross-pod/data communication; it is routed through the pluggable
``repro.comm.Exchange`` layer (DESIGN.md §8) — topology x codec + exact
wire-byte accounting — and defaults to server/fp32, which is bit-exact
with the original ``average_groups`` (mean over G + broadcast): one
all-reduce of the model per round, instead of one gradient all-reduce per
step (the conventional baseline, also provided here as ``make_sync_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm as comm_mod
from repro.optim import Optimizer, map_moments, packing


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    n_groups: int                 # m in the paper
    inner_steps: int = 1          # T (uniform), or max T when t_i is set
    # Per-node T_i (paper Alg 1 allows a different count per worker i).
    # Tuple of length n_groups; each group runs its own T_i <= inner_steps
    # (implemented as a masked scan to the max — SPMD-friendly).
    t_i: Optional[Tuple[int, ...]] = None
    threshold: Optional[float] = None  # if set: T_i = inf mode, stop at
                                       # ||grad_i||^2 <= threshold
    max_inner: int = 1_000        # hard cap for threshold mode
    inner_mode: str = "fixed_batch"    # fixed_batch (paper GD) | microbatch
    average_opt_state: bool = True
    # Metric granularity of the PACKED round (DESIGN.md §6): "final"
    # evaluates loss/||grad||^2 once at the round's result (the fixed-T
    # algorithm needs no per-step diagnostics — materializing them costs
    # ~2 extra passes over the model per inner step); "traj" matches the
    # pytree round's per-step trajectories (needed by the Sec-4 adaptive-T
    # controller). The pytree round always records trajectories.
    metrics: str = "final"


class TrainState(dict):
    """{"params": pytree, "opt": pytree} — plain dict for pytree-ness."""


def replicate(tree, n_groups: int):
    """Tile a pytree with a leading group axis (all groups identical)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), tree)


def average_groups(tree):
    """Model averaging: mean over the leading G axis, broadcast back.

    This is the paper's server combination step — kept as the reference
    the ``comm.Exchange`` server backend must stay bit-exact with (the
    rounds themselves route through the exchange; see DESIGN.md §8).
    """
    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape)

    return jax.tree.map(avg, tree)


def _resolve_exchange(exchange, cfg: LocalSGDConfig, layout):
    """Default + validate the round's exchange (see DESIGN.md §8/§10 for
    the combinations that refuse)."""
    exch = exchange if exchange is not None else comm_mod.default_exchange(
        cfg.n_groups)
    if exch.n_groups != cfg.n_groups:
        raise ValueError(f"exchange built for G={exch.n_groups} but "
                         f"cfg.n_groups={cfg.n_groups}")
    if exch.codec.flat_only and layout is None and exch.topology != "none":
        # ("none" is exempt: nothing goes on the wire, the codec never runs)
        raise NotImplementedError(
            f"codec {exch.codec.name!r} needs the packed (G, N) buffer as "
            "its wire format — run the round with a packing.Layout "
            "(DESIGN.md §8)")
    if (cfg.average_opt_state and exch.mcodec.flat_only and layout is None
            and exch.topology != "none"):
        raise NotImplementedError(
            f"moment codec {exch.mcodec.name!r} needs packed flat moment "
            "buffers as its wire format — run the round with a "
            "packing.Layout and a packed optimizer (DESIGN.md §10)")
    if (exch.downlink_codec is not None and exch.downlink_codec.flat_only
            and layout is None and exch.topology != "none"):
        raise NotImplementedError(
            f"downlink codec {exch.downlink_codec.name!r} needs the "
            "packed flat buffer as its wire format — run the round with "
            "a packing.Layout (DESIGN.md §11)")
    if cfg.average_opt_state and not exch.supports_opt_state_averaging:
        raise NotImplementedError(
            f"{exch.topology} cannot average opt state; set "
            "average_opt_state=False (DESIGN.md §10)")
    if exch.overlap and layout is None:
        raise NotImplementedError(
            "the overlapped (delayed-mixing) exchange double-buffers the "
            "packed flat stream payload as comm['inflight'] — run the "
            "round with a packing.Layout and a packed optimizer "
            "(DESIGN.md §14); the pytree path has no single donation-"
            "safe buffer to put in flight")
    return exch


def _check_comm_state(exch, state_G, mkeys=()):
    if exch.stateful and "comm" not in state_G:
        raise ValueError(
            f"exchange {exch.name!r} carries round-to-round state "
            "(staleness buffers / codec residuals); build the train state "
            "with init_state(..., exchange=...)")
    if (exch.topology == "async_stale" and mkeys
            and "pushed_opt" not in state_G.get("comm", {})):
        raise ValueError(
            "async_stale averages opt state through per-stream staleness "
            "buffers; build the train state with init_state(..., "
            "exchange=...) so comm['pushed_opt'] is allocated "
            "(DESIGN.md §10)")
    if (exch.topology == "push_sum"
            and "mass" not in state_G.get("comm", {})):
        raise ValueError(
            "push_sum is ratio consensus: every round needs the mass "
            "counters and per-edge backlog buffers; build the train state "
            "with init_state(..., exchange=...) so comm['mass'] / "
            "comm['backlog'] are allocated (DESIGN.md §12)")
    if (exch.faulty and exch.topology == "server"
            and "pushed" not in state_G.get("comm", {})):
        raise ValueError(
            "a faulty server exchange retries dropped pushes from "
            "per-group staleness buffers; build the train state with "
            "init_state(..., exchange=...) so comm['pushed'] is "
            "allocated (DESIGN.md §12)")
    if (exch.hierarchical and exch.inter_topology == "push_sum"
            and exch.n_pods > 1
            and "mass" not in state_G.get("comm", {})):
        raise ValueError(
            "hierarchical push_sum inter tier is ratio consensus: every "
            "round needs the pod-level mass counters and per-edge "
            "backlogs; build the train state with init_state(..., "
            "exchange=...) so comm['mass'] / comm['backlog'] are "
            "allocated (DESIGN.md §16)")
    if exch.overlap and "inflight" not in state_G.get("comm", {}):
        raise ValueError(
            "an overlapped exchange double-buffers the previous round's "
            "payload; build the train state with init_state(..., "
            "exchange=...) so comm['inflight'] is allocated "
            "(DESIGN.md §14)")


def _round_wire_bytes(exch, params_G, opt_G, avg_opt: bool,
                      n_groups: int) -> dict:
    """Exact payload bytes this round puts on the wire (static ints —
    shapes only), matching what the round actually exchanges: every
    stream of the payload through ITS codec — params via the params
    codec, each moment stream via the moment codec (DESIGN.md §10). The
    step counter is never exchanged on either path. Returns the totals
    (``wire_bytes`` — the physical total, p2p payloads count once —
    plus per-direction ``wire_bytes_up`` / ``wire_bytes_down``) and one
    ``wire_bytes/<stream>`` key per stream; the totals are exactly the
    sums of the per-stream splits."""
    n = sum(l.size // n_groups for l in jax.tree.leaves(params_G))
    moment_sizes = {}
    if avg_opt:
        moment_sizes = {
            k: sum(l.size // n_groups for l in jax.tree.leaves(v))
            for k, v in opt_G.items() if k != "count"}
    by_stream = exch.wire_bytes_by_stream(n, moment_sizes)
    by_tier = exch.wire_bytes_by_tier(n, moment_sizes)
    out = {"wire_bytes": sum(by_stream.values()),
           "wire_bytes_up": exch.wire_bytes_up(n, moment_sizes=moment_sizes),
           "wire_bytes_down": exch.wire_bytes_down(
               n, moment_sizes=moment_sizes),
           # per-tier totals (DESIGN.md §16): flat topologies put the
           # whole wire on the intra tier (one big pod), inter = 0
           "wire_bytes_intra": by_tier["intra"],
           "wire_bytes_inter": by_tier["inter"]}
    out.update({f"wire_bytes/{k}": v for k, v in by_stream.items()})
    return out


def _clamp_nonneg_streams(mixed: dict, opt, exch) -> dict:
    """Project lossy-decoded non-negative moment streams (adamw's second
    moment) back onto [0, inf): a delta codec's decode error is bounded
    by the chunk scale, so small-magnitude v elements can come back
    slightly negative and sqrt(v) would NaN. The true value is >= 0, so
    the projection only shrinks the decode error. Identity moment codecs
    skip this entirely (the default path stays bit-exact). Overlap mode
    always projects: the delayed-mixing correction is ADDITIVE
    (``v_T + mix(inflight) - inflight``), so even an fp32 payload can
    push a near-zero v element negative (DESIGN.md §14)."""
    if ((exch.mcodec.identity and not exch.lossy_downlink
         and not exch.overlap) or exch.topology == "none"):
        return mixed
    nonneg = getattr(opt, "moment_nonneg", ())
    return {k: (jax.tree.map(lambda x: jnp.maximum(x, 0.0), v)
                if k in nonneg else v)
            for k, v in mixed.items()}


def grad_sq_norm(grads, use_pallas: bool = False) -> jax.Array:
    """||g||^2. On a packed flat buffer this is ONE fused reduction
    (optionally the Pallas sq_norm kernel) instead of one partial sum
    per pytree leaf."""
    if isinstance(grads, jax.Array):
        if use_pallas:
            from repro.kernels import use_interpret
            from repro.kernels.sq_norm import sq_norm
            return sq_norm(grads.reshape(-1), interpret=use_interpret())
        return jnp.sum(jnp.square(grads.astype(jnp.float32)))
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(grads))


def _grad_sq_norm_groups(grads_G, use_pallas: bool = False) -> jax.Array:
    """Per-group ||g||^2 for a (G, N) packed gradient buffer -> (G,)."""
    if use_pallas:
        from repro.kernels import use_interpret
        from repro.kernels.sq_norm import sq_norm_groups
        return sq_norm_groups(grads_G, interpret=use_interpret())
    return jnp.sum(jnp.square(grads_G.astype(jnp.float32)), axis=-1)


# ---------------------------------------------------------------------------
# Uniform round observability block (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _consensus_sq_flat(x_G, use_pallas: bool = False) -> jax.Array:
    """Per-group consensus distance ||x_g - x̄||² of a (G, N) buffer ->
    (G,): the pad region is zero in every group, so it contributes
    nothing. The deviation is formed in fp32 and reduced by the same
    sq_norm path the grad metrics use."""
    x32 = x_G.astype(jnp.float32)
    d = x32 - jnp.mean(x32, axis=0, keepdims=True)
    return _grad_sq_norm_groups(d, use_pallas)


def _consensus_sq_tree(params_G) -> jax.Array:
    """Per-group ||x_g - x̄||² summed over every pytree leaf -> (G,)."""
    total = None
    for leaf in jax.tree.leaves(params_G):
        x = leaf.astype(jnp.float32)
        d = x - jnp.mean(x, axis=0, keepdims=True)
        part = jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim)))
        total = part if total is None else total + part
    return total


def _residual_sq_groups(res, n_groups: int) -> jax.Array:
    """Per-group squared mass of a codec's error-feedback residual ->
    (G,); zeros when the stream's codec carries none (width codecs,
    identity) so the codec_err/<stream> key is always present."""
    if res is None:
        return jnp.zeros((n_groups,), jnp.float32)
    total = None
    for leaf in jax.tree.leaves(res):
        x = leaf.astype(jnp.float32)
        part = jnp.sum(jnp.square(x), axis=tuple(range(1, x.ndim)))
        total = part if total is None else total + part
    return total


def _obs_round_metrics(exch, comm_state: dict, streams, consensus_pre,
                       consensus_post, n_groups: int) -> dict:
    """The uniform observability block every round emits (DESIGN.md
    §13): consensus distance pre/post exchange, per-stream codec error
    mass, push-sum backlog mass, participation and the static expected
    delivery rate — ALWAYS present, zeros/ones on configurations where
    the quantity is trivially inert, so the metric schema never depends
    on topology/codec/fault flags."""
    m = {"consensus_sq": consensus_pre,
         "consensus_sq_post": consensus_post}
    cstates = comm_state.get("codec", {})
    for s in streams:
        m[f"codec_err/{s}"] = _residual_sq_groups(
            cstates.get(s, {}).get("residual"), n_groups)
    m["backlog_mass"] = (jnp.sum(comm_state["backlog_w"])
                         if "backlog_w" in comm_state
                         else jnp.zeros((), jnp.float32))
    part = comm_state.get("participation")
    m["participation"] = (jnp.asarray(part, jnp.float32)
                          if part is not None
                          else jnp.ones((), jnp.float32))
    m["delivery_rate"] = jnp.asarray(exch.delivery_rate, jnp.float32)
    # per-tier participation/delivery (DESIGN.md §16). Flat single-tier
    # convention: the whole wire is the intra tier, so intra mirrors the
    # overall number and the (nonexistent) inter tier reports 1.0
    part_i = comm_state.get("participation_intra")
    m["participation_intra"] = (jnp.asarray(part_i, jnp.float32)
                                if part_i is not None
                                else m["participation"])
    part_x = comm_state.get("participation_inter")
    m["participation_inter"] = (jnp.asarray(part_x, jnp.float32)
                                if part_x is not None
                                else jnp.ones((), jnp.float32))
    m["delivery_rate_intra"] = jnp.asarray(exch.delivery_rate_intra,
                                           jnp.float32)
    m["delivery_rate_inter"] = jnp.asarray(exch.delivery_rate_inter,
                                           jnp.float32)
    return m


# ---------------------------------------------------------------------------
# Local round = T local steps (vmapped over groups) + one averaging step
# ---------------------------------------------------------------------------


def make_local_round(loss_fn: Callable, opt: Optimizer, cfg: LocalSGDConfig,
                     layout: Optional[packing.Layout] = None,
                     exchange: Optional["comm_mod.Exchange"] = None,
                     shardexec=None):
    """Build ``round(state_G, batch_G) -> (state_G, metrics)``.

    loss_fn(params, batch) -> scalar.
    state_G: {"params","opt"} with leading G axis on every leaf, plus a
             "comm" entry when the exchange carries state
             (``init_state(..., exchange=...)``).
    batch_G: leaves with leading axes (G, ...) for fixed_batch or
             (G, T, ...) for microbatch mode.

    With ``layout`` (and a packed optimizer from ``optim.packed``) the
    round runs on the flat-buffer fast path: state_G["params"] is one
    (G, N) f32 buffer, every inner step is one fused update pass, and the
    buffer doubles as the wire format (see DESIGN.md §6).

    ``exchange`` selects the communication backend (repro.comm,
    DESIGN.md §8): topology x codec + exact wire-byte accounting
    (``metrics["wire_bytes"]`` + per-direction up/down). Default:
    server/fp32 — bit-exact with the pre-comm ``average_groups``.

    ``shardexec`` (a ``sharding.shardexec.ShardExec``, packed path only)
    runs the fused update, the codec, and the exchange inside shard_map
    blocks on shard-local slices of the (G, Np) buffer — ``layout`` must
    then be the matching ``packing.ShardedLayout`` (DESIGN.md §9).
    """
    exch = _resolve_exchange(exchange, cfg, layout)
    if layout is not None or getattr(opt, "packed", False):
        if layout is None or not getattr(opt, "packed", False):
            raise ValueError(
                "packed rounds need BOTH a packing.Layout and a packed "
                "optimizer (optim.packed / optim.get(..., packed=True))")
        return _make_packed_local_round(loss_fn, opt, cfg, layout, exch,
                                        shardexec)
    if shardexec is not None:
        raise ValueError(
            "shardexec shards the packed flat buffer — it has no meaning "
            "for the per-leaf pytree round; pass layout= and a packed "
            "optimizer (DESIGN.md §9)")
    vg = jax.value_and_grad(loss_fn)

    def fixed_batch_group(state, batch, t_i=None):
        """T_i steps of full-batch local GD on this group's shard.

        t_i: optional per-group scalar — steps beyond t_i keep the state
        unchanged (masked scan to cfg.inner_steps, the max)."""
        if cfg.threshold is not None:
            def cond(carry):
                state, t, gsq, _ = carry
                return jnp.logical_and(t < cfg.max_inner,
                                       gsq > cfg.threshold)

            def body(carry):
                state, t, _, loss0 = carry
                loss, g = vg(state["params"], batch)
                new_p, new_o = opt.step(state["params"], g, state["opt"])
                return ({"params": new_p, "opt": new_o}, t + 1,
                        grad_sq_norm(g), loss)

            loss0, g0 = vg(state["params"], batch)
            state, t, gsq, loss = jax.lax.while_loop(
                cond, body, (state, jnp.zeros((), jnp.int32),
                             grad_sq_norm(g0), loss0))
            return state, {"loss": loss, "inner_steps": t, "grad_sq": gsq}

        def inner(state, t):
            loss, g = vg(state["params"], batch)
            new_p, new_o = opt.step(state["params"], g, state["opt"])
            new = {"params": new_p, "opt": new_o}
            if t_i is not None:
                keep = t < t_i
                new = jax.tree.map(
                    lambda a, b: jnp.where(keep, a, b), new, state)
            return new, (loss, grad_sq_norm(g))

        state, (losses, gsqs) = jax.lax.scan(
            inner, state, jnp.arange(cfg.inner_steps))
        n_steps = jnp.asarray(cfg.inner_steps) if t_i is None else t_i
        return state, {"loss": losses[-1],
                       "inner_steps": n_steps,
                       "grad_sq": gsqs[-1],
                       "grad_sq_first": gsqs[0],
                       "grad_sq_traj": gsqs}

    def microbatch_group(state, batches):
        """T_i steps, one microbatch per step (practical local SGD)."""
        def inner(state, mb):
            loss, g = vg(state["params"], mb)
            new_p, new_o = opt.step(state["params"], g, state["opt"])
            return {"params": new_p, "opt": new_o}, (loss, grad_sq_norm(g))

        state, (losses, gsqs) = jax.lax.scan(inner, state, batches)
        return state, {"loss": losses[-1],
                       "inner_steps": jnp.asarray(cfg.inner_steps),
                       "grad_sq": gsqs[-1],
                       "grad_sq_first": gsqs[0],
                       "grad_sq_traj": gsqs}

    group_fn = fixed_batch_group if cfg.inner_mode == "fixed_batch" \
        else microbatch_group

    def round_(state_G, batch_G):
        st = {"params": state_G["params"], "opt": state_G["opt"]}
        mkeys = (tuple(k for k in st["opt"] if k != "count")
                 if cfg.average_opt_state else ())
        _check_comm_state(exch, state_G, mkeys)
        comm_state = state_G.get("comm", {})
        # lossy codecs transmit each stream's round delta vs these
        # (identity codecs never touch x0, keeping the default bit-exact)
        xs0 = {}
        if exch.lossy_stream("params"):
            xs0["params"] = st["params"]
        xs0.update({k: st["opt"][k] for k in mkeys
                    if exch.lossy_stream(k)})
        if cfg.t_i is not None and cfg.inner_mode == "fixed_batch":
            assert len(cfg.t_i) == cfg.n_groups, cfg.t_i
            assert max(cfg.t_i) <= cfg.inner_steps, cfg.t_i
            t_vec = jnp.asarray(cfg.t_i, jnp.int32)
            with jax.named_scope("local_steps"):
                st, metrics = jax.vmap(fixed_batch_group)(st, batch_G,
                                                          t_vec)
        else:
            with jax.named_scope("local_steps"):
                st, metrics = jax.vmap(group_fn)(st, batch_G)
        # ---- communication: the multi-stream exchange (DESIGN.md §10) ----
        # params plus (when averaging opt state) one stream per moment
        # buffer, each through its own codec; the step counter is never
        # exchanged — mixing an int32 counter through a float matmul
        # would truncate and drift it across groups, and under t_i the
        # per-group counts are meaningful
        xs = {"params": st["params"]}
        xs.update({k: st["opt"][k] for k in mkeys})
        with jax.named_scope("exchange"):
            mixed, comm_state = exch.streams(xs, xs0, comm_state)
        mixed = _clamp_nonneg_streams(mixed, opt, exch)
        new_opt = {k: mixed.get(k, v) for k, v in st["opt"].items()}
        metrics.update(_round_wire_bytes(
            exch, st["params"], st["opt"], cfg.average_opt_state,
            cfg.n_groups))
        with jax.named_scope("round_metrics"):
            metrics.update(_obs_round_metrics(
                exch, comm_state, ("params",) + mkeys,
                _consensus_sq_tree(st["params"]),
                _consensus_sq_tree(mixed["params"]), cfg.n_groups))
        out = {"params": mixed["params"], "opt": new_opt}
        if "comm" in state_G:
            out["comm"] = comm_state
        return out, metrics

    return round_


# ---------------------------------------------------------------------------
# Packed fast path: the same round on one flat f32 buffer per state part
# ---------------------------------------------------------------------------


def _make_packed_local_round(loss_fn: Callable, opt: Optimizer,
                             cfg: LocalSGDConfig, layout: packing.Layout,
                             exch: "comm_mod.Exchange", shardexec=None):
    """Flat-buffer local round (see DESIGN.md §6).

    The T-step inner loop scans over fused whole-buffer updates: grads are
    taken per group (vmapped over G) against the unpacked view of the
    buffer and packed with one concatenate; ``opt.step`` then updates all
    G*N elements in one fused pass and the round ends with a single flat
    mean over G — one all-reduce of the model per round on a mesh.

    With ``shardexec`` the update, the codec, the exchange, and the traj
    ||g||² reduction run in shard_map blocks on shard-local slices of the
    (G, Np) buffer instead of relying on GSPMD partitioning — this is what
    lets the real Pallas kernels run on a sharded mesh (DESIGN.md §9).

    cfg.metrics selects the metric contract: "final" (default — the hot
    path; per-step work is JUST the fused update, loss/||grad||^2 are
    evaluated once on the round's result) or "traj" (per-step
    trajectories, matching the pytree round's metrics exactly).

    Per-node t_i with a count-dependent update (adamw bias correction,
    lr schedules) runs the fused step vmapped over G with a PER-GROUP
    count vector (masked like the moments), matching the pytree path's
    per-group counters — replicated path only (DESIGN.md §10). Not on
    this path (use the pytree path): threshold (T_i = inf) mode.
    """
    assert cfg.metrics in ("traj", "final"), cfg.metrics
    packing.check_packed_index_space(layout, cfg.n_groups)
    if cfg.threshold is not None:
        raise NotImplementedError(
            "threshold (T_i=inf) mode runs on the pytree path")
    if cfg.t_i is not None and cfg.inner_mode == "microbatch":
        raise NotImplementedError(
            "t_i is only defined for fixed_batch mode (the pytree path "
            "silently ignores it for microbatch)")
    # Count-dependent updates (adamw bias correction, lr schedules) need
    # per-group step counts under t_i: the fused step runs vmapped over G
    # with a (G,) count vector instead of the shared scalar.
    per_group_count = (cfg.t_i is not None
                       and getattr(opt, "count_dependent", False))
    if per_group_count and shardexec is not None:
        raise NotImplementedError(
            "per-node t_i with a count-dependent update keeps a (G,) "
            "count vector outside the shard_map opt step; run it on the "
            "replicated packed path (DESIGN.md §10)")
    use_pallas = getattr(opt, "impl", "jnp") == "pallas"
    flat_vg = packing.value_and_flat_grad(loss_fn, layout)
    slayout = packing.stream_layout_for(opt, layout)

    exch_streams = mix_inflight = encode_streams = None
    if shardexec is not None:
        opt_step = shardexec.opt_step(opt)
        if exch.overlap:
            mix_inflight = shardexec.mix_streams(exch)
            encode_streams = shardexec.encode_streams(exch, layout)
        else:
            exch_streams = shardexec.exchange_streams(exch, layout)
        gsq_groups = shardexec.sq_norm_groups(use_pallas)
        consensus_groups = shardexec.consensus_sq_groups(use_pallas)
    else:
        opt_step = (jax.vmap(opt.step) if per_group_count else opt.step)
        if exch.overlap:
            mix_inflight = exch.mix_inflight
            encode_streams = exch.encode_streams
        else:
            exch_streams = exch.streams

        def gsq_groups(g_G):
            return _grad_sq_norm_groups(g_G, use_pallas)

        def consensus_groups(x_G):
            return _consensus_sq_flat(x_G, use_pallas)

    if cfg.t_i is not None:
        assert len(cfg.t_i) == cfg.n_groups, cfg.t_i
        assert max(cfg.t_i) <= cfg.inner_steps, cfg.t_i

    def round_(state_G, batch_G):
        mkeys = slayout.moment_streams if cfg.average_opt_state else ()
        assert set(mkeys) <= set(state_G["opt"]), (mkeys,
                                                   tuple(state_G["opt"]))
        _check_comm_state(exch, state_G, mkeys)
        had_comm = "comm" in state_G
        comm_state = state_G.get("comm", {})
        opt0 = state_G["opt"]
        if per_group_count and opt0["count"].ndim == 0:
            # first round after init: promote the shared scalar count to
            # the per-group vector the masked t_i updates need
            opt0 = {**opt0, "count": jnp.broadcast_to(
                opt0["count"], (cfg.n_groups,))}
        state_G = {"params": state_G["params"], "opt": opt0}
        # lossy codecs transmit each stream's round delta vs these
        # (identity codecs never touch x0: bit-exact + donatable)
        xs0 = {}
        if exch.lossy_stream("params"):
            xs0["params"] = state_G["params"]
        xs0.update({k: state_G["opt"][k] for k in mkeys
                    if exch.lossy_stream(k)})
        t_vec = (jnp.asarray(cfg.t_i, jnp.int32)
                 if cfg.t_i is not None else None)
        if exch.overlap:
            # delayed mixing (DESIGN.md §14): issue the PREVIOUS round's
            # mixing collective FIRST — it depends only on the in-flight
            # buffers, not on this round's local steps, so a parallel
            # backend schedules the two concurrently inside one graph
            inflight = comm_state["inflight"]
            with jax.named_scope("exchange"):
                mixed_inf = mix_inflight(inflight)

        traj = cfg.metrics == "traj"

        def body(state, t, batch_t):
            loss_G, g_G = jax.vmap(flat_vg)(state["params"], batch_t)
            new_p, new_o = opt_step(state["params"], g_G, state["opt"])
            if t_vec is not None:
                keep = (t < t_vec)[:, None]           # (G, 1)
                new_p = jnp.where(keep, new_p, state["params"])
                old_o = state["opt"]

                def mask(k, v):
                    # count stays the shared scalar (map_moments
                    # convention) unless the update is count-dependent —
                    # then it is per-group and masks like the moments
                    if k == "count":
                        return (jnp.where(t < t_vec, v, old_o[k])
                                if per_group_count else v)
                    return jnp.where(keep, v, old_o[k])

                new_o = {k: mask(k, v) for k, v in new_o.items()}
            new = {"params": new_p, "opt": new_o}
            if not traj:
                # hot path: no per-step diagnostics to materialize — XLA
                # keeps only the fused update chain
                return new, None
            gsq_G = gsq_groups(g_G)
            return new, (loss_G, gsq_G)

        ts = jnp.arange(cfg.inner_steps)
        if cfg.inner_mode == "microbatch":
            # (G, T, ...) -> (T, G, ...) so scan feeds one microbatch/step
            batches_T = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1),
                                     batch_G)
            with jax.named_scope("local_steps"):
                state_G, ys = jax.lax.scan(
                    lambda s, xs: body(s, xs[0], xs[1]),
                    state_G, (ts, batches_T))
            last_batch = jax.tree.map(lambda x: x[:, -1], batch_G)
        else:
            with jax.named_scope("local_steps"):
                state_G, ys = jax.lax.scan(
                    lambda s, t: body(s, t, batch_G), state_G, ts)
            last_batch = batch_G

        n_steps = (t_vec if t_vec is not None
                   else jnp.full((cfg.n_groups,), cfg.inner_steps,
                                 jnp.int32))
        if traj:
            losses = jnp.swapaxes(ys[0], 0, 1)        # (G, T)
            gsqs = jnp.swapaxes(ys[1], 0, 1)
            metrics = {"loss": losses[:, -1],
                       "inner_steps": n_steps,
                       "grad_sq": gsqs[:, -1],
                       "grad_sq_first": gsqs[:, 0],
                       "grad_sq_traj": gsqs}
        else:
            # one extra loss/grad eval at the round's RESULT (note: the
            # traj metrics report the grad made at step T-1 instead).
            # Evaluated per leaf — the norm needs no packed gradient, so
            # skipping the pack saves two full passes over the model.
            vg = jax.value_and_grad(loss_fn)

            def final_eval(buf, b):
                loss, g_tree = vg(packing.unpack(buf, layout), b)
                return loss, grad_sq_norm(g_tree)

            with jax.named_scope("final_eval"):
                loss_G, gsq_G = jax.vmap(final_eval)(state_G["params"],
                                                     last_batch)
            metrics = {"loss": loss_G,
                       "inner_steps": n_steps,
                       "grad_sq": gsq_G}
        # ---- communication: flat buffers through the stream exchange ----
        # every stream (params + averaged moments) rides its own codec;
        # the step counter is never exchanged (map_moments convention)
        xs = {"params": state_G["params"]}
        xs.update({k: state_G["opt"][k] for k in mkeys})
        with jax.named_scope("round_metrics"):
            consensus_pre = consensus_groups(state_G["params"])
        if exch.overlap:
            # delayed mixing, applied one round late: p' = local(p) +
            # mix(inflight) - inflight. The correction preserves the
            # G-mean (the mix is doubly stochastic) and contracts the
            # consensus deviation like the barrier mix does — PROVIDED
            # the in-flight payload is the ROUND RESULT p' (encoded
            # below), not the raw local iterate: shipping the local
            # iterate gives the deviation recursion e' = e - e_prev +
            # drift, whose characteristic roots sit ON the unit circle
            # (it oscillates and never converges).
            with jax.named_scope("apply_inflight"):
                mixed = {k: xs[k] + (mixed_inf[k] - inflight[k])
                         for k in xs}
            mixed = _clamp_nonneg_streams(mixed, opt, exch)
            # encode this round's result as the next round's in-flight
            # payload: delta vs the round start (the same codec
            # reference the barrier path uses, so quantization error
            # vanishes with convergence)
            with jax.named_scope("encode_inflight"):
                new_inflight, comm_state = encode_streams(
                    mixed, xs0, comm_state)
            comm_state = dict(comm_state)
            comm_state["inflight"] = new_inflight
        else:
            with jax.named_scope("exchange"):
                mixed, comm_state = exch_streams(xs, xs0, comm_state)
            mixed = _clamp_nonneg_streams(mixed, opt, exch)
        new_opt = {k: mixed.get(k, v) for k, v in state_G["opt"].items()}
        metrics.update(_round_wire_bytes(
            exch, state_G["params"], state_G["opt"],
            cfg.average_opt_state, cfg.n_groups))
        with jax.named_scope("round_metrics"):
            metrics.update(_obs_round_metrics(
                exch, comm_state, ("params",) + tuple(mkeys),
                consensus_pre, consensus_groups(mixed["params"]),
                cfg.n_groups))
        out = {"params": mixed["params"], "opt": new_opt}
        if had_comm:
            out["comm"] = comm_state
        return out, metrics

    return round_


# ---------------------------------------------------------------------------
# Conventional baseline: synchronous data parallelism (all-reduce per step)
# ---------------------------------------------------------------------------


def make_sync_step(loss_fn: Callable, opt: Optimizer,
                   layout: Optional[packing.Layout] = None):
    """Standard DP: grads averaged across the whole batch every step.

    With params replicated and the batch sharded over ("pod","data"), XLA
    inserts a gradient all-reduce per step — the conventional schedule the
    paper compares against.

    With ``layout`` (and a packed optimizer) the state is the flat (N,)
    buffer and the update is one fused pass per step.
    """
    if layout is not None or getattr(opt, "packed", False):
        if layout is None or not getattr(opt, "packed", False):
            raise ValueError(
                "packed sync steps need BOTH a packing.Layout and a "
                "packed optimizer")
        packing.check_packed_index_space(layout)
        use_pallas = getattr(opt, "impl", "jnp") == "pallas"
        flat_vg = packing.value_and_flat_grad(loss_fn, layout)

        def packed_step(state, batch):
            loss, g = flat_vg(state["params"], batch)
            new_p, new_o = opt.step(state["params"], g, state["opt"])
            return ({"params": new_p, "opt": new_o},
                    {"loss": loss,
                     "grad_sq": grad_sq_norm(g, use_pallas)})

        return packed_step

    vg = jax.value_and_grad(loss_fn)

    def step(state, batch):
        loss, g = vg(state["params"], batch)
        new_p, new_o = opt.step(state["params"], g, state["opt"])
        return {"params": new_p, "opt": new_o}, {"loss": loss,
                                                 "grad_sq": grad_sq_norm(g)}

    return step


# ---------------------------------------------------------------------------
# Host-level driver (for real runs on small configs / examples)
# ---------------------------------------------------------------------------


def init_state(params, opt: Optimizer, n_groups: Optional[int] = None,
               layout: Optional[packing.Layout] = None,
               exchange: Optional["comm_mod.Exchange"] = None,
               average_opt_state: bool = True):
    if layout is not None:
        buf = packing.pack(params, layout)
        state = {"params": buf, "opt": opt.init(buf)}
        if n_groups:
            def rep(x):
                return jnp.broadcast_to(x[None], (n_groups,) + x.shape)

            state = {"params": rep(buf),
                     "opt": map_moments(rep, state["opt"])}
    else:
        state = {"params": params, "opt": opt.init(params)}
        if n_groups:
            state = replicate(state, n_groups)
    if exchange is not None and exchange.stateful:
        if not n_groups:
            raise ValueError("stateful exchanges need a grouped state "
                             "(pass n_groups)")
        # moment streams ride the exchange too (DESIGN.md §10): hand the
        # exchange every moment buffer so it can allocate per-stream
        # codec state and (async) per-stream staleness buffers — but only
        # when the rounds will actually average opt state (match
        # cfg.average_opt_state here, or dead G x Np pushed_opt copies
        # ride the donated train state and every checkpoint)
        moments = ({k: v for k, v in state["opt"].items() if k != "count"}
                   if average_opt_state else {})
        state["comm"] = exchange.init(state["params"],
                                      moments=moments or None)
    return state


def server_params(state_G, layout: Optional[packing.Layout] = None):
    """The averaged (server) model from a grouped state (as a pytree)."""
    if layout is not None:
        buf = state_G["params"]
        if buf.ndim > 1:
            buf = jnp.mean(buf, axis=0)
        return packing.unpack(buf, layout)
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), state_G["params"])
