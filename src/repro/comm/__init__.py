"""repro.comm — pluggable communication subsystem (DESIGN.md §8).

The paper's claim is about communication: T local steps amortize ONE model
exchange per round. This package makes that exchange a first-class layer —
topologies (server / ring / gossip / async_stale / push_sum), flat-buffer
wire codecs (fp32 / fp16 / bf16 / int8 / topk) applied PER STREAM of the
payload (params + optimizer moments, DESIGN.md §10), exact per-round
per-stream wire-byte accounting, and deterministic fault injection
(``FaultPlan``, DESIGN.md §12) — behind the ``Exchange`` protocol that
``core.localsgd`` routes both its pytree and packed rounds through.
"""
from repro.comm.codecs import CODECS, Codec, defer_undelivered, get_codec
from repro.comm.exchange import (TOPOLOGIES, Exchange, default_exchange,
                                 get_exchange)
from repro.comm.faults import FaultPlan
from repro.comm.topology import (gossip_matrix, is_doubly_stochastic,
                                 mixing_matrix, n_edge_sends,
                                 push_sum_offsets, ring_matrix,
                                 server_matrix, spectral_gap)

__all__ = [
    "CODECS", "Codec", "defer_undelivered", "get_codec",
    "TOPOLOGIES", "Exchange", "default_exchange", "get_exchange",
    "FaultPlan",
    "gossip_matrix", "is_doubly_stochastic", "mixing_matrix",
    "n_edge_sends", "push_sum_offsets", "ring_matrix", "server_matrix",
    "spectral_gap",
]
