"""Flat-buffer wire codecs for the communication subsystem (DESIGN.md §8).

A codec compresses what a group puts on the wire each round. Lossy codecs
are applied to the round DELTA ``x_T - x_0`` (the T local steps' movement),
not the model itself: deltas shrink as training converges, so the absolute
quantization error vanishes with them and convex-feasibility convergence
is preserved (the ``benchmarks/comm_bytes.py`` check). The ``fp32`` codec
is the identity — the exchange skips the delta arithmetic entirely so the
default path stays bit-exact with the pre-comm ``average_groups``.

Codec contract:
  * ``compress(delta, state) -> (delta_hat, state)`` — quantize + dequantize
    in one step (the simulated wire: every group lives on the same mesh, so
    the decoded value is what the exchange mixes). ``delta`` is the packed
    (G, N) buffer for flat-only codecs, any pytree for cast codecs.
  * ``state`` threads round-to-round codec memory through the train state
    (``{"comm": ...}``): the int8 rng counter, the top-k error-feedback
    residual. Stateless codecs use ``{}``.
  * ``wire_bytes(n)`` — EXACT encoded payload bytes one sender puts on the
    wire for an n-element f32 buffer. This is the number the wire
    accounting threads into round metrics and AdaptiveT's cost ratio.

int8 follows the ``impl="jnp"|"pallas"`` convention of the packed
optimizers: the Pallas kernels (kernels/quantize.py) and the jnp reference
consume the same stochastic-rounding bits and agree exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import packing


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable[[Any, dict], tuple]
    wire_bytes: Callable[[int], int]
    init: Callable[[Any], dict]
    # identity codecs skip the delta path entirely (bit-exact default)
    identity: bool = False
    # flat-only codecs need the packed (G, N) buffer as the wire format
    flat_only: bool = False
    stateful: bool = False
    impl: str = "jnp"
    # Shard-aware hooks (DESIGN.md §9). Chunked codecs (int8) expose the
    # per-(rows, chunk) core so the shard_map exchange can generate the
    # stochastic-rounding noise OUTSIDE the shard_map block (full rows
    # shape, same key) — each device then consumes its own row slice and
    # the sharded result is BIT-IDENTICAL to the replicated path.
    #   noise(count, rows_shape) -> u          (deterministic per count)
    #   compress_rows(rows, u) -> decoded rows (pure, shard-local safe)
    chunk: int = 0
    noise: Callable[[Any, tuple], Any] = None
    compress_rows: Callable[[Any, Any], Any] = None
    # codecs whose shard_map execution would change the payload refuse
    # sharded execution (none currently: topk runs sharded through the
    # distributed threshold selection — DESIGN.md §11)
    shardable: bool = True
    # top-k selection fraction (0 for non-selective codecs); the sharded
    # exchange reads it to size the distributed selection
    topk_frac: float = 0.0


def _no_state(_params_like):
    return {}


def fp32() -> Codec:
    """Identity: the uncompressed baseline (4 bytes/element)."""
    return Codec("fp32", lambda d, s: (d, s), lambda n: 4 * n, _no_state,
                 identity=True)


def _cast_codec(name: str, dtype) -> Codec:
    def compress(delta, state):
        out = jax.tree.map(
            lambda d: d.astype(dtype).astype(d.dtype), delta)
        return out, state

    return Codec(name, compress, lambda n: 2 * n, _no_state)


def fp16() -> Codec:
    return _cast_codec("fp16", jnp.float16)


def bf16() -> Codec:
    return _cast_codec("bf16", jnp.bfloat16)


def int8(chunk: int = 256, seed: int = 0, *, impl: str = "auto") -> Codec:
    """Per-chunk-scaled int8 with unbiased stochastic rounding.

    Payload: 1 byte/element + one fp32 scale per ``chunk`` elements
    (3.94x under fp32 at chunk=256). Rounding noise is zero-mean and
    bounded by the chunk scale, so the mixed model is an unbiased estimate
    of the uncompressed mix. The rng counter in the codec state makes the
    noise deterministic per round (reproducible runs, no host rng)."""
    from repro.kernels import resolve_impl
    impl = resolve_impl(impl)

    def init(_params_like):
        return {"count": jnp.zeros((), jnp.int32)}

    def noise(count, rows_shape):
        """Stochastic-rounding bits for one compress application:
        deterministic per (seed, count) and per element — the shard_map
        exchange calls this at the FULL rows shape so every shard's slice
        matches the replicated path exactly."""
        key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        return jax.random.uniform(key, rows_shape, jnp.float32)

    def compress_rows(rows, u):
        """Quantize+dequantize (rows, chunk) with given noise — pure, so
        it is safe on a shard-local row slice (one fp32 scale per row;
        rows never straddle shards under a chunk-aligned ShardedLayout).
        The pallas impl is the FUSED qdq kernel (one VMEM pass instead of
        the staged quantize + dequantize pair — DESIGN.md §11)."""
        if impl == "pallas":
            from repro.kernels import use_interpret
            from repro.kernels.exchange_epilogue import qdq_int8
            return qdq_int8(rows, u, interpret=use_interpret())
        amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.floor(rows / scale + u),
                     -127.0, 127.0).astype(jnp.int8)
        return q.astype(jnp.float32) * scale

    def compress(delta, state):
        rows = packing.chunk_rows(delta, chunk)
        out = compress_rows(rows, noise(state["count"], rows.shape))
        return (packing.unchunk_rows(out, delta.shape),
                {"count": state["count"] + 1})

    return Codec("int8", compress,
                 lambda n: n + 4 * math.ceil(n / chunk), init,
                 flat_only=True, stateful=True, impl=impl,
                 chunk=chunk, noise=noise, compress_rows=compress_rows)


def int8z(chunk: int = 256, seed: int = 0, *, impl: str = "auto") -> Codec:
    """Zero-preserving int8: the moment-friendly variant closing the
    DESIGN.md §10 caveat (per-chunk ABSOLUTE scales misfit moment chunks
    that mix live and dead coordinates — a dead coordinate could receive
    a full-quantum ``m`` kick over ``v̂ ≈ 0`` and take a 1/eps-sized
    step).

    Same wire format and bytes as ``int8`` (1 byte/element + one fp32
    scale per chunk), but every element smaller than HALF a quantum
    rounds DETERMINISTICALLY to exact zero instead of stochastically to
    ``±scale``: the rounding noise is pinned to 0.5 wherever
    ``|row| < scale/2``, so ``floor(x/s + 0.5) == 0`` there. The trade
    is explicit — sub-half-quantum mass is dropped (bias bounded by
    ``scale/2`` per element, vanishing with the round delta) instead of
    unbiasedly dithered; elements at or above half a quantum keep int8's
    exact stochastic-rounding semantics. The mask is computed from the
    row values alone BEFORE the qdq core, so the pallas and jnp impls
    consume identical noise and still agree exactly, and the shard_map
    exchange (which slices noise and rows identically) stays
    bit-identical to the replicated path."""
    base = int8(chunk=chunk, seed=seed, impl=impl)

    def compress_rows(rows, u):
        amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
        u = jnp.where(jnp.abs(rows) < 0.5 * scale, 0.5, u)
        return base.compress_rows(rows, u)

    def compress(delta, state):
        rows = packing.chunk_rows(delta, chunk)
        out = compress_rows(rows, base.noise(state["count"], rows.shape))
        return (packing.unchunk_rows(out, delta.shape),
                {"count": state["count"] + 1})

    return dataclasses.replace(base, name="int8z", compress=compress,
                               compress_rows=compress_rows)


def topk(frac: float = 0.05, *, impl: str = "auto") -> Codec:
    """Magnitude top-k sparsification with error feedback.

    Only the k = max(1, round(frac*N)) largest-|.| delta entries go on the
    wire (4-byte value + 4-byte index each); what was dropped accumulates
    in a per-group residual and is re-offered next round. The accounting
    identity ``delta + residual_in == delta_hat + residual_out`` holds
    EXACTLY (the residual update is the same subtraction that defines it),
    so compression drops nothing — it only delays it.

    Sharded execution (DESIGN.md §11): the shard_map exchange replaces
    this exact global selection with the distributed threshold rule
    (shard-local top-k bounds + psum'd bisection, at most k selected,
    shard-local residual) — ``ShardExec.exchange_streams``; the residual
    state shards like the params.

    ``impl`` selects the fused thresh epilogue's kernel on the
    replicated SERVER path (Exchange routes it through
    ``exchange_epilogue.codec_mix(kind="thresh")`` — select + residual +
    mean mix in one pass; ``compress`` below stays the staged exact-
    selection reference used by ring/gossip per-hop rounds)."""
    from repro.kernels import resolve_impl
    impl = resolve_impl(impl)

    def init(params_like):
        return {"residual": jnp.zeros_like(params_like)}

    def compress(delta, state):
        c = delta + state["residual"]
        k = max(1, int(round(frac * c.shape[-1])))

        def row(v):
            _, idx = jax.lax.top_k(jnp.abs(v), k)
            return jnp.zeros_like(v).at[idx].set(v[idx])

        d_hat = row(c) if c.ndim == 1 else jax.vmap(row)(c)
        return d_hat, {"residual": c - d_hat}

    def wire_bytes(n):
        return 8 * max(1, int(round(frac * n)))

    return Codec("topk", compress, wire_bytes, init,
                 flat_only=True, stateful=True, impl=impl,
                 topk_frac=frac)


def defer_undelivered(state: dict, d_hat, delivered):
    """Error-feedback semantics under packet loss (DESIGN.md §12): a
    compressed payload that never arrived must DEFER, not vanish. The
    codec's ``compress`` already moved the shipped entries out of the
    residual (``residual_out = c - d_hat``); if group g's push was
    dropped, its shipped entries go BACK into the residual — restoring
    ``residual = c`` exactly, as if nothing had been selected — and are
    re-offered next round. ``delivered``: (G,) float mask (1 = arrived);
    ``d_hat``: the decoded payload per group. No-op for codec states
    without an EF residual (int8's rng counter advances regardless — the
    noise was spent on the transmission whether or not it arrived)."""
    if "residual" not in state:
        return state
    def back(res, d):
        keep = delivered.reshape((-1,) + (1,) * (d.ndim - 1))
        return res + (1.0 - keep) * d

    return {**state,
            "residual": jax.tree.map(back, state["residual"], d_hat)}


CODECS = ("fp32", "fp16", "bf16", "int8", "int8z", "topk")


def get_codec(name: str, *, impl: str = "auto", chunk: int = 256,
              topk_frac: float = 0.05, seed: int = 0) -> Codec:
    if name == "fp32":
        return fp32()
    if name == "fp16":
        return fp16()
    if name == "bf16":
        return bf16()
    if name == "int8":
        return int8(chunk=chunk, seed=seed, impl=impl)
    if name == "int8z":
        return int8z(chunk=chunk, seed=seed, impl=impl)
    if name == "topk":
        return topk(frac=topk_frac, impl=impl)
    raise ValueError(f"unknown codec {name!r}: valid codecs are {CODECS}")
