"""Mixing topologies for the communication subsystem (DESIGN.md §8).

The paper's server step is the star topology: every node pushes its model,
pulls the mean. Decentralized variants replace that with one (or several)
rounds of neighbor averaging ``x <- W x`` where ``W`` is a doubly-stochastic
mixing matrix over the G groups: rows sum to 1 (each node's update is a
convex combination — iterates stay in the convex hull) and columns sum to 1
(the G-mean is invariant, so decentralized rounds optimize the same average
objective as the server). For connected topologies the spectral gap
``1 - |lambda_2(W)|`` is positive and repeated mixing contracts to
consensus at rate |lambda_2|^k — the property ``tests/test_comm.py``
checks.

Matrices are built host-side with numpy (static, deterministic per seed)
and closed over as constants by the jitted exchange.
"""
from __future__ import annotations

import numpy as np


def server_matrix(m: int) -> np.ndarray:
    """Star topology as a mixing matrix: one step reaches exact consensus.

    (The server Exchange does NOT multiply by this — it uses the same
    mean+broadcast ops as the pre-comm ``average_groups`` so the default
    path stays bit-exact — but the matrix form is what the consensus /
    spectral tests reason about.)"""
    return np.full((m, m), 1.0 / m)


def ring_matrix(m: int) -> np.ndarray:
    """Symmetric ring: each node averages itself with its two neighbors
    (equal 1/3 weights; degenerate small-m cases fall back to the mean)."""
    if m <= 2:
        return server_matrix(m)
    w = np.zeros((m, m))
    for i in range(m):
        w[i, i] = 1.0 / 3.0
        w[i, (i - 1) % m] = 1.0 / 3.0
        w[i, (i + 1) % m] = 1.0 / 3.0
    return w


def gossip_matrix(m: int, seed: int = 0) -> np.ndarray:
    """Metropolis-Hastings weights on a random connected graph.

    A ring backbone guarantees connectivity; ``m // 2`` random chords
    (deterministic per seed) shrink the diameter. Metropolis weights
    W_ij = 1 / (1 + max(deg_i, deg_j)) for each edge, W_ii = 1 - sum_j,
    are symmetric and doubly stochastic for ANY undirected graph.
    """
    if m <= 2:
        return server_matrix(m)
    rng = np.random.RandomState(seed)
    edges = {(i, (i + 1) % m) for i in range(m)}
    edges = {(min(a, b), max(a, b)) for a, b in edges}
    for _ in range(m // 2):
        a, b = rng.randint(0, m, size=2)
        if a != b:
            edges.add((min(a, b), max(a, b)))
    deg = np.zeros(m, dtype=np.int64)
    for a, b in edges:
        deg[a] += 1
        deg[b] += 1
    w = np.zeros((m, m))
    for a, b in edges:
        w[a, b] = w[b, a] = 1.0 / (1.0 + max(deg[a], deg[b]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def mixing_matrix(name: str, m: int, seed: int = 0) -> np.ndarray:
    if name == "server":
        return server_matrix(m)
    if name == "ring":
        return ring_matrix(m)
    if name == "gossip":
        return gossip_matrix(m, seed=seed)
    raise ValueError(
        f"unknown topology {name!r}: valid mixing-matrix topologies are "
        "'server', 'ring', 'gossip' (push_sum is matrix-free ratio "
        "consensus — see push_sum_offsets; async_stale/none never mix "
        "through W)")


def push_sum_offsets(m: int) -> tuple:
    """Directed circulant offsets of the push-sum communication graph
    (DESIGN.md §12): the ring backbone — node g pushes shares to
    ``(g + d) % m`` for each offset d. Regular out-degree ``len(offsets)``
    so every node splits its value/weight mass into
    ``len(offsets) + 1`` equal shares (one kept). m = 1 needs no wire;
    m = 2 has a single edge each way (offset 1 covers both directions)."""
    if m <= 1:
        return ()
    if m == 2:
        return (1,)
    return (1, m - 1)


def pod_size(g: int, n_pods: int) -> int:
    """Validated pod size for the hierarchical topology (DESIGN.md §16):
    the G group axis factors into ``n_pods`` CONTIGUOUS pods of equal
    size — group g lives in pod ``g // pod_size``. Contiguity is what
    makes the intra-pod hop a pod-local circulant (a ``jnp.roll`` along
    the within-pod axis) and the cross-pod hop a stride-``pod_size``
    circulant, both expressible as single ppermutes under shard_map."""
    if n_pods < 1:
        raise ValueError(f"n_pods {n_pods} must be >= 1")
    if g % n_pods != 0:
        raise ValueError(
            f"hierarchical topology needs n_pods ({n_pods}) to divide "
            f"n_groups ({g}) into equal contiguous pods; valid pod counts "
            f"for G={g} are the divisors of G")
    return g // n_pods


def ring_circulant(m: int):
    """Circulant decomposition ``(w_self, offsets, w_edge)`` of the
    symmetric ring over m nodes: x_i <- w_self*x_i + w_edge*sum_d
    x_{(i+d) % m}. Matches ``ring_matrix`` exactly (m <= 2 degenerates
    to the dense mean, which is still circulant at those sizes)."""
    if m <= 1:
        return 1.0, (), 0.0
    if m == 2:
        return 0.5, (1,), 0.5
    return 1.0 / 3.0, (1, m - 1), 1.0 / 3.0


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=tol)
            and np.allclose(w.sum(axis=1), 1.0, atol=tol))


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2|. Positive iff repeated mixing reaches consensus."""
    lam = np.sort(np.abs(np.linalg.eigvals(w)))[::-1]
    return float(1.0 - (lam[1] if len(lam) > 1 else 0.0))


def n_edge_sends(w: np.ndarray) -> int:
    """Point-to-point payloads one mixing round costs: each node sends its
    buffer to every neighbor with a nonzero incoming weight (off-diagonal
    nonzeros of W). The wire-byte accounting in exchange.py multiplies
    this by the per-sender codec payload."""
    off = w.copy()
    np.fill_diagonal(off, 0.0)
    return int(np.count_nonzero(off))


def neighbor_offsets(w: np.ndarray) -> tuple:
    """Distinct nonzero circulant offsets of W's off-diagonal support:
    ``d`` is in the result iff some node i receives from ``(i + d) % m``.

    The ppermute hop (sharding/shardexec) ships one neighbor exchange per
    offset instead of an all_gather of all G blocks — O(deg·shard) wire
    for a ring (whose support is exactly {1, m-1}) instead of O(G·shard).
    Irregular graphs (gossip chords) ship the union of offsets; entries a
    node has no edge for carry weight 0 (see ``offset_weights``) and a
    real per-link transport would elide them — the wire accounting counts
    only the true nonzero edges (``n_edge_sends``)."""
    m = w.shape[0]
    off = w.copy()
    np.fill_diagonal(off, 0.0)
    i, j = np.nonzero(off)
    return tuple(sorted({int(d) for d in (j - i) % m}))


def offset_weights(w: np.ndarray, offsets: tuple) -> np.ndarray:
    """(n_offsets, m) offset-decomposed view of W's off-diagonal: entry
    [d_idx, g] is ``W[g, (g + d) % m]`` — node g's weight on the payload
    arriving at offset d (0 where g has no such edge). A verification
    helper (tests reconstruct W's support from it); the ppermute hop
    itself takes this group's full W row via ``jnp.take`` after
    assembling the received blocks (``ShardExec._hop_fn``)."""
    m = w.shape[0]
    g = np.arange(m)
    return np.stack([w[g, (g + d) % m] for d in offsets]).astype(np.float32)
