"""Deterministic fault injection for the exchange subsystem (DESIGN.md §12).

A ``FaultPlan`` is a seeded, replayable description of an unreliable
network: per-edge packet drops (Bernoulli per directed edge per hop),
per-round node stalls (Bernoulli per node) and explicit dropout windows
(node g absent for rounds [r0, r1) — elastic membership). Every mask is a
PURE function of ``(round, seed)`` computed by a counter-based splitmix32
hash over ``(seed, lane, round, hop, sub, index)`` — plain elementwise
uint32 arithmetic on an iota, NOT ``jax.random``: with this jax build's
non-partitionable threefry, GSPMD sharding propagation can rewrite the
threefry lowering and CHANGE the drawn bits between the eager and the
jitted-sharded graph. The hash draws are value-identical under any
partitioning, so

* a run replays bit-for-bit from a checkpoint (the round counter rides
  the comm state),
* the replicated and shard_map exchanges consume IDENTICAL masks (the
  masks are generated outside the shard_map block at full (G,) shape,
  like the int8 stochastic-rounding noise — DESIGN.md §9),
* every test and benchmark cell is reproducible from ``(seed, drop_rate,
  stall_rate, dropouts)`` alone.

Mask semantics (1.0 = delivered / active, 0.0 = lost / stalled):

* ``edge_mask``    one p2p transmission lane (per hop, per circulant
                   offset) — masks ppermute/all_gather hop payloads and
                   push-sum edge deliveries.
* ``matrix_mask``  dense (G, G) delivery mask for one W-hop; entry
                   [j, i] gates the i -> j payload (aligned with
                   ``W[j, i]``). The diagonal is always 1 — a node never
                   loses its own value.
* ``active_mask``  per-round node liveness: stalls (random) and dropout
                   windows (static). A stalled node sends nothing that
                   round and consumes nothing; its queued mass waits.
* ``push_mask``    server-uplink delivery (edge drop x sender liveness).

The plan is a frozen, hashable dataclass so the jitted round can close
over it like the Exchange itself.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

# hash lanes keeping the mask families statistically independent
_LANE_EDGE = 1
_LANE_STALL = 2
_LANE_PUSH = 3
_LANE_MATRIX = 4

_GOLD = 0x9E3779B9          # 2^32 / golden ratio: Weyl-sequence stride


def _mix(x):
    """splitmix32 finalizer: full-avalanche elementwise uint32 hash."""
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule: ``drop_rate`` per-transmission loss,
    ``stall_rate`` per-(round, node) stall probability, ``dropouts`` a
    tuple of ``(g, r0, r1)`` windows during which node g is absent."""
    seed: int = 0
    drop_rate: float = 0.0
    stall_rate: float = 0.0
    dropouts: Tuple[Tuple[int, int, int], ...] = ()

    def __post_init__(self):
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(f"drop_rate {self.drop_rate} not in [0, 1)")
        if not 0.0 <= self.stall_rate < 1.0:
            raise ValueError(f"stall_rate {self.stall_rate} not in [0, 1)")

    @property
    def trivial(self) -> bool:
        """True when the plan injects nothing (all masks identically 1);
        ``get_exchange`` normalizes trivial plans away so the default
        path stays literally the PR-5 code."""
        return (self.drop_rate == 0.0 and self.stall_rate == 0.0
                and not self.dropouts)

    @property
    def expected_delivery(self) -> float:
        """Expected fraction of transmissions delivered per round — the
        delivery rate ``AdaptiveT.from_exchange`` reprices the comm cost
        with (dropout windows are transient, not priced)."""
        return (1.0 - self.drop_rate) * (1.0 - self.stall_rate) ** 2

    # -- keyed mask primitives (jittable, pure in round) -------------------

    def _key(self, lane: int, rnd, hop: int = 0, sub: int = 0):
        """uint32 hash state from the (seed, lane, round, hop, sub)
        counter chain — ``rnd`` may be a traced scalar."""
        h = jnp.uint32(self.seed & 0xFFFFFFFF)
        for w in (lane, rnd, hop, sub):
            w32 = jnp.asarray(w).astype(jnp.uint32)
            h = _mix(h ^ (w32 * jnp.uint32(_GOLD) + jnp.uint32(1)))
        return h

    def _uniform(self, key, shape):
        """[0, 1) uniforms: one hash per counter index. Elementwise ops
        over an iota are value-invariant under jit AND sharding."""
        n = 1
        for s in shape:
            n *= s
        idx = jnp.arange(n, dtype=jnp.uint32)
        bits = _mix(key ^ (idx * jnp.uint32(_GOLD) + jnp.uint32(1)))
        return (bits.astype(jnp.float32) / jnp.float32(2 ** 32)) \
            .reshape(shape)

    def _deliver(self, key, shape):
        if self.drop_rate == 0.0:
            return jnp.ones(shape, jnp.float32)
        u = self._uniform(key, shape)
        return (u >= self.drop_rate).astype(jnp.float32)

    def edge_mask(self, rnd, hop: int, offset_idx: int, n: int):
        """(n,) delivery mask for one transmission lane — receiver-indexed
        entries of the ``offset_idx``-th circulant offset at ``hop``."""
        return self._deliver(self._key(_LANE_EDGE, rnd, hop, offset_idx),
                             (n,))

    def matrix_mask(self, rnd, hop: int, n: int):
        """(n, n) delivery mask for one dense W-hop; [j, i] gates i -> j
        (sender liveness folded in), diagonal pinned to 1."""
        m = self._deliver(self._key(_LANE_MATRIX, rnd, hop), (n, n))
        act = self.active_mask(rnd, n)
        m = m * act[None, :]                   # column i: sender i stalled
        return jnp.where(jnp.eye(n, dtype=bool), 1.0, m)

    def active_mask(self, rnd, n: int):
        """(n,) liveness this round: 1 = participating. Stalls are
        Bernoulli per (round, node); dropout windows are static."""
        if self.stall_rate > 0.0:
            u = self._uniform(self._key(_LANE_STALL, rnd), (n,))
            act = (u >= self.stall_rate).astype(jnp.float32)
        else:
            act = jnp.ones((n,), jnp.float32)
        for g, r0, r1 in self.dropouts:
            absent = jnp.logical_and(rnd >= r0, rnd < r1)
            act = act.at[g].set(jnp.where(absent, 0.0, act[g]))
        return act

    def push_mask(self, rnd, n: int):
        """(n,) server-uplink delivery: the push of a stalled/absent node
        never leaves it, and a live node's push drops at ``drop_rate``."""
        m = self._deliver(self._key(_LANE_PUSH, rnd), (n,))
        return m * self.active_mask(rnd, n)
