"""Deterministic fault injection for the exchange subsystem (DESIGN.md §12).

A ``FaultPlan`` is a seeded, replayable description of an unreliable
network: per-edge packet drops (Bernoulli per directed edge per hop),
per-round node stalls (Bernoulli per node) and explicit dropout windows
(node g absent for rounds [r0, r1) — elastic membership). Every mask is a
PURE function of ``(round, seed)`` computed by a counter-based splitmix32
hash over ``(seed, lane, round, hop, sub, index)`` — plain elementwise
uint32 arithmetic on an iota, NOT ``jax.random``: with this jax build's
non-partitionable threefry, GSPMD sharding propagation can rewrite the
threefry lowering and CHANGE the drawn bits between the eager and the
jitted-sharded graph. The hash draws are value-identical under any
partitioning, so

* a run replays bit-for-bit from a checkpoint (the round counter rides
  the comm state),
* the replicated and shard_map exchanges consume IDENTICAL masks (the
  masks are generated outside the shard_map block at full (G,) shape,
  like the int8 stochastic-rounding noise — DESIGN.md §9),
* every test and benchmark cell is reproducible from ``(seed, drop_rate,
  stall_rate, dropouts)`` alone.

Mask semantics (1.0 = delivered / active, 0.0 = lost / stalled):

* ``edge_mask``    one p2p transmission lane (per hop, per circulant
                   offset) — masks ppermute/all_gather hop payloads and
                   push-sum edge deliveries.
* ``matrix_mask``  dense (G, G) delivery mask for one W-hop; entry
                   [j, i] gates the i -> j payload (aligned with
                   ``W[j, i]``). The diagonal is always 1 — a node never
                   loses its own value.
* ``active_mask``  per-round node liveness: stalls (random) and dropout
                   windows (static). A stalled node sends nothing that
                   round and consumes nothing; its queued mass waits.
* ``push_mask``    server-uplink delivery (edge drop x sender liveness).

The plan is a frozen, hashable dataclass so the jitted round can close
over it like the Exchange itself.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Named seed-lane registry (the ONE place rng-lane allocation lives).
#
# Two independent namespaces, both replayable from a single user seed:
#
# * HASH_LANES — the splitmix32 lane constants mixed into ``FaultPlan._key``
#   keeping its mask families statistically independent of each other.
# * CODEC_SEED_OFFSETS / FAULT_SEED_OFFSETS — derived-seed offsets: each
#   independent rng CONSUMER gets ``base_seed + offset`` so its bits never
#   correlate with a sibling consumer of the same base seed (the moment
#   codec's historical ``seed + 1`` and the downlink codec's ad-hoc
#   ``seed + 2`` now live here by name, alongside the per-tier fault and
#   cross-tier codec lanes). A new consumer MUST claim a fresh offset in
#   its namespace — tests/test_faults.py asserts uniqueness so a collision
#   fails loudly instead of silently correlating two mask families.
# ---------------------------------------------------------------------------

HASH_LANES = {
    "fault/edge": 1,
    "fault/stall": 2,
    "fault/push": 3,
    "fault/matrix": 4,
}

# offsets on the --seed (codec) base: one per independent codec consumer
CODEC_SEED_OFFSETS = {
    "params": 0,       # the uplink params codec (the base itself)
    "moments": 1,      # every moment stream's codec (DESIGN.md §10)
    "downlink": 2,     # the broadcast-reply codec (DESIGN.md §11)
    "inter": 3,        # the hierarchical cross-tier codec (DESIGN.md §16)
}

# offsets on the --fault-seed base: one per independent fault plan
FAULT_SEED_OFFSETS = {
    "flat": 0,         # a single-tier FaultPlan (the base itself)
    "intra": 1,        # the hierarchical intra-pod (ICI) tier
    "inter": 2,        # the hierarchical cross-pod (DCN) tier
}


def hash_lane(name: str) -> int:
    """The registered splitmix32 hash-lane constant for ``name``."""
    if name not in HASH_LANES:
        raise ValueError(f"unknown hash lane {name!r}: valid lanes are "
                         f"{tuple(HASH_LANES)}")
    return HASH_LANES[name]


def codec_seed(base: int, consumer: str) -> int:
    """The derived seed for a named codec consumer of ``base``."""
    if consumer not in CODEC_SEED_OFFSETS:
        raise ValueError(f"unknown codec seed lane {consumer!r}: valid "
                         f"lanes are {tuple(CODEC_SEED_OFFSETS)}")
    return (base + CODEC_SEED_OFFSETS[consumer]) & 0xFFFFFFFF


def fault_seed_for(base: int, tier: str) -> int:
    """The derived seed for a named fault-plan tier of ``base``."""
    if tier not in FAULT_SEED_OFFSETS:
        raise ValueError(f"unknown fault seed tier {tier!r}: valid "
                         f"tiers are {tuple(FAULT_SEED_OFFSETS)}")
    return (base + FAULT_SEED_OFFSETS[tier]) & 0xFFFFFFFF


# legacy aliases (every mask call routes through the registry now)
_LANE_EDGE = HASH_LANES["fault/edge"]
_LANE_STALL = HASH_LANES["fault/stall"]
_LANE_PUSH = HASH_LANES["fault/push"]
_LANE_MATRIX = HASH_LANES["fault/matrix"]

_GOLD = 0x9E3779B9          # 2^32 / golden ratio: Weyl-sequence stride


def _mix(x):
    """splitmix32 finalizer: full-avalanche elementwise uint32 hash."""
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule: ``drop_rate`` per-transmission loss,
    ``stall_rate`` per-(round, node) stall probability, ``dropouts`` a
    tuple of ``(g, r0, r1)`` windows during which node g is absent."""
    seed: int = 0
    drop_rate: float = 0.0
    stall_rate: float = 0.0
    dropouts: Tuple[Tuple[int, int, int], ...] = ()

    def __post_init__(self):
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(f"drop_rate {self.drop_rate} not in [0, 1)")
        if not 0.0 <= self.stall_rate < 1.0:
            raise ValueError(f"stall_rate {self.stall_rate} not in [0, 1)")

    @property
    def trivial(self) -> bool:
        """True when the plan injects nothing (all masks identically 1);
        ``get_exchange`` normalizes trivial plans away so the default
        path stays literally the PR-5 code."""
        return (self.drop_rate == 0.0 and self.stall_rate == 0.0
                and not self.dropouts)

    @property
    def expected_delivery(self) -> float:
        """Expected fraction of transmissions delivered per round — the
        delivery rate ``AdaptiveT.from_exchange`` reprices the comm cost
        with (dropout windows are transient, not priced)."""
        return (1.0 - self.drop_rate) * (1.0 - self.stall_rate) ** 2

    # -- keyed mask primitives (jittable, pure in round) -------------------

    def _key(self, lane: int, rnd, hop: int = 0, sub: int = 0):
        """uint32 hash state from the (seed, lane, round, hop, sub)
        counter chain — ``rnd`` may be a traced scalar."""
        h = jnp.uint32(self.seed & 0xFFFFFFFF)
        for w in (lane, rnd, hop, sub):
            w32 = jnp.asarray(w).astype(jnp.uint32)
            h = _mix(h ^ (w32 * jnp.uint32(_GOLD) + jnp.uint32(1)))
        return h

    def _uniform(self, key, shape):
        """[0, 1) uniforms: one hash per counter index. Elementwise ops
        over an iota are value-invariant under jit AND sharding."""
        n = 1
        for s in shape:
            n *= s
        idx = jnp.arange(n, dtype=jnp.uint32)
        bits = _mix(key ^ (idx * jnp.uint32(_GOLD) + jnp.uint32(1)))
        return (bits.astype(jnp.float32) / jnp.float32(2 ** 32)) \
            .reshape(shape)

    def _deliver(self, key, shape):
        if self.drop_rate == 0.0:
            return jnp.ones(shape, jnp.float32)
        u = self._uniform(key, shape)
        return (u >= self.drop_rate).astype(jnp.float32)

    def edge_mask(self, rnd, hop: int, offset_idx: int, n: int):
        """(n,) delivery mask for one transmission lane — receiver-indexed
        entries of the ``offset_idx``-th circulant offset at ``hop``."""
        return self._deliver(self._key(_LANE_EDGE, rnd, hop, offset_idx),
                             (n,))

    def matrix_mask(self, rnd, hop: int, n: int):
        """(n, n) delivery mask for one dense W-hop; [j, i] gates i -> j
        (sender liveness folded in), diagonal pinned to 1."""
        m = self._deliver(self._key(_LANE_MATRIX, rnd, hop), (n, n))
        act = self.active_mask(rnd, n)
        m = m * act[None, :]                   # column i: sender i stalled
        return jnp.where(jnp.eye(n, dtype=bool), 1.0, m)

    def active_mask(self, rnd, n: int):
        """(n,) liveness this round: 1 = participating. Stalls are
        Bernoulli per (round, node); dropout windows are static."""
        if self.stall_rate > 0.0:
            u = self._uniform(self._key(_LANE_STALL, rnd), (n,))
            act = (u >= self.stall_rate).astype(jnp.float32)
        else:
            act = jnp.ones((n,), jnp.float32)
        for g, r0, r1 in self.dropouts:
            absent = jnp.logical_and(rnd >= r0, rnd < r1)
            act = act.at[g].set(jnp.where(absent, 0.0, act[g]))
        return act

    def push_mask(self, rnd, n: int):
        """(n,) server-uplink delivery: the push of a stalled/absent node
        never leaves it, and a live node's push drops at ``drop_rate``."""
        m = self._deliver(self._key(_LANE_PUSH, rnd), (n,))
        return m * self.active_mask(rnd, n)


@dataclasses.dataclass(frozen=True)
class TieredFaultPlan:
    """Per-tier fault schedule for the hierarchical topology
    (DESIGN.md §16): ``intra`` governs pod-internal (ICI) hops,
    ``inter`` governs cross-pod (DCN) transmissions. The tiers draw
    from INDEPENDENT seed lanes (``fault_seed_for(base, tier)``), so
    one user-facing ``--fault-seed`` yields uncorrelated mask families
    per tier. Either tier may be None (= that tier is reliable); both
    None is the trivial plan and is normalized away by ``get_exchange``
    exactly like a trivial flat ``FaultPlan``."""
    intra: Optional[FaultPlan] = None
    inter: Optional[FaultPlan] = None

    def __post_init__(self):
        # normalize trivial tiers to None so "reliable tier" has ONE
        # representation and the fast paths key off `is None` alone
        if self.intra is not None and self.intra.trivial:
            object.__setattr__(self, "intra", None)
        if self.inter is not None and self.inter.trivial:
            object.__setattr__(self, "inter", None)

    @property
    def trivial(self) -> bool:
        return self.intra is None and self.inter is None

    @property
    def expected_delivery_intra(self) -> float:
        return 1.0 if self.intra is None else self.intra.expected_delivery

    @property
    def expected_delivery_inter(self) -> float:
        return 1.0 if self.inter is None else self.inter.expected_delivery

    @property
    def expected_delivery(self) -> float:
        """Conservative overall delivery rate: the product of the tier
        rates (a round's payload crosses whichever tiers it touches)."""
        return self.expected_delivery_intra * self.expected_delivery_inter
