"""Exchange backends: pluggable inter-group communication (DESIGN.md §8).

An ``Exchange`` is the round's communication step — the thing that was a
hard-coded ``average_groups`` mean before this subsystem existed. It
composes a TOPOLOGY (who talks to whom) with a CODEC (what goes on the
wire) and reports exact per-round wire bytes:

  server       star topology: mean over G + broadcast back. With the fp32
               codec this is the SAME ops as the pre-comm
               ``average_groups`` — bit-exact, the default.
  ring/gossip  decentralized neighbor averaging ``x <- W^k x`` with an
               explicit doubly-stochastic mixing matrix W over the G axis
               (topology.py), ``k = mix_rounds`` hops per round.
  async_stale  server averaging with bounded staleness s, simulated
               deterministically on the G axis: in round n only groups
               with ``(g + n) % (s + 1) == 0`` push a fresh model; the
               server averages each group's LAST pushed model. Every
               group's contribution is at most s rounds old; s = 0 is
               exactly ``server``.
  none         no communication (W = I, zero wire bytes) — the
               disconnected baseline for ablations and parity tests.

All backends preserve the G-mean (doubly-stochastic mixing / exact mean),
so every topology optimizes the same average objective; they differ in
consensus speed and wire bytes. Exchanges are frozen dataclasses closed
over by the jitted round; per-round memory (codec residuals, staleness
buffers, the round counter) lives in the train state under ``"comm"``
(``localsgd.init_state(..., exchange=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import codecs as codecs_mod
from repro.comm import topology as topo_mod

TOPOLOGIES = ("server", "ring", "gossip", "async_stale", "none")


@dataclasses.dataclass(frozen=True)
class Exchange:
    topology: str
    codec: codecs_mod.Codec
    n_groups: int
    mix_rounds: int = 1
    staleness: int = 0
    # (G, G) doubly-stochastic mixing matrix; None = exact mean+broadcast
    # (server/async) or identity (none) — those paths avoid the matmul so
    # the default stays bit-exact with the pre-comm ``average_groups``.
    w: Optional[np.ndarray] = None

    @property
    def name(self) -> str:
        return f"{self.topology}/{self.codec.name}"

    @property
    def stateful(self) -> bool:
        if self.topology == "none":
            return False   # no wire: the codec never runs, no state
        return self.topology == "async_stale" or self.codec.stateful

    @property
    def supports_opt_state_averaging(self) -> bool:
        """async_stale keeps its staleness buffer for params only, so
        rounds must run with average_opt_state=False (the single source
        of the rule the launchers and the localsgd guard consult)."""
        return self.topology != "async_stale"

    # -- state ------------------------------------------------------------

    def init(self, params_G) -> dict:
        """Comm state for a G-grouped params pytree/buffer ({} when the
        exchange is stateless — the round then carries no "comm" key)."""
        state = {}
        if not self.stateful:
            return state
        if self.codec.stateful:
            state["codec"] = self.codec.init(params_G)
        if self.topology == "async_stale":
            # a real COPY: the staleness buffer must not alias the live
            # params (donated train states would double-donate the buffer)
            state["pushed"] = jax.tree.map(jnp.copy, params_G)
            state["round"] = jnp.zeros((), jnp.int32)
        return state

    # -- mixing -----------------------------------------------------------

    def _mix_leaf_once(self, x, w):
        return jnp.tensordot(w, x.astype(jnp.float32),
                             axes=[[1], [0]]).astype(x.dtype)

    def _mix_leaf(self, x):
        if self.topology == "none":
            return x
        if self.w is None:  # server/async: exact mean, broadcast back —
            # identical ops to the pre-comm average_groups (bit-exact)
            m = jnp.mean(x, axis=0, keepdims=True)
            return jnp.broadcast_to(m, x.shape)
        # codec-free k-hop mix: ONE upcast, all hops in fp32, one downcast
        # (per-hop round-tripping to a bf16 leaf dtype would inject k-1
        # extra rounding steps; the lossy path casts per hop by design —
        # that IS the wire behavior there)
        w = jnp.asarray(self.w, jnp.float32)
        y = x.astype(jnp.float32)
        for _ in range(self.mix_rounds):
            y = jnp.tensordot(w, y, axes=[[1], [0]])
        return y.astype(x.dtype)

    def mix(self, tree):
        """Codec-free mixing over the G axis (opt-state moments follow the
        topology at full fp32 width — see DESIGN.md §8)."""
        return jax.tree.map(self._mix_leaf, tree)

    # -- the communication step -------------------------------------------

    def _decentral_lossy(self, x_G, x0_G, cstate):
        """ring/gossip with a lossy codec: RE-compress at every mixing hop
        (each hop's payload is a fresh wire transmission — the byte
        accounting already counts per hop, and now the noise model does
        too). Each node encodes the delta vs its previously TRANSMITTED
        (decoded) value — hop 0 vs the round start, hop h vs hop h-1's
        decoded payload — so what's compressed is a hop-sized difference
        that shrinks with consensus, and error feedback (top-k residual)
        updates once per hop. Returns (mixed, codec_state)."""
        w = jnp.asarray(self.w, jnp.float32)
        y, ref = x_G, x0_G
        for _ in range(self.mix_rounds):
            delta = jax.tree.map(lambda a, b: a - b, y, ref)
            delta_hat, cstate = self.codec.compress(delta, cstate)
            y_hat = jax.tree.map(lambda b, d: b + d, ref, delta_hat)
            ref = y_hat
            y = jax.tree.map(lambda v: self._mix_leaf_once(v, w), y_hat)
        return y, cstate

    def params(self, x_G, x0_G, comm_state: dict):
        """One exchange of the models: ``x_G`` are the post-local-step
        params (leading G axis), ``x0_G`` the round-start params — the
        codec reference: lossy codecs transmit the delta ``x_G - x0_G``
        so quantization error vanishes as rounds converge. Returns
        ``(mixed_x_G, new_comm_state)``."""
        new_state = dict(comm_state)
        if self.codec.identity or self.topology == "none":
            # "none" skips the codec too: nothing goes on the wire, so a
            # no-comm baseline must not inject quantization noise
            x_hat = x_G
        elif self.w is not None:
            # decentralized + lossy: codec applied per mixing hop
            mixed, cstate = self._decentral_lossy(
                x_G, x0_G, comm_state.get("codec", {}))
            if self.codec.stateful:
                new_state["codec"] = cstate
            return mixed, new_state
        else:
            delta = jax.tree.map(lambda a, b: a - b, x_G, x0_G)
            delta_hat, cstate = self.codec.compress(
                delta, comm_state.get("codec", {}))
            x_hat = jax.tree.map(lambda b, d: b + d, x0_G, delta_hat)
            if self.codec.stateful:
                new_state["codec"] = cstate
        if self.topology != "async_stale":
            return self.mix(x_hat), new_state
        # bounded-staleness server: refresh only this round's pushers,
        # average everyone's last push
        rnd = comm_state["round"]
        fresh = (jnp.arange(self.n_groups) + rnd) % (self.staleness + 1) == 0

        def refresh(pushed, x):
            keep = fresh.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.where(keep, x, pushed)

        pushed = jax.tree.map(refresh, comm_state["pushed"], x_hat)
        new_state["pushed"] = pushed
        new_state["round"] = rnd + 1
        return self.mix(pushed), new_state

    # -- wire accounting ---------------------------------------------------

    def senders_per_round(self) -> float:
        """UPLINK payloads one round puts on the wire. server: G uplinks.
        ring/gossip: one payload per directed edge per mixing hop.
        async_stale: amortized over the staleness cycle (each group pushes
        once per s+1 rounds; exact when (s+1) divides G)."""
        if self.topology == "none":
            return 0.0
        if self.topology == "server":
            return float(self.n_groups)
        if self.topology == "async_stale":
            return self.n_groups / (self.staleness + 1)
        return float(topo_mod.n_edge_sends(self.w) * self.mix_rounds)

    def receivers_per_round(self) -> float:
        """DOWNLINK payloads per round, per topology (DESIGN.md §8):
        server broadcasts the new average to all G groups; ring/gossip are
        symmetric (every edge payload is one node's uplink and its
        neighbor's downlink, so down == up); async_stale answers each
        PUSH with the fresh average (pull-on-push — amortized like the
        uplink; note the simulated round idealizes this by handing every
        group the mean, the accounting models the real per-push pull)."""
        # every topology's downlink currently mirrors its uplink count
        # (single source until one actually diverges)
        return self.senders_per_round()

    def _per_payload_bytes(self, n_params: int, moment_elems: int) -> int:
        """One payload: the codec'd params buffer plus (when the round
        averages opt state) the moment buffers at full fp32 width. The
        downlink rides at the same width — the server re-encodes the new
        mean as a delta against its last broadcast with the same codec."""
        return self.codec.wire_bytes(n_params) + 4 * moment_elems

    def wire_bytes_up(self, n_params: int, moment_elems: int = 0) -> int:
        return int(round(self.senders_per_round()
                         * self._per_payload_bytes(n_params, moment_elems)))

    def wire_bytes_down(self, n_params: int, moment_elems: int = 0) -> int:
        return int(round(self.receivers_per_round()
                         * self._per_payload_bytes(n_params, moment_elems)))

    def wire_bytes_per_round(self, n_params: int,
                             moment_elems: int = 0) -> int:
        """TOTAL physical payload bytes per round (was uplink-only before
        downlink accounting landed; per-direction numbers are
        ``wire_bytes_up`` / ``wire_bytes_down``). server/async: pushes and
        broadcast replies are DISTINCT payloads — the total is their sum.
        ring/gossip: each edge payload is one node's uplink AND its
        neighbor's downlink — the SAME transmission viewed from both
        endpoints — so the total counts it once, not twice."""
        up = self.wire_bytes_up(n_params, moment_elems)
        if self.w is not None:
            return up
        return up + self.wire_bytes_down(n_params, moment_elems)


def get_exchange(topology: str = "server", codec: str = "fp32",
                 n_groups: int = 1, *, mix_rounds: int = 1,
                 staleness: int = 1, seed: int = 0, impl: str = "auto",
                 chunk: int = 256, topk_frac: float = 0.05) -> Exchange:
    """Build an Exchange from names (the ``--comm`` / ``--codec`` flags)."""
    if topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r} "
                         f"(have {TOPOLOGIES})")
    if topology == "async_stale" and codec == "topk":
        # the staleness schedule DROPS non-pushing groups' deltas by
        # design; an error-feedback residual would instead absorb their
        # top-k entries as "delivered" and silently lose them
        raise NotImplementedError(
            "async_stale + topk: error feedback assumes every round's "
            "payload is delivered, but the staleness schedule drops "
            "non-pushing rounds (DESIGN.md §8)")
    c = codecs_mod.get_codec(codec, impl=impl, chunk=chunk,
                             topk_frac=topk_frac, seed=seed)
    w = None
    if topology in ("ring", "gossip"):
        w = topo_mod.mixing_matrix(topology, n_groups, seed=seed)
    return Exchange(topology=topology, codec=c, n_groups=n_groups,
                    mix_rounds=mix_rounds,
                    staleness=staleness if topology == "async_stale" else 0,
                    w=w)


def default_exchange(n_groups: int) -> Exchange:
    """The pre-comm behavior: star mean, uncompressed — bit-exact with
    ``average_groups``."""
    return get_exchange("server", "fp32", n_groups)
