"""Exchange backends: pluggable inter-group communication (DESIGN.md §8).

An ``Exchange`` is the round's communication step — the thing that was a
hard-coded ``average_groups`` mean before this subsystem existed. It
composes a TOPOLOGY (who talks to whom) with a per-stream CODEC policy
(what goes on the wire) and reports exact per-round wire bytes:

  server       star topology: mean over G + broadcast back. With the fp32
               codec this is the SAME ops as the pre-comm
               ``average_groups`` — bit-exact, the default.
  ring/gossip  decentralized neighbor averaging ``x <- W^k x`` with an
               explicit doubly-stochastic mixing matrix W over the G axis
               (topology.py), ``k = mix_rounds`` hops per round.
  async_stale  server averaging with bounded staleness s, simulated
               deterministically on the G axis: in round n only groups
               with ``(g + n) % (s + 1) == 0`` push a fresh model; the
               server averages each group's LAST pushed model. Every
               group's contribution is at most s rounds old; s = 0 is
               exactly ``server``.
  push_sum     ratio consensus on the directed ring graph (DESIGN.md
               §12): each node pushes equal shares of a (value, weight)
               mass pair to its out-neighbors and estimates the model as
               the ratio. Mass counters (``comm["mass"]`` + per-edge
               backlogs) make the estimate unbiased under packet loss —
               an undelivered share stays queued on its edge and the
               next delivered payload carries it — where the
               doubly-stochastic topologies above measurably bias.
  none         no communication (W = I, zero wire bytes) — the
               disconnected baseline for ablations and parity tests.
  hierarchical two-tier exchange (DESIGN.md §16): the G axis factors
               into ``n_pods`` contiguous pods of ``G // n_pods``
               groups. Each round first mixes WITHIN pods
               (``intra_topology``: a pod-local circulant ring or an
               exact pod mean) over the fast reliable tier, then ACROSS
               pods (``inter_topology``: push_sum ratio consensus with
               mass-conserving backlogs over the lossy DCN tier, or a
               reliable leader-mean server step) — with an independent
               cross-tier codec (``inter_codec``, e.g. int8 across +
               bf16 within), per-tier fault plans
               (``faults.TieredFaultPlan``) on independent seed lanes,
               and per-tier wire/participation/delivery accounting.

Fault injection (DESIGN.md §12): an optional ``FaultPlan``
(comm/faults.py — seeded, replayable, pure in ``(round, seed)``) masks
per-edge packet drops and per-round node stalls/dropouts. server/async
degrade gracefully — a dropped push keeps that group's LAST delivered
model in the staleness buffer (bounded-staleness retry), error-feedback
residuals DEFER undelivered payloads (codecs.defer_undelivered), and the
round reports a ``participation`` metric. ring/gossip under drops are
the demonstrated-biased configuration (a receiver substitutes its own
value for a lost neighbor payload: rows stay stochastic, columns do not
— the mean drifts); push_sum is the loss-tolerant alternative. No plan
(the default) leaves every code path bit-exact with the fault-free
engine.

Overlap (DESIGN.md §14): ``overlap=True`` turns the round into its
double-buffered delayed-mixing variant — the round mixes the PREVIOUS
round's encoded payload (riding ``comm_state["inflight"]``) while its
own local steps run, then encodes a fresh payload for the next round.
Semantically this is bounded staleness s=1 on every topology; the mixing
collective is issued before the local-step block in the jitted round so
a parallel backend can overlap communication with compute.
``get_exchange`` refuses the combinations whose wire interleaves with
the mixing (async_stale, push_sum, downlink codecs, fault plans,
multi-hop rounds).

The round's payload is MULTI-STREAM (DESIGN.md §10): the ``params``
stream plus one stream per optimizer moment buffer (momentum ``mu``,
adamw ``m``/``v``) when the round averages opt state. ``codec`` applies
to the params stream, ``moment_codec`` to every moment stream; each
stream keeps its OWN codec state (rng counter / error-feedback residual)
under ``comm_state["codec"][stream]`` and — for async_stale — its own
staleness buffer (params under ``"pushed"``, moments under
``"pushed_opt"][stream]``), which is what lifted the old
``average_opt_state=False`` restriction on async rounds.

All backends preserve the G-mean (doubly-stochastic mixing / exact mean),
so every topology optimizes the same average objective; they differ in
consensus speed and wire bytes. Exchanges are frozen dataclasses closed
over by the jitted round; per-round memory (codec residuals, staleness
buffers, the round counter) lives in the train state under ``"comm"``
(``localsgd.init_state(..., exchange=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import codecs as codecs_mod
from repro.comm import faults as faults_mod
from repro.comm import topology as topo_mod

TOPOLOGIES = ("server", "ring", "gossip", "async_stale", "push_sum",
              "none", "hierarchical")

INTRA_TOPOLOGIES = ("ring", "server")        # pod-internal tier
INTER_TOPOLOGIES = ("push_sum", "server")    # cross-pod tier

# moment streams default to the uncompressed wire (one shared instance:
# the identity codec is stateless and pure)
_FP32 = codecs_mod.fp32()


def elect_leaders(act, n_pods: int):
    """Deterministic pod-leader election from a (G,) liveness mask
    (DESIGN.md §16): the leader of each contiguous pod is its FIRST live
    member — pure in the mask, so a checkpoint resume replays the same
    election and every node agrees without a round of coordination.
    Returns ``(leader_w, pod_live)``: ``leader_w`` a (G,) one-hot-per-pod
    weight vector (all-zero for a fully-dead pod) and ``pod_live`` the
    (n_pods,) pod liveness (a pod is live while ANY member is — leader
    dropout re-elects instead of partitioning the pod)."""
    a = act.reshape(n_pods, -1)
    pod_live = jnp.max(a, axis=1)
    lead = jnp.argmax(a, axis=1)          # first max = first live member
    onehot = (jax.nn.one_hot(lead, a.shape[1], dtype=jnp.float32)
              * pod_live[:, None])
    return onehot.reshape(-1), pod_live


@dataclasses.dataclass(frozen=True)
class Exchange:
    topology: str
    codec: codecs_mod.Codec             # the params stream's codec
    n_groups: int
    mix_rounds: int = 1
    staleness: int = 0
    # (G, G) doubly-stochastic mixing matrix; None = exact mean+broadcast
    # (server/async) or identity (none) — those paths avoid the matmul so
    # the default stays bit-exact with the pre-comm ``average_groups``.
    w: Optional[np.ndarray] = None
    # codec for every MOMENT stream (None -> fp32 identity: moments ride
    # uncompressed, the pre-§10 behavior). topk is refused here — see
    # ``get_exchange``.
    moment_codec: Optional[codecs_mod.Codec] = None
    # codec for the server/async DOWNLINK (the broadcast reply), applied
    # to EVERY broadcast stream independently of the uplink codec
    # (DESIGN.md §11). None (default) keeps today's behavior bit-exactly:
    # the broadcast is idealized (no noise) and the accounting prices the
    # downlink at the uplink codec's width. When set, the server
    # re-encodes each round's mean as a delta vs its LAST decoded
    # broadcast (reference + codec state under comm_state["down"]) and
    # the accounting prices the downlink at THIS codec's width.
    downlink_codec: Optional[codecs_mod.Codec] = None
    # route int8/fp16/bf16 flat-buffer streams through the fused
    # codec-mix epilogue (kernels/exchange_epilogue.py — one pass over
    # the (G, N) buffer instead of the staged encode/decode/mix chain;
    # bit-identical by contract). False = the staged reference path.
    fused: bool = True
    # deterministic fault schedule (comm/faults.py, DESIGN.md §12). None
    # (default) is the reliable network — every path stays literally the
    # fault-free code, bit-exact with the PR-5 exchange.
    fault_plan: Optional[faults_mod.FaultPlan] = None
    # double-buffered delayed mixing (DESIGN.md §14): the round MIXES the
    # PREVIOUS round's encoded payload (comm_state["inflight"]) — the
    # collective is issued before the local-step block so a parallel
    # backend overlaps it with compute — and encodes a fresh payload for
    # the next round. One-round-stale mixing on every topology
    # (async_stale s=1 semantics); False (default) keeps the barrier
    # engine bit-exactly.
    overlap: bool = False
    # hierarchical (DESIGN.md §16): the tier factoring G = n_pods x
    # pod_size (0 = not hierarchical), the per-tier mixing steps, and
    # the optional cross-tier codec (None -> each stream's own codec
    # rides the inter tier too). ``fault_plan`` is a TieredFaultPlan
    # when the topology is hierarchical (per-tier seed lanes).
    n_pods: int = 0
    intra_topology: str = "ring"
    inter_topology: str = "push_sum"
    inter_codec: Optional[codecs_mod.Codec] = None

    @property
    def mcodec(self) -> codecs_mod.Codec:
        return self.moment_codec if self.moment_codec is not None else _FP32

    @property
    def hierarchical(self) -> bool:
        return self.topology == "hierarchical"

    @property
    def pod_len(self) -> int:
        """Members per pod (validated tier factoring, DESIGN.md §16)."""
        return topo_mod.pod_size(self.n_groups, self.n_pods)

    @property
    def intra_plan(self) -> Optional[faults_mod.FaultPlan]:
        p = self.fault_plan
        return p.intra if isinstance(p, faults_mod.TieredFaultPlan) else None

    @property
    def inter_plan(self) -> Optional[faults_mod.FaultPlan]:
        p = self.fault_plan
        return p.inter if isinstance(p, faults_mod.TieredFaultPlan) else None

    def inter_stream_codec(self, stream: str) -> codecs_mod.Codec:
        """The codec a stream rides the CROSS-POD tier with: the
        dedicated ``inter_codec`` when set, else the stream's own codec
        (single codec policy across both tiers)."""
        return (self.inter_codec if self.inter_codec is not None
                else self.stream_codec(stream))

    @property
    def faulty(self) -> bool:
        """True when a FaultPlan is active on a topology with a wire."""
        return self.fault_plan is not None and self.topology != "none"

    @property
    def delivery_rate(self) -> float:
        """Expected fraction of transmissions delivered per round (1.0
        for the reliable network) — what reprices the wire accounting
        under delivered-edge pricing and ``AdaptiveT.from_exchange``."""
        return (self.fault_plan.expected_delivery
                if self.fault_plan is not None else 1.0)

    @property
    def delivery_rate_intra(self) -> float:
        """Delivery rate of the pod-internal tier. Flat topologies are
        single-tier — the whole wire is "intra" by the §13 convention
        (one big pod), so this equals ``delivery_rate`` there."""
        if not self.hierarchical:
            return self.delivery_rate
        p = self.fault_plan
        return (p.expected_delivery_intra
                if isinstance(p, faults_mod.TieredFaultPlan) else 1.0)

    @property
    def delivery_rate_inter(self) -> float:
        """Delivery rate of the cross-pod tier (1.0 for flat topologies
        — no cross-pod wire exists, §13 convention)."""
        if not self.hierarchical:
            return 1.0
        p = self.fault_plan
        return (p.expected_delivery_inter
                if isinstance(p, faults_mod.TieredFaultPlan) else 1.0)

    @property
    def p2p(self) -> bool:
        """Topologies whose payloads are symmetric point-to-point edges
        (one transmission is the sender's uplink AND the receiver's
        downlink, so the byte total counts it once): explicit-W mixing
        and push_sum."""
        return self.w is not None or self.topology == "push_sum"

    @property
    def lossy_downlink(self) -> bool:
        # w is None: the broadcast-reply model only exists for mean
        # topologies (server/async). On ring/gossip the edge payload IS
        # the downlink and the mixed rows differ per group, so
        # _apply_downlink's single-row encode would be wrong — a
        # directly-constructed p2p exchange no-ops here (get_exchange
        # refuses the combination up front with the reason)
        return (self.downlink_codec is not None
                and not self.downlink_codec.identity
                and self.w is None
                and self.topology not in ("none", "push_sum",
                                          "hierarchical"))

    def stream_codec(self, stream: str) -> codecs_mod.Codec:
        """The per-stream codec policy: params get ``codec``, every
        moment stream gets ``moment_codec`` (DESIGN.md §10)."""
        return self.codec if stream == "params" else self.mcodec

    def lossy_stream(self, stream: str) -> bool:
        """True when ``stream`` needs its round-start reference in
        ``xs0`` — some codec on its path encodes a round DELTA. Covers
        the stream's own codec AND the hierarchical cross-tier codec on
        the server inter tier (the int8 cell encodes the delta vs the
        round start, DESIGN.md §16); identity-codec streams never touch
        x0, keeping the default path bit-exact and donation-safe."""
        if not self.stream_codec(stream).identity:
            return True
        return (self.hierarchical and self.inter_topology == "server"
                and not self.inter_stream_codec(stream).identity)

    @property
    def name(self) -> str:
        if self.hierarchical:
            base = (f"hier[{self.intra_topology}x{self.n_pods}"
                    f"|{self.inter_topology}]/{self.codec.name}")
        else:
            base = f"{self.topology}/{self.codec.name}"
        if not self.mcodec.identity:
            base += f"+m:{self.mcodec.name}"
        if self.inter_codec is not None:
            base += f"+x:{self.inter_codec.name}"
        if self.downlink_codec is not None:
            base += f"+d:{self.downlink_codec.name}"
        if self.faulty:
            p = self.fault_plan
            if isinstance(p, faults_mod.TieredFaultPlan):
                tags = []
                if p.intra is not None:
                    tags.append(f"i{p.intra.drop_rate:g}@{p.intra.seed}")
                if p.inter is not None:
                    tags.append(f"x{p.inter.drop_rate:g}@{p.inter.seed}")
                base += "+drop[" + ",".join(tags) + "]"
            else:
                base += f"+drop{p.drop_rate:g}@{p.seed}"
        if self.overlap:
            base += "+ov"
        return base

    @property
    def stateful(self) -> bool:
        if self.topology == "none":
            return False   # no wire: the codecs never run, no state
        if self.overlap:
            return True    # the in-flight payload IS round-to-round state
        if self.hierarchical:
            return True    # round counter + per-tier participation always
        return (self.topology in ("async_stale", "push_sum")
                or self.codec.stateful or self.mcodec.stateful
                or self.lossy_downlink or self.faulty)

    @property
    def supports_opt_state_averaging(self) -> bool:
        """Always True since the per-stream staleness buffers landed
        (DESIGN.md §10): async_stale keeps one ``pushed_opt`` buffer per
        moment stream, so rounds may average opt state on every topology.
        Kept as a property because the launchers consult it."""
        return True

    # -- state ------------------------------------------------------------

    def init(self, params_G, moments: Optional[dict] = None) -> dict:
        """Comm state for a G-grouped params pytree/buffer ({} when the
        exchange is stateless — the round then carries no "comm" key).

        ``moments``: the opt state's moment streams ``{name: value_G}``
        (same G-leading geometry as the params). Needed whenever the
        moment codec is stateful or the topology keeps staleness buffers
        — ``localsgd.init_state`` passes them automatically."""
        state: dict = {}
        if not self.stateful:
            return state
        cstate = {}
        if self.codec.stateful:
            cstate["params"] = self.codec.init(params_G)
        if moments and self.mcodec.stateful:
            for k, v in moments.items():
                cstate[k] = self.mcodec.init(v)
        if self.codec.stateful or (moments and self.mcodec.stateful):
            state["codec"] = cstate
        if self.overlap:
            # the double buffer (DESIGN.md §14): round r mixes what round
            # r-1 put here. Initialized to the (replicated) initial
            # params, so round 0's delayed-mixing correction is exactly
            # zero — one uniform code path, no special first round. A
            # real COPY for the same donation-safety reason as "pushed".
            state["inflight"] = {
                "params": jax.tree.map(jnp.copy, params_G)}
            if moments:
                state["inflight"].update(
                    {k: jax.tree.map(jnp.copy, v)
                     for k, v in moments.items()})
        if self.topology == "async_stale":
            # a real COPY: the staleness buffer must not alias the live
            # params (donated train states would double-donate the buffer)
            state["pushed"] = jax.tree.map(jnp.copy, params_G)
            if moments:
                state["pushed_opt"] = {
                    k: jax.tree.map(jnp.copy, v) for k, v in moments.items()}
            state["round"] = jnp.zeros((), jnp.int32)
        if self.topology == "server" and self.faulty:
            # graceful degradation (DESIGN.md §12): under faults the
            # server path keeps the SAME per-stream staleness buffers as
            # async_stale — a group whose push drops contributes its last
            # delivered model instead of deadlocking the round
            state["pushed"] = jax.tree.map(jnp.copy, params_G)
            if moments:
                state["pushed_opt"] = {
                    k: jax.tree.map(jnp.copy, v) for k, v in moments.items()}
        if self.hierarchical:
            # DESIGN.md §16: cross-tier codec state (e.g. the int8
            # rng counter) keyed "inter:<stream>" so it never collides
            # with the intra-tier codec state of the same stream
            names = ["params"] + (list(moments) if moments else [])
            vals = {"params": params_G}
            if moments:
                vals.update(moments)
            ic_state = {}
            for k in names:
                ic = self.inter_stream_codec(k)
                if ic.stateful:
                    ic_state["inter:" + k] = ic.init(vals[k])
            if ic_state:
                state.setdefault("codec", {}).update(ic_state)
            if self.inter_topology == "push_sum":
                # pod-level ratio consensus: same mass/backlog counters
                # as flat push_sum, with one backlog slot per POD-graph
                # circulant offset; all state stays at G-leading shape
                # (every member lane carries 1/pod_size of pod traffic)
                # so checkpointing and sharding are unchanged. Invariant:
                # sum(mass) + sum(backlog_w) == G exactly, every round.
                offs_p = topo_mod.push_sum_offsets(self.n_pods)

                def pblz(v):
                    return jax.tree.map(
                        lambda a: jnp.zeros((len(offs_p),) + a.shape,
                                            jnp.float32), v)

                state["mass"] = jnp.ones((self.n_groups,), jnp.float32)
                state["backlog"] = {"params": pblz(params_G)}
                if moments:
                    state["backlog"].update(
                        {k: pblz(v) for k, v in moments.items()})
                state["backlog_w"] = jnp.zeros(
                    (len(offs_p), self.n_groups), jnp.float32)
            # the round counter drives the per-tier fault masks and the
            # leader election — checkpoint resume replays both exactly
            state["round"] = jnp.zeros((), jnp.int32)
            state["participation"] = jnp.ones((), jnp.float32)
            state["participation_intra"] = jnp.ones((), jnp.float32)
            state["participation_inter"] = jnp.ones((), jnp.float32)
            return state
        if self.topology == "push_sum":
            # ratio-consensus mass counters (DESIGN.md §12): per-node
            # weight mass plus per-directed-edge backlog buffers for the
            # value and weight channels. Invariant: sum(mass) +
            # sum(backlog_w) == G exactly, every round, under any drop
            # pattern.
            offs = topo_mod.push_sum_offsets(self.n_groups)

            def blz(v):
                return jax.tree.map(
                    lambda a: jnp.zeros((len(offs),) + a.shape,
                                        jnp.float32), v)

            state["mass"] = jnp.ones((self.n_groups,), jnp.float32)
            state["backlog"] = {"params": blz(params_G)}
            if moments:
                state["backlog"].update(
                    {k: blz(v) for k, v in moments.items()})
            state["backlog_w"] = jnp.zeros(
                (len(offs), self.n_groups), jnp.float32)
        if (self.faulty or self.topology == "push_sum") \
                and "round" not in state:
            # the fault masks are pure functions of (round, seed): the
            # counter riding the comm state is what makes a checkpoint
            # resume replay the exact fault schedule
            state["round"] = jnp.zeros((), jnp.int32)
        if self.faulty or self.topology == "push_sum":
            state["participation"] = jnp.ones((), jnp.float32)
        if self.lossy_downlink:
            # per-stream downlink memory (DESIGN.md §11): the last DECODED
            # broadcast (every receiver holds it, so it is the delta
            # reference the server encodes against) plus the downlink
            # codec's own state — seeded/counted apart from the uplink.
            # The reference must be SHARED across G: init to the G-mean
            # (bit-equal to the params when they start replicated — the
            # normal round init)
            def dinit(v):
                def shared(a):
                    m = jnp.mean(a, axis=0, keepdims=True)
                    return jnp.broadcast_to(m, a.shape) + 0.0

                return {"ref": jax.tree.map(shared, v),
                        "state": self.downlink_codec.init(v)}

            state["down"] = {"params": dinit(params_G)}
            if moments:
                state["down"].update(
                    {k: dinit(v) for k, v in moments.items()})
        return state

    # -- mixing -----------------------------------------------------------

    def _mix_leaf_once(self, x, w):
        return jnp.tensordot(w, x.astype(jnp.float32),
                             axes=[[1], [0]]).astype(x.dtype)

    def _mix_leaf(self, x):
        if self.topology == "none":
            return x
        if self.w is None:  # server/async: exact mean, broadcast back —
            # identical ops to the pre-comm average_groups (bit-exact)
            m = jnp.mean(x, axis=0, keepdims=True)
            return jnp.broadcast_to(m, x.shape)
        # codec-free k-hop mix: ONE upcast, all hops in fp32, one downcast
        # (per-hop round-tripping to a bf16 leaf dtype would inject k-1
        # extra rounding steps; the lossy path casts per hop by design —
        # that IS the wire behavior there)
        w = jnp.asarray(self.w, jnp.float32)
        y = x.astype(jnp.float32)
        for _ in range(self.mix_rounds):
            y = jnp.tensordot(w, y, axes=[[1], [0]])
        return y.astype(x.dtype)

    def mix(self, tree):
        """Codec-free mixing over the G axis (what an identity-codec
        stream rides through — see DESIGN.md §8/§10)."""
        return jax.tree.map(self._mix_leaf, tree)

    def _masked_hop_leaf(self, v, wm, deficit, act):
        """One masked W-hop: a receiver substitutes its OWN value for
        every lost payload (the deficit term keeps rows stochastic so
        iterates stay in the convex hull — no blowup) and a stalled
        receiver keeps its value outright."""
        s1 = (-1,) + (1,) * (v.ndim - 1)
        v32 = v.astype(jnp.float32)
        out = (jnp.tensordot(wm, v32, axes=[[1], [0]])
               + deficit.reshape(s1) * v32)
        return jnp.where(act.reshape(s1) > 0, out, v32).astype(v.dtype)

    def _mix_faulty(self, tree, rnd):
        """ring/gossip under a FaultPlan. Self-substitution keeps the
        masked matrix row-stochastic but its COLUMNS no longer sum to 1,
        so the G-mean drifts — the measurable bias the bias-regression
        test pins and push_sum exists to fix (DESIGN.md §12)."""
        plan, n = self.fault_plan, self.n_groups
        w = jnp.asarray(self.w, jnp.float32)
        act = plan.active_mask(rnd, n)
        y = tree
        for h in range(self.mix_rounds):
            m = plan.matrix_mask(rnd, h, n)
            wm = w * m
            deficit = 1.0 - jnp.sum(wm, axis=1)
            y = jax.tree.map(
                lambda v, _wm=wm, _de=deficit:
                self._masked_hop_leaf(v, _wm, _de, act), y)
        return y

    def _edge_participation(self, rnd):
        """Fraction of this round's TRUE edge transmissions delivered
        (off-diagonal W-support entries whose mask fired, averaged over
        hops) — the decentralized analogue of the server path's
        delivered-push fraction."""
        sup_np = (np.asarray(self.w) > 0) & ~np.eye(self.n_groups,
                                                    dtype=bool)
        tot = max(float(sup_np.sum()), 1.0)
        sup = jnp.asarray(sup_np, jnp.float32)
        vals = [jnp.sum(self.fault_plan.matrix_mask(rnd, h, self.n_groups)
                        * sup) / tot
                for h in range(self.mix_rounds)]
        return sum(vals) / float(len(vals))

    # -- the communication step -------------------------------------------

    def _decentral_lossy(self, x_G, x0_G, cstate, codec, rnd=None):
        """ring/gossip with a lossy codec: RE-compress at every mixing hop
        (each hop's payload is a fresh wire transmission — the byte
        accounting already counts per hop, and now the noise model does
        too). Each node encodes the delta vs its previously TRANSMITTED
        (decoded) value — hop 0 vs the round start, hop h vs hop h-1's
        decoded payload — so what's compressed is a hop-sized difference
        that shrinks with consensus, and error feedback (top-k residual)
        updates once per hop. Returns (mixed, codec_state). With ``rnd``
        set (an active FaultPlan) every hop is masked by the SAME
        ``matrix_mask(rnd, hop)`` the identity streams consume — one
        physical transmission carries the whole multi-stream payload."""
        w = jnp.asarray(self.w, jnp.float32)
        plan = self.fault_plan if rnd is not None else None
        act = (plan.active_mask(rnd, self.n_groups)
               if plan is not None else None)
        y, ref = x_G, x0_G
        for h in range(self.mix_rounds):
            delta = jax.tree.map(lambda a, b: a - b, y, ref)
            delta_hat, cstate = codec.compress(delta, cstate)
            y_hat = jax.tree.map(lambda b, d: b + d, ref, delta_hat)
            ref = y_hat
            if plan is None:
                y = jax.tree.map(
                    lambda v: self._mix_leaf_once(v, w), y_hat)
            else:
                m = plan.matrix_mask(rnd, h, self.n_groups)
                wm = w * m
                deficit = 1.0 - jnp.sum(wm, axis=1)
                y = jax.tree.map(
                    lambda v, _wm=wm, _de=deficit:
                    self._masked_hop_leaf(v, _wm, _de, act), y_hat)
        return y, cstate

    def _fusable(self, codec, x) -> bool:
        """Streams the fused codec-mix epilogue covers (DESIGN.md §11):
        a flat (G, N) buffer through a width codec on a topology whose
        mixing is pure mean / W-row arithmetic, or top-k on the server
        topology (select + error-feedback residual + mean fuse once the
        per-group threshold is known; ring/gossip re-select per hop and
        keep the staged path). async keeps the staged path (the
        staleness mask interleaves); pytree streams have no flat wire
        format. An active FaultPlan keeps the staged path for every
        stream — the masks interleave with the mixing like the staleness
        schedule does."""
        if self.faulty:
            return False
        if not (self.fused and isinstance(x, jax.Array) and x.ndim == 2):
            return False
        if codec.topk_frac > 0:
            return self.topology == "server"
        return (codec.name in ("int8", "fp16", "bf16")
                and self.topology in ("server", "ring", "gossip"))

    def _fused_stream(self, codec, x, x0, cstate):
        """One stream through the fused epilogue: encode + decode + mix
        (+ per-hop recompression / + EF residual for top-k) in one pass
        — kernel or the staged-op jnp reference per ``codec.impl``.
        Width codecs are bit-identical to the staged path; the top-k
        thresh kind matches the staged exact selection except on exact
        nonzero |c| ties at the threshold (it then ships the whole tie
        group — absent in generic fp data). Noise is generated here at
        the staged rows shape (the kernel is deterministic given its
        inputs — kernels/quantize.py contract)."""
        from repro.kernels import use_interpret
        from repro.kernels import exchange_epilogue as ee

        if codec.topk_frac > 0:          # server top-k (mean mixing)
            res = cstate["residual"]
            c = (x - x0) + res
            k = max(1, int(round(codec.topk_frac * x.shape[-1])))
            tau = jax.lax.top_k(jnp.abs(c), k)[0][:, -1:]
            mixed, res_out = ee.codec_mix(x, x0, kind="thresh",
                                          residual=res, tau=tau,
                                          impl=codec.impl,
                                          interpret=use_interpret())
            return mixed, {"residual": res_out}
        hops = self.mix_rounds if self.w is not None else 1
        u, new_state = None, cstate
        if codec.chunk > 0:
            g, n = x.shape
            rows_shape = (g * (-(-n // codec.chunk)), codec.chunk)
            u = jnp.stack([codec.noise(cstate["count"] + h, rows_shape)
                           for h in range(hops)])
            new_state = {"count": cstate["count"] + hops}
        mixed, _ = ee.codec_mix(x, x0, kind=codec.name, u=u, w=self.w,
                                hops=hops, chunk=codec.chunk,
                                impl=codec.impl,
                                interpret=use_interpret())
        return mixed, new_state

    def streams(self, xs: dict, xs0: dict, comm_state: dict):
        """One exchange of the round's MULTI-STREAM payload (DESIGN.md
        §10). ``xs`` maps stream name -> post-local-step value (leading
        G axis): always ``"params"``, plus one entry per moment stream
        when the round averages opt state. ``xs0`` holds the round-start
        value of every stream whose codec is lossy — the codec reference:
        the wire carries the delta ``x_T - x_0`` so quantization error
        vanishes as rounds converge. Every stream follows the same
        topology; each keeps its own codec state and (async) staleness
        buffer. Returns ``(mixed: {name: value}, new_comm_state)``."""
        if (isinstance(self.fault_plan, faults_mod.TieredFaultPlan)
                and not self.hierarchical):
            raise NotImplementedError(
                f"topology {self.topology!r} is single-tier — a "
                "TieredFaultPlan has no intra/inter split to bind to; "
                "the only valid tiered-fault topology is 'hierarchical'. "
                "Flat topologies take a plain FaultPlan: 'server', "
                "'ring', 'gossip', 'async_stale', 'push_sum'")
        if self.hierarchical:
            return self._hier_streams(xs, xs0, comm_state)
        if self.topology == "push_sum":
            return self._push_sum_streams(xs, comm_state)
        plan = self.fault_plan if self.topology != "none" else None
        rnd = comm_state.get("round")
        new_state = dict(comm_state)
        cstates = dict(comm_state.get("codec", {}))
        touched = False
        x_hat = {}
        d_hats = {}
        mixed = {}
        for name, x in xs.items():
            codec = self.stream_codec(name)
            if codec.identity or self.topology == "none":
                # "none" skips the codec too: nothing goes on the wire,
                # so a no-comm baseline must not inject quantization noise
                x_hat[name] = x
                continue
            if self._fusable(codec, x):
                y, cs = self._fused_stream(codec, x, xs0[name],
                                           cstates.get(name, {}))
                mixed[name] = y
                if codec.stateful:
                    cstates[name] = cs
                    touched = True
                continue
            if self.w is not None:
                # decentralized + lossy: codec applied per mixing hop
                y, cs = self._decentral_lossy(
                    x, xs0[name], cstates.get(name, {}), codec,
                    rnd=rnd if plan is not None else None)
                mixed[name] = y
                if codec.stateful:
                    cstates[name] = cs
                    touched = True
                continue
            with jax.named_scope("encode"):
                delta = jax.tree.map(lambda a, b: a - b, x, xs0[name])
                d_hat, cs = codec.compress(delta, cstates.get(name, {}))
            x_hat[name] = jax.tree.map(lambda b, d: b + d, xs0[name], d_hat)
            d_hats[name] = d_hat
            if codec.stateful:
                cstates[name] = cs
                touched = True
        if plan is not None and self.w is not None:
            # faulty ring/gossip: masked hops for the identity streams
            # (lossy streams were masked inside _decentral_lossy above)
            mixed.update(
                {k: self._mix_faulty(v, rnd) for k, v in x_hat.items()})
            if touched:
                new_state["codec"] = cstates
            new_state["round"] = rnd + 1
            new_state["participation"] = self._edge_participation(rnd)
            return self._apply_downlink(mixed, comm_state, new_state)
        if self.topology != "async_stale" and not (
                self.topology == "server" and plan is not None):
            if touched:
                new_state["codec"] = cstates
            with jax.named_scope("mix"):
                mixed.update({k: self.mix(v) for k, v in x_hat.items()})
            return self._apply_downlink(mixed, comm_state, new_state)
        # bounded-staleness server: refresh only the groups whose push
        # ARRIVED this round (the staleness schedule for async_stale,
        # everyone for the faulty server), average everyone's last
        # delivered push — per stream. A dropped push is re-sent next
        # cycle from the same buffer: bounded-staleness retry
        # (DESIGN.md §12).
        rnd = comm_state["round"]
        if self.topology == "async_stale":
            sched = (jnp.arange(self.n_groups) + rnd) \
                % (self.staleness + 1) == 0
        else:
            sched = jnp.ones((self.n_groups,), bool)
        if plan is not None:
            delivered = plan.push_mask(rnd, self.n_groups)
            fresh = jnp.logical_and(sched, delivered > 0)
            # EF deferral (DESIGN.md §12): only FAULTS defer — the
            # staleness schedule's own non-pushing rounds keep their
            # drop-by-design semantics (async + topk stays refused)
            arrived = jnp.where(sched, delivered,
                                jnp.ones_like(delivered))
            for name, d in d_hats.items():
                if "residual" in cstates.get(name, {}):
                    cstates[name] = codecs_mod.defer_undelivered(
                        cstates[name], d, arrived)
                    touched = True
            n_sched = jnp.maximum(jnp.sum(sched.astype(jnp.float32)), 1.0)
            new_state["participation"] = (
                jnp.sum(jnp.where(sched, delivered, 0.0)) / n_sched)
        else:
            fresh = sched
        if touched:
            new_state["codec"] = cstates

        def refresh(pushed, x):
            keep = fresh.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.where(keep, x, pushed)

        pushed = jax.tree.map(refresh, comm_state["pushed"], x_hat["params"])
        new_state["pushed"] = pushed
        mixed["params"] = self.mix(pushed)
        mnames = [k for k in x_hat if k != "params"]
        if mnames:
            pushed_opt = dict(comm_state["pushed_opt"])
            for k in mnames:
                pushed_opt[k] = jax.tree.map(refresh, pushed_opt[k],
                                             x_hat[k])
                mixed[k] = self.mix(pushed_opt[k])
            new_state["pushed_opt"] = pushed_opt
        new_state["round"] = rnd + 1
        return self._apply_downlink(mixed, comm_state, new_state)

    def _push_sum_streams(self, xs: dict, comm_state: dict):
        """Push-sum ratio consensus (DESIGN.md §12). Every live node
        splits a (value, weight) mass pair into ``deg + 1`` equal shares
        — one kept, one pushed along each circulant offset — and the
        model estimate is the ratio value / weight. Per-directed-edge
        BACKLOG buffers make the exchange loss-tolerant: each hop
        enqueues the share on its edge; a delivered payload carries the
        edge's ENTIRE queued mass (one delivery repairs any run of
        drops), an undelivered one leaves it queued. Mass is conserved
        EXACTLY — sum(mass) + sum(backlog_w) == G every round, under any
        drop pattern — so the ratio stays an unbiased convex combination
        of (possibly queued-stale) group models where masked
        doubly-stochastic mixing drifts the mean. A cast codec
        (fp16/bf16) quantizes the transmitted VALUE payload and the cast
        residue stays in the sender's backlog (transmitted =
        cast(backlog + share); backlog' -= delivered * transmitted), so
        compression also defers rather than loses; the fp32 weight
        counter rides exact (+4 bytes/edge in the accounting). Elastic
        membership rides the same counters: an absent node's mass
        freezes, queued shares to/from it drain on rejoin."""
        G = self.n_groups
        offs = topo_mod.push_sum_offsets(G)
        for name in xs:
            codec = self.stream_codec(name)
            if not (codec.identity or codec.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"push_sum + {codec.name}: the push-sum wire carries "
                    "cumulative (value, weight) mass, not round deltas "
                    "(DESIGN.md §12); valid push_sum codecs: 'fp32', "
                    "'fp16', 'bf16'")
        new_state = dict(comm_state)
        rnd = comm_state["round"]
        if not offs:                               # G == 1: no wire
            new_state["round"] = rnd + 1
            return dict(xs), new_state
        plan = self.fault_plan
        a = 1.0 / (len(offs) + 1.0)
        act = (plan.active_mask(rnd, G) if plan is not None
               else jnp.ones((G,), jnp.float32))
        # per-(hop, offset) masks, generated OUTSIDE the per-leaf math:
        # every stream of one physical transmission shares one mask, and
        # the shard_map path consumes these identical arrays
        masks, incs = [], []
        for h in range(self.mix_rounds):
            mh, ih = [], []
            for di, d in enumerate(offs):
                bern = (plan.edge_mask(rnd, h, di, G) if plan is not None
                        else jnp.ones((G,), jnp.float32))
                src = jnp.roll(act, d)   # sender liveness, receiver slot
                ih.append(src)
                mh.append(bern * src * act)
            masks.append(mh)
            incs.append(ih)
        w = comm_state["mass"]
        blw = comm_state["backlog_w"]
        nums = {k: jax.tree.map(
                    lambda v: v.astype(jnp.float32)
                    * w.reshape((G,) + (1,) * (v.ndim - 1)), v)
                for k, v in xs.items()}
        backlog = {k: comm_state["backlog"][k] for k in xs}
        for h in range(self.mix_rounds):
            # weight channel: same arithmetic as the values, scalar per
            # node, no codec (the counter must stay exact)
            new_w = jnp.where(act > 0, a * w, w)
            new_blw = []
            for di, d in enumerate(offs):
                b = blw[di] + incs[h][di] * jnp.roll(a * w, d)
                new_w = new_w + masks[h][di] * b
                new_blw.append(b - masks[h][di] * b)
            for k in list(nums):
                codec = self.stream_codec(k)

                def hop_leaf(x, bl, _codec=codec, _h=h):
                    s1 = (G,) + (1,) * (x.ndim - 1)
                    y = jnp.where(act.reshape(s1) > 0, a * x, x)
                    nb = []
                    for di, d in enumerate(offs):
                        b = bl[di] + (incs[_h][di].reshape(s1)
                                      * jnp.roll(a * x, d, axis=0))
                        t = b if _codec.identity \
                            else _codec.compress(b, {})[0]
                        m = masks[_h][di].reshape(s1)
                        y = y + m * t
                        nb.append(b - m * t)
                    return (y, jnp.stack(nb))

                pairs = jax.tree.map(hop_leaf, nums[k], backlog[k])
                is_pair = (lambda t: isinstance(t, tuple))
                nums[k] = jax.tree.map(lambda p: p[0], pairs,
                                       is_leaf=is_pair)
                backlog[k] = jax.tree.map(lambda p: p[1], pairs,
                                          is_leaf=is_pair)
            w = new_w
            blw = jnp.stack(new_blw)
        mixed = {}
        for k, v in xs.items():
            def ratio(num, orig):
                den = w.reshape((G,) + (1,) * (num.ndim - 1))
                return (num / den).astype(orig.dtype)

            mixed[k] = jax.tree.map(ratio, nums[k], v)
        new_state["mass"] = w
        new_state["backlog"] = backlog
        new_state["backlog_w"] = blw
        new_state["round"] = rnd + 1
        new_state["participation"] = (
            sum(jnp.mean(m) for mh in masks for m in mh)
            / float(self.mix_rounds * len(offs)))
        return mixed, new_state

    def _hier_streams(self, xs: dict, xs0: dict, comm_state: dict):
        """Two-tier hierarchical round (DESIGN.md §16).

        Stage A — the pod-internal tier: G reshapes to (n_pods,
        pod_size) and mixes WITHIN each contiguous pod.
        ``intra_topology='ring'`` runs ``mix_rounds`` pod-local
        circulant hops (cast codecs quantize the transmitted neighbor
        payload, the self term stays exact; under an intra FaultPlan a
        lost payload self-substitutes — rows stay stochastic, the same
        documented pod-internal bias as flat gossip-under-loss).
        ``intra_topology='server'`` takes the exact pod mean (masked
        survivor mean under faults).

        Stage B — the cross-pod tier, on independent fault/codec lanes.
        ``inter_topology='push_sum'`` runs ONE hop of pod-level ratio
        consensus: the pod graph's circulant offsets stride ``pod_size``
        on the G axis, every mask is drawn at pod granularity and
        repeated per member (each member lane carries 1/pod_size of its
        pod's traffic — this pod-uniformity is what keeps the weight
        channel pod-uniform and the estimate unbiased), and the
        mass-conserving per-edge backlogs work exactly as in flat
        push_sum: sum(mass) + sum(backlog_w) == G every round, a
        fully-partitioned pod degrades to local-only rounds and rejoins
        by draining queued mass. Pod liveness survives leader dropout —
        ``elect_leaders`` re-elects the first live member
        deterministically from the plan's active mask.
        ``inter_topology='server'`` is the reliable-DCN baseline: each
        pod's elected leader ships its model (through ``inter_codec`` —
        the int8 cross-tier cell) and every live member receives the
        leader mean.

        Per-tier participation rides ``comm_state`` for the §13 keys;
        the overall scalar weights the tiers by their static payload
        counts."""
        G, n_pods = self.n_groups, self.n_pods
        s = self.pod_len
        if (self.fault_plan is not None
                and not isinstance(self.fault_plan,
                                   faults_mod.TieredFaultPlan)):
            raise NotImplementedError(
                "hierarchical faults are per-tier: a flat FaultPlan does "
                "not say WHICH tier it masks — wrap it as "
                "faults.TieredFaultPlan(intra=..., inter=...); valid "
                "tiers: 'intra' (pod-internal), 'inter' (cross-pod)")
        for name in xs:
            c = self.stream_codec(name)
            if not (c.identity or c.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"hierarchical intra tier + {c.name}: pod-internal "
                    "hops carry whole-value payloads, not round deltas "
                    "(DESIGN.md §16); valid intra codecs: 'fp32', "
                    "'fp16', 'bf16' — put int8 on the cross-tier wire "
                    "via inter_codec with inter_topology='server'")
            ic = self.inter_stream_codec(name)
            if self.inter_topology == "push_sum" and not (
                    ic.identity or ic.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"hierarchical push_sum inter tier + {ic.name}: the "
                    "cross-pod wire carries cumulative (value, weight) "
                    "mass, not round deltas (DESIGN.md §12/§16); valid "
                    "push_sum inter codecs: 'fp32', 'fp16', 'bf16' — or "
                    "inter_topology='server' for 'int8'")
        ip, xp = self.intra_plan, self.inter_plan
        rnd = comm_state["round"]
        new_state = dict(comm_state)

        def pod_take(x, d):
            # payload arriving at member i from pod-mate (i + d) % s
            r = x.reshape((n_pods, s) + x.shape[1:])
            return jnp.roll(r, -d, axis=1).reshape(x.shape)

        # ---- stage A: pod-internal tier ------------------------------
        act_i = (ip.active_mask(rnd, G) if ip is not None
                 else jnp.ones((G,), jnp.float32))
        ys = {k: jax.tree.map(lambda v: v.astype(jnp.float32), x)
              for k, x in xs.items()}
        part_intra = jnp.ones((), jnp.float32)
        if s > 1 and self.intra_topology == "ring":
            w_self, offs_pod, w_edge = topo_mod.ring_circulant(s)
            mask_sum, mask_n = 0.0, 0
            for h in range(self.mix_rounds):
                masks_a = []
                for di, d in enumerate(offs_pod):
                    bern = (ip.edge_mask(rnd, h, di, G) if ip is not None
                            else jnp.ones((G,), jnp.float32))
                    masks_a.append(bern * pod_take(act_i, d) * act_i)
                mask_sum = mask_sum + sum(jnp.mean(m) for m in masks_a)
                mask_n += len(masks_a)
                for k in list(ys):
                    codec = self.stream_codec(k)

                    def hop(v, _codec=codec, _masks=masks_a):
                        s1 = (G,) + (1,) * (v.ndim - 1)
                        out = w_self * v
                        for di, d in enumerate(offs_pod):
                            t = pod_take(v, d)
                            if not _codec.identity:
                                t = _codec.compress(t, {})[0]
                            m = _masks[di].reshape(s1)
                            # lost payload -> self-substitution (rows
                            # stay stochastic); stalled receiver keeps
                            # its value outright
                            out = out + w_edge * (m * t + (1.0 - m) * v)
                        return jnp.where(act_i.reshape(s1) > 0, out, v)

                    ys[k] = jax.tree.map(hop, ys[k])
            if ip is not None and mask_n:
                part_intra = mask_sum / float(mask_n)
        elif s > 1:                                # intra "server"
            deliv = (ip.push_mask(rnd, G) if ip is not None
                     else jnp.ones((G,), jnp.float32))
            for k in list(ys):
                codec = self.stream_codec(k)

                def pod_mean(v, _codec=codec):
                    r = v.reshape((n_pods, s) + v.shape[1:])
                    t = r if _codec.identity else _codec.compress(r, {})[0]
                    sh = (n_pods, s) + (1,) * (v.ndim - 1)
                    dv = deliv.reshape(sh)
                    den = jnp.sum(dv, axis=1, keepdims=True)
                    m = (jnp.sum(dv * t, axis=1, keepdims=True)
                         / jnp.maximum(den, 1.0))
                    recv = jnp.logical_and(act_i.reshape(sh) > 0, den > 0)
                    out = jnp.where(recv, jnp.broadcast_to(m, r.shape), r)
                    return out.reshape(v.shape)

                ys[k] = jax.tree.map(pod_mean, ys[k])
            if ip is not None:
                part_intra = jnp.mean(deliv)

        # ---- stage B: cross-pod tier ---------------------------------
        offs_p = topo_mod.push_sum_offsets(n_pods)
        cstates = dict(comm_state.get("codec", {}))
        touched = False
        if self.inter_topology == "push_sum" and offs_p:
            act_x = (xp.active_mask(rnd, G) if xp is not None
                     else jnp.ones((G,), jnp.float32))
            _, pod_live = elect_leaders(act_x, n_pods)
            act_pod = jnp.repeat(pod_live, s)
            a = 1.0 / (len(offs_p) + 1.0)
            masks, incs = [], []
            for di, dp in enumerate(offs_p):
                # one Bernoulli per DCN edge per round, drawn at pod
                # granularity from the inter seed lane and shared by the
                # pod's member lanes (the leader uplink model — what
                # keeps the weight channel pod-uniform)
                bern = (xp.edge_mask(rnd, 0, di, n_pods)
                        if xp is not None
                        else jnp.ones((n_pods,), jnp.float32))
                src = jnp.roll(act_pod, dp * s)
                incs.append(src)
                masks.append(jnp.repeat(bern, s) * src * act_pod)
            w = comm_state["mass"]
            blw = comm_state["backlog_w"]
            nums = {k: jax.tree.map(
                        lambda v: v * w.reshape((G,) + (1,) * (v.ndim - 1)),
                        ys[k])
                    for k in ys}
            backlog = {k: comm_state["backlog"][k] for k in xs}
            new_w = jnp.where(act_pod > 0, a * w, w)
            new_blw = []
            for di, dp in enumerate(offs_p):
                b = blw[di] + incs[di] * jnp.roll(a * w, dp * s)
                new_w = new_w + masks[di] * b
                new_blw.append(b - masks[di] * b)
            for k in list(nums):
                ic = self.inter_stream_codec(k)

                def hop_leaf(x, bl, _ic=ic):
                    s1 = (G,) + (1,) * (x.ndim - 1)
                    y = jnp.where(act_pod.reshape(s1) > 0, a * x, x)
                    nb = []
                    for di, dp in enumerate(offs_p):
                        b = bl[di] + (incs[di].reshape(s1)
                                      * jnp.roll(a * x, dp * s, axis=0))
                        t = b if _ic.identity \
                            else _ic.compress(b, {})[0]
                        m = masks[di].reshape(s1)
                        y = y + m * t
                        nb.append(b - m * t)
                    return (y, jnp.stack(nb))

                pairs = jax.tree.map(hop_leaf, nums[k], backlog[k])
                is_pair = (lambda t: isinstance(t, tuple))
                nums[k] = jax.tree.map(lambda p: p[0], pairs,
                                       is_leaf=is_pair)
                backlog[k] = jax.tree.map(lambda p: p[1], pairs,
                                          is_leaf=is_pair)
            mixed = {}
            for k, v in xs.items():
                def ratio(num, orig):
                    den = new_w.reshape((G,) + (1,) * (num.ndim - 1))
                    return (num / den).astype(orig.dtype)

                mixed[k] = jax.tree.map(ratio, nums[k], v)
            new_state["mass"] = new_w
            new_state["backlog"] = backlog
            new_state["backlog_w"] = jnp.stack(new_blw)
            part_inter = (sum(jnp.mean(m) for m in masks)
                          / float(len(offs_p))
                          if xp is not None else jnp.ones((), jnp.float32))
        elif self.inter_topology == "push_sum":    # single pod: no DCN
            mixed = {k: jax.tree.map(lambda y, o: y.astype(o.dtype),
                                     ys[k], xs[k]) for k in xs}
            part_inter = jnp.ones((), jnp.float32)
        else:                                      # inter "server"
            act_x = (xp.active_mask(rnd, G) if xp is not None
                     else jnp.ones((G,), jnp.float32))
            lead_w, plive = elect_leaders(act_i * act_x, n_pods)
            n_live = jnp.maximum(jnp.sum(plive), 1.0)
            mixed = {}
            for k in list(ys):
                ic = self.inter_stream_codec(k)
                y = ys[k]
                if not ic.identity:
                    # the cross-tier codec (e.g. int8) codes the round
                    # DELTA vs the round-start reference, per group —
                    # only the elected leaders' decoded payloads enter
                    # the mean, but encoding the full buffer keeps the
                    # rng counter schedule group-independent
                    key = "inter:" + k
                    delta = jax.tree.map(
                        lambda a, b: a - b.astype(jnp.float32),
                        y, xs0[k])
                    d_hat, cs = ic.compress(delta, cstates.get(key, {}))
                    y = jax.tree.map(
                        lambda b, d: b.astype(jnp.float32) + d,
                        xs0[k], d_hat)
                    if ic.stateful:
                        cstates[key] = cs
                        touched = True

                def gmean(v, orig):
                    s1 = (G,) + (1,) * (v.ndim - 1)
                    lw = lead_w.reshape(s1)
                    m = jnp.sum(lw * v, axis=0, keepdims=True) / n_live
                    out = jnp.where(act_i.reshape(s1) > 0,
                                    jnp.broadcast_to(m, v.shape), v)
                    return out.astype(orig.dtype)

                mixed[k] = jax.tree.map(gmean, y, xs[k])
            part_inter = (jnp.mean(plive)
                          if (ip is not None or xp is not None)
                          else jnp.ones((), jnp.float32))
        n_is = self._intra_send_count()
        n_xs = self._inter_send_count()
        tot = n_is + n_xs
        if touched:
            new_state["codec"] = cstates
        new_state["round"] = rnd + 1
        new_state["participation"] = (
            (part_intra * n_is + part_inter * n_xs) / tot if tot > 0
            else jnp.ones((), jnp.float32))
        new_state["participation_intra"] = part_intra
        new_state["participation_inter"] = part_inter
        return mixed, new_state

    def _apply_downlink(self, mixed: dict, comm_state: dict,
                        new_state: dict):
        """Model the compressed broadcast reply (DESIGN.md §11): what
        groups actually receive is the server's mean re-encoded as a
        delta vs the LAST decoded broadcast, per stream, through the
        downlink codec. No downlink codec (the default) means the
        idealized broadcast — bit-exact with the pre-§11 rounds."""
        if not self.lossy_downlink:
            return mixed, new_state
        down = dict(comm_state["down"])
        out = {}
        with jax.named_scope("decode"):
            for name, m in mixed.items():
                st = down[name]
                # ONE encode of the (row-identical) broadcast: every
                # receiver decodes the same bits, so the delta is
                # compressed on a single G-row and the decoded payload
                # broadcast back
                delta = jax.tree.map(lambda a, b: (a - b)[:1], m,
                                     st["ref"])
                d_hat, cs = self.downlink_codec.compress(delta,
                                                         st["state"])
                m_hat = jax.tree.map(
                    lambda b, d: b + jnp.broadcast_to(d, b.shape),
                    st["ref"], d_hat)
                out[name] = m_hat
                down[name] = {"ref": m_hat, "state": cs}
        new_state["down"] = down
        return out, new_state

    def params(self, x_G, x0_G, comm_state: dict):
        """Single-stream convenience wrapper over ``streams``: one
        exchange of the models only (``x0_G`` may be None for identity
        codecs). Returns ``(mixed_x_G, new_comm_state)``."""
        xs0 = {} if x0_G is None else {"params": x0_G}
        mixed, new_state = self.streams({"params": x_G}, xs0, comm_state)
        return mixed["params"], new_state

    # -- overlap: delayed mixing (DESIGN.md §14) ---------------------------

    def encode_streams(self, xs: dict, xs0: dict, comm_state: dict):
        """Codec-encode every stream ONCE, with no mixing: what the
        overlap round puts IN FLIGHT (``comm_state["inflight"]``) for the
        NEXT round to mix. Identity codecs ship the value itself; lossy
        codecs ship ``x0 + decode(encode(x - x0))`` — exactly the decoded
        payload the barrier engine would mix this round — and advance
        their codec state once (int8's rng counter, topk's EF residual).
        ``get_exchange`` refuses overlap on the topologies whose wire
        interleaves with the mixing (async schedules, push-sum mass,
        faults, downlink re-encodes), so this single-shot delta path is
        the whole story. Returns ``({name: decoded}, new_comm_state)``."""
        new_state = dict(comm_state)
        cstates = dict(comm_state.get("codec", {}))
        touched = False
        x_hat = {}
        for name, x in xs.items():
            codec = self.stream_codec(name)
            if codec.identity:
                x_hat[name] = x
                continue
            with jax.named_scope("encode"):
                delta = jax.tree.map(lambda a, b: a - b, x, xs0[name])
                d_hat, cs = codec.compress(delta, cstates.get(name, {}))
            x_hat[name] = jax.tree.map(lambda b, d: b + d,
                                       xs0[name], d_hat)
            if codec.stateful:
                cstates[name] = cs
                touched = True
        if touched:
            new_state["codec"] = cstates
        return x_hat, new_state

    def mix_inflight(self, inflight: dict) -> dict:
        """Mix the PREVIOUS round's decoded in-flight payload, codec-free
        (it was encoded when it was shipped — re-coding it here would
        double-charge the wire noise): the collective the jitted overlap
        round issues BEFORE its local-step block, so a parallel backend
        can schedule both concurrently (DESIGN.md §14). With overlap the
        decentralized topologies run exactly one codec-free W hop
        (``get_exchange`` refuses ``mix_rounds > 1`` there) — identical
        bytes to the barrier engine's single-hop round."""
        with jax.named_scope("mix_inflight"):
            return {k: self.mix(v) for k, v in inflight.items()}

    # -- wire accounting ---------------------------------------------------

    def senders_per_round(self) -> float:
        """UPLINK payloads one round puts on the wire. server: G uplinks.
        ring/gossip: one payload per directed edge per mixing hop.
        async_stale: amortized over the staleness cycle (each group pushes
        once per s+1 rounds; exact when (s+1) divides G)."""
        if self.topology == "none":
            return 0.0
        if self.hierarchical:
            return (self._intra_send_count()
                    + self._inter_send_count(delivered=True))
        if self.topology == "server":
            return float(self.n_groups)
        if self.topology == "async_stale":
            return self.n_groups / (self.staleness + 1)
        if self.topology == "push_sum":
            # delivered-edge pricing (DESIGN.md §12): a dropped payload
            # moves no bytes and the sender's queued mass rides the NEXT
            # delivered payload at no extra width, so the expected
            # physical transfer scales with the delivery rate
            offs = topo_mod.push_sum_offsets(self.n_groups)
            return (len(offs) * self.n_groups * self.mix_rounds
                    * self.delivery_rate)
        return float(topo_mod.n_edge_sends(self.w) * self.mix_rounds)

    def receivers_per_round(self) -> float:
        """DOWNLINK payloads per round, per topology (DESIGN.md §8):
        server broadcasts the new average to all G groups; ring/gossip are
        symmetric (every edge payload is one node's uplink and its
        neighbor's downlink, so down == up); async_stale answers each
        PUSH with the fresh average (pull-on-push — amortized like the
        uplink; note the simulated round idealizes this by handing every
        group the mean, the accounting models the real per-push pull)."""
        # every topology's downlink currently mirrors its uplink count
        # (single source until one actually diverges)
        return self.senders_per_round()

    def _stream_payload_bytes(self, n_params: int,
                              moment_sizes: Optional[Dict[str, int]]
                              ) -> Dict[str, int]:
        """One UPLINK payload, per stream: each stream's buffer through
        ITS codec (params via ``codec``, moments via ``moment_codec`` —
        the fp32 moment surcharge this replaces was ``4 * moment_elems``)."""
        out = {"params": self.codec.wire_bytes(n_params)}
        if self.topology == "push_sum":
            # every push-sum edge payload carries the fp32 weight-mass
            # counter alongside the value buffer (DESIGN.md §12)
            out["params"] += 4
        for k, n in (moment_sizes or {}).items():
            out[k] = self.mcodec.wire_bytes(n)
        return out

    def _downlink_payload_bytes(self, n_params: int,
                                moment_sizes: Optional[Dict[str, int]]
                                ) -> Dict[str, int]:
        """One DOWNLINK payload, per stream. Default (no downlink codec):
        the server re-encodes the new mean as a delta against its last
        broadcast at the SAME widths as the uplink. With a downlink
        codec, every broadcast stream rides at ITS width (DESIGN.md §11)."""
        if self.downlink_codec is None:
            return self._stream_payload_bytes(n_params, moment_sizes)
        out = {"params": self.downlink_codec.wire_bytes(n_params)}
        for k, n in (moment_sizes or {}).items():
            out[k] = self.downlink_codec.wire_bytes(n)
        return out

    def _legacy_sizes(self, moment_elems: int,
                      moment_sizes: Optional[Dict[str, int]]):
        if moment_sizes is not None:
            return moment_sizes
        return {"moments": moment_elems} if moment_elems else {}

    # -- hierarchical per-tier accounting (DESIGN.md §16) ------------------

    def _intra_send_count(self) -> float:
        """UPLINK payloads of the pod-internal tier per round: one per
        pod-local circulant edge per hop (ring), or one member uplink
        each (server)."""
        s = self.pod_len
        if s <= 1:
            return 0.0
        if self.intra_topology == "server":
            return float(self.n_groups)
        _, offs_pod, _ = topo_mod.ring_circulant(s)
        return float(self.n_groups * len(offs_pod) * self.mix_rounds)

    def _inter_send_count(self, delivered: bool = False) -> float:
        """UPLINK payloads of the cross-pod tier per round — pod leaders
        carry the traffic: one payload per pod per directed DCN edge
        (push_sum: delivered-edge pricing like flat push_sum when
        ``delivered``), or one leader uplink per pod (server)."""
        if self.inter_topology == "server":
            return float(self.n_pods)
        offs_p = topo_mod.push_sum_offsets(self.n_pods)
        n = float(len(offs_p) * self.n_pods)
        return n * self.delivery_rate_inter if delivered else n

    def _tier_wire(self, n_params: int,
                   moment_sizes: Optional[Dict[str, int]]):
        """Per-tier wire tables: ``{"intra"|"inter": {"up": {stream:
        bytes}, "down": ..., "total": ...}}``. p2p tiers (intra ring,
        inter push_sum) mirror each edge payload in up/down but count it
        ONCE in the total; server-style tiers count uplink and broadcast
        reply as distinct payloads. The total identity the §13 schema
        checks is ``wire_bytes == wire_bytes_intra + wire_bytes_inter``."""
        iw = {"params": self.codec.wire_bytes(n_params)}
        xw = {"params":
              self.inter_stream_codec("params").wire_bytes(n_params)}
        if self.inter_topology == "push_sum":
            xw["params"] += 4        # the fp32 weight-mass counter
        for k, n in (moment_sizes or {}).items():
            iw[k] = self.mcodec.wire_bytes(n)
            xw[k] = self.inter_stream_codec(k).wire_bytes(n)
        n_i = self._intra_send_count()
        n_x = self._inter_send_count(delivered=True)
        out = {}
        up = {k: int(round(n_i * b)) for k, b in iw.items()}
        out["intra"] = ({"up": up, "down": dict(up), "total": dict(up)}
                        if self.intra_topology == "ring" else
                        {"up": up, "down": dict(up),
                         "total": {k: 2 * v for k, v in up.items()}})
        up = {k: int(round(n_x * b)) for k, b in xw.items()}
        out["inter"] = ({"up": up, "down": dict(up), "total": dict(up)}
                        if self.inter_topology == "push_sum" else
                        {"up": up, "down": dict(up),
                         "total": {k: 2 * v for k, v in up.items()}})
        return out

    def wire_bytes_by_tier(self, n_params: int,
                           moment_sizes: Optional[Dict[str, int]] = None
                           ) -> Dict[str, int]:
        """TOTAL bytes per round per tier (the §13 ``wire_bytes_intra``
        / ``wire_bytes_inter`` keys). Flat topologies are single-tier by
        convention — the whole wire is the intra tier (one big pod),
        inter = 0 — so the tier identity ``total == intra + inter``
        holds for every topology."""
        if not self.hierarchical:
            return {"intra": self.wire_bytes_per_round(
                        n_params, moment_sizes=moment_sizes),
                    "inter": 0}
        tw = self._tier_wire(n_params, moment_sizes)
        return {t: sum(tw[t]["total"].values())
                for t in ("intra", "inter")}

    def wire_bytes_by_stream(self, n_params: int,
                             moment_sizes: Optional[Dict[str, int]] = None
                             ) -> Dict[str, int]:
        """TOTAL physical payload bytes per round, per stream (same
        counting rule as ``wire_bytes_per_round``: server/async pushes and
        replies are distinct payloads, p2p edge payloads count once). The
        old totals are exactly the sums of these."""
        if self.hierarchical:
            tw = self._tier_wire(n_params, moment_sizes)
            return {k: tw["intra"]["total"][k] + tw["inter"]["total"][k]
                    for k in tw["intra"]["total"]}
        per = self._stream_payload_bytes(n_params, moment_sizes)
        per_dn = self._downlink_payload_bytes(n_params, moment_sizes)
        s, r = self.senders_per_round(), self.receivers_per_round()
        out = {}
        for k, b in per.items():
            up = int(round(s * b))
            out[k] = up if self.p2p \
                else up + int(round(r * per_dn[k]))
        return out

    def wire_bytes_up(self, n_params: int, moment_elems: int = 0, *,
                      moment_sizes: Optional[Dict[str, int]] = None) -> int:
        ms = self._legacy_sizes(moment_elems, moment_sizes)
        if self.hierarchical:
            tw = self._tier_wire(n_params, ms)
            return sum(sum(tw[t]["up"].values())
                       for t in ("intra", "inter"))
        s = self.senders_per_round()
        return sum(int(round(s * b)) for b in
                   self._stream_payload_bytes(n_params, ms).values())

    def wire_bytes_down(self, n_params: int, moment_elems: int = 0, *,
                        moment_sizes: Optional[Dict[str, int]] = None) -> int:
        ms = self._legacy_sizes(moment_elems, moment_sizes)
        if self.hierarchical:
            tw = self._tier_wire(n_params, ms)
            return sum(sum(tw[t]["down"].values())
                       for t in ("intra", "inter"))
        r = self.receivers_per_round()
        return sum(int(round(r * b)) for b in
                   self._downlink_payload_bytes(n_params, ms).values())

    def wire_bytes_per_round(self, n_params: int, moment_elems: int = 0, *,
                             moment_sizes: Optional[Dict[str, int]] = None
                             ) -> int:
        """TOTAL physical payload bytes per round (was uplink-only before
        downlink accounting landed; per-direction numbers are
        ``wire_bytes_up`` / ``wire_bytes_down``, per-stream splits
        ``wire_bytes_by_stream``). server/async: pushes and broadcast
        replies are DISTINCT payloads — the total is their sum.
        ring/gossip: each edge payload is one node's uplink AND its
        neighbor's downlink — the SAME transmission viewed from both
        endpoints — so the total counts it once, not twice."""
        ms = self._legacy_sizes(moment_elems, moment_sizes)
        return sum(self.wire_bytes_by_stream(n_params, ms).values())


def get_exchange(topology: str = "server", codec: str = "fp32",
                 n_groups: int = 1, *, mix_rounds: int = 1,
                 staleness: int = 1, seed: int = 0, impl: str = "auto",
                 chunk: int = 256, topk_frac: float = 0.05,
                 moment_codec: str = "fp32", downlink_codec: str = "",
                 fused: bool = True, drop_rate: float = 0.0,
                 stall_rate: float = 0.0, fault_seed: int = 0,
                 dropouts=(), overlap: bool = False, n_pods: int = 0,
                 intra_topology: str = "ring",
                 inter_topology: str = "push_sum", inter_codec: str = "",
                 intra_drop_rate: float = 0.0,
                 intra_stall_rate: float = 0.0) -> Exchange:
    """Build an Exchange from names (the ``--comm`` / ``--codec`` /
    ``--moment-codec`` / ``--downlink-codec`` flags). ``moment_codec``
    applies to every moment stream of the payload (DESIGN.md §10); topk
    is refused there. ``downlink_codec`` ("" = default: the idealized
    broadcast priced at uplink widths) compresses the server/async
    broadcast reply independently of the uplink (DESIGN.md §11).
    ``drop_rate`` / ``stall_rate`` / ``fault_seed`` / ``dropouts``
    assemble a deterministic FaultPlan (the ``--drop-rate`` /
    ``--fault-seed`` flags, DESIGN.md §12); all-zero (the default)
    attaches NO plan, keeping every path bit-exact with the fault-free
    engine. ``overlap`` turns on double-buffered delayed mixing
    (DESIGN.md §14, the ``--overlap`` flag): the round mixes the previous
    round's in-flight payload while its own local steps run.

    Hierarchical (DESIGN.md §16, ``topology="hierarchical"``):
    ``n_pods`` factors the G axis into contiguous pods;
    ``intra_topology`` ('ring'|'server') mixes within pods,
    ``inter_topology`` ('push_sum'|'server') across them;
    ``inter_codec`` ("" = each stream's own codec) rides the cross-pod
    wire only. The generic ``drop_rate``/``stall_rate``/``dropouts``
    describe the LOSSY DCN (inter) tier; ``intra_drop_rate``/
    ``intra_stall_rate`` cover the ICI tier — the two tiers draw from
    independent seed lanes of one ``fault_seed``
    (``faults.fault_seed_for``). Every refusal below names the valid
    alternatives."""
    if topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r}: valid "
                         f"topologies are {TOPOLOGIES}")
    hier = topology == "hierarchical"
    if not hier:
        if n_pods:
            raise ValueError(
                f"n_pods only applies to topology 'hierarchical' (got "
                f"topology={topology!r}); valid flat topologies take no "
                "tier factoring — use 'hierarchical' or drop n_pods")
        if inter_codec:
            raise ValueError(
                "inter_codec only applies to topology 'hierarchical' — "
                "flat topologies have one wire; valid per-stream knobs "
                "there are 'codec', 'moment_codec', 'downlink_codec'")
        if intra_drop_rate or intra_stall_rate:
            raise ValueError(
                "intra_drop_rate/intra_stall_rate only apply to topology "
                "'hierarchical' — a flat topology's single tier is "
                "configured via 'drop_rate'/'stall_rate'")
    if hier:
        topo_mod.pod_size(n_groups, n_pods)    # validates the factoring
        if intra_topology not in INTRA_TOPOLOGIES:
            raise ValueError(
                f"unknown intra_topology {intra_topology!r}: valid "
                f"intra-pod topologies are {INTRA_TOPOLOGIES}")
        if inter_topology not in INTER_TOPOLOGIES:
            raise ValueError(
                f"unknown inter_topology {inter_topology!r}: valid "
                f"cross-pod topologies are {INTER_TOPOLOGIES}")
        if overlap:
            raise NotImplementedError(
                "overlap + hierarchical: the two mixing stages consume "
                "each other's outputs within one round — a "
                "one-round-stale in-flight payload would interleave the "
                "tiers ambiguously (DESIGN.md §16); valid overlap "
                "topologies: 'server', 'ring', 'gossip'")
        if downlink_codec:
            raise NotImplementedError(
                "hierarchical + downlink_codec: the cross-pod reply is "
                "priced per tier already — compress it with "
                "'inter_codec' instead; valid downlink_codec topologies: "
                "'server', 'async_stale'")
        for nm, c in (("codec", codec), ("moment_codec", moment_codec)):
            if c in ("int8", "int8z", "topk"):
                raise NotImplementedError(
                    f"hierarchical + {nm}={c!r}: pod-internal hops carry "
                    "whole-value payloads, not round deltas (DESIGN.md "
                    "§16); valid intra codecs: 'fp32', 'fp16', 'bf16' — "
                    "put int8 on the cross-tier wire via inter_codec "
                    "with inter_topology='server'")
        if inter_codec == "topk":
            raise NotImplementedError(
                "hierarchical + inter_codec='topk': error feedback "
                "against the pod-leader wire has no per-member residual "
                "home (DESIGN.md §16); valid inter codecs: 'fp32', "
                "'fp16', 'bf16', 'int8', 'int8z'")
        if inter_topology == "push_sum" and inter_codec in ("int8",
                                                            "int8z"):
            raise NotImplementedError(
                f"hierarchical push_sum inter tier + {inter_codec!r}: "
                "the cross-pod wire carries cumulative (value, weight) "
                "mass, not round deltas (DESIGN.md §12/§16); valid "
                "push_sum inter codecs: 'fp32', 'fp16', 'bf16' — or "
                "inter_topology='server' for 'int8'")
        if inter_topology == "server" and (drop_rate or stall_rate
                                           or dropouts):
            raise NotImplementedError(
                "hierarchical inter_topology='server' is the "
                "reliable-DCN baseline — it has no mass counters to "
                "conserve dropped payloads with; lossy cross-pod faults "
                "need inter_topology='push_sum', or a flat faulty "
                "'server'")
    if overlap:
        if topology == "none":
            raise NotImplementedError(
                "topology 'none' has no wire, so there is nothing to put "
                "in flight — overlap would double-buffer a payload that "
                "never ships (DESIGN.md §14); valid overlap topologies: "
                "'server', 'ring', 'gossip'")
        if topology == "async_stale":
            raise NotImplementedError(
                "overlap + async_stale: overlap IS bounded staleness "
                "(s=1 delayed mixing on every topology, DESIGN.md §14) — "
                "stacking the per-group staleness schedule on top would "
                "compound the lag ambiguously; use overlap on 'server' "
                "(same semantics, every group one round stale) or plain "
                "async_stale with staleness=1")
        if topology == "push_sum":
            raise NotImplementedError(
                "overlap + push_sum: the mass counters and per-edge "
                "backlogs must update in the SAME step that mixes the "
                "payload (sum(mass) + sum(backlog_w) == G every round, "
                "DESIGN.md §12) — a one-round-stale mix would break mass "
                "conservation; valid overlap topologies: 'server', "
                "'ring', 'gossip'")
        if downlink_codec:
            raise NotImplementedError(
                "overlap + downlink_codec: the downlink re-encodes the "
                "MIXED mean against the last broadcast, but with overlap "
                "the mix happens a round after the encode — the "
                "broadcast reference would be two rounds stale and the "
                "in-flight payload no longer matches what receivers "
                "decode (DESIGN.md §14); drop one of the two, or use "
                "the barrier engine with downlink_codec")
        if mix_rounds != 1 and topology in ("ring", "gossip"):
            raise NotImplementedError(
                "overlap + mix_rounds > 1: a multi-hop round re-encodes "
                "per hop, but the in-flight payload is a SINGLE encoded "
                "buffer — only one codec-free hop can ride it "
                "(DESIGN.md §14); use mix_rounds=1 with overlap, or the "
                "barrier engine for k-hop rounds")
        if drop_rate or stall_rate or dropouts:
            raise NotImplementedError(
                "overlap + fault injection: the fault masks gate the "
                "mixing in the round that SHIPS the payload — with "
                "delayed mixing the drop schedule and the mix are a "
                "round apart, and retry-from-pushed semantics (DESIGN.md "
                "§12) have no in-flight analogue yet; valid overlap "
                "networks are fault-free, or use the barrier engine "
                "with a FaultPlan")
        if codec == "topk" or moment_codec == "topk":
            raise NotImplementedError(
                "overlap + topk: the error-feedback residual re-offers "
                "unshipped mass against a reference that is one round "
                "stale under delayed mixing — the EF loop gain exceeds 1 "
                "at small selection fractions and the run diverges "
                "(DESIGN.md §14 refusal matrix, measured: ring/topk "
                "f=0.05 → inf); valid overlap codecs: 'fp32', 'fp16', "
                "'bf16', 'int8', 'int8z'")
    if downlink_codec:
        if topology in ("ring", "gossip"):
            raise NotImplementedError(
                "ring/gossip edge payloads are symmetric — each edge "
                "transmission IS both one node's uplink and its "
                "neighbor's downlink, so there is no separate downlink "
                "to compress (DESIGN.md §11); valid downlink_codec "
                "topologies: 'server', 'async_stale'")
        if topology == "push_sum":
            raise NotImplementedError(
                "push_sum edge payloads already carry the (value, "
                "weight) mass both ways — there is no broadcast reply "
                "to compress (DESIGN.md §12); valid downlink_codec "
                "topologies: 'server', 'async_stale'")
        if topology == "none":
            raise NotImplementedError(
                "the 'none' topology has no wire; a downlink codec "
                "would compress a broadcast that never happens; valid "
                "downlink_codec topologies: 'server', 'async_stale'")
        if downlink_codec == "topk":
            raise NotImplementedError(
                "topk is not supported as a downlink codec (DESIGN.md "
                "§11); valid downlink codecs: 'fp32', 'fp16', 'bf16', "
                "'int8'")
    if topology == "async_stale" and codec == "topk":
        # the staleness schedule DROPS non-pushing groups' deltas by
        # design; an error-feedback residual would instead absorb their
        # top-k entries as "delivered" and silently lose them
        raise NotImplementedError(
            "async_stale + topk: error feedback assumes every round's "
            "payload is delivered, but the staleness schedule drops "
            "non-pushing rounds (DESIGN.md §8); valid async_stale "
            "codecs: 'fp32', 'fp16', 'bf16', 'int8', 'int8z'")
    if moment_codec == "topk":
        # moments are re-estimated each step, not accumulated deltas of a
        # fixed target: delaying dropped moment mass via error feedback
        # would mix rounds-stale curvature into fresh estimates, and the
        # sparsity pattern of |delta| has no meaning for second moments
        raise NotImplementedError(
            "topk is not supported as a moment codec (DESIGN.md §10): "
            "error feedback would re-offer rounds-stale moment mass; "
            "valid moment codecs: 'fp32', 'fp16', 'bf16', 'int8', "
            "'int8z'")
    if topology == "push_sum":
        # refusal matrix (DESIGN.md §12): the push-sum wire carries
        # cumulative (value, weight) mass counters, not round deltas —
        # int8's per-round delta scaling and topk's error feedback have
        # no delta reference to code against. Cast codecs work: the
        # cast residue stays in the edge backlog (deferred, not lost).
        if codec in ("int8", "int8z", "topk"):
            raise NotImplementedError(
                f"push_sum + {codec}: the push-sum wire carries "
                "cumulative mass, not round deltas (DESIGN.md §12); "
                "valid push_sum codecs: 'fp32', 'fp16', 'bf16'")
        if moment_codec in ("int8", "int8z", "topk"):
            raise NotImplementedError(
                f"push_sum + moment_codec={moment_codec!r}: moment "
                "streams ride the same mass-counter wire (DESIGN.md "
                "§12); valid push_sum moment codecs: 'fp32', 'fp16', "
                "'bf16'")
    plan = None
    if hier:
        # per-tier plans on independent seed lanes of ONE fault_seed
        # (DESIGN.md §16): the generic drop/stall/dropout flags describe
        # the lossy DCN (inter) tier, intra_* the ICI tier
        plan = faults_mod.TieredFaultPlan(
            intra=faults_mod.FaultPlan(
                seed=faults_mod.fault_seed_for(fault_seed, "intra"),
                drop_rate=intra_drop_rate, stall_rate=intra_stall_rate),
            inter=faults_mod.FaultPlan(
                seed=faults_mod.fault_seed_for(fault_seed, "inter"),
                drop_rate=drop_rate, stall_rate=stall_rate,
                dropouts=tuple(tuple(d) for d in dropouts)))
        if plan.trivial:
            plan = None          # reliable tiers: the fault-free path
    elif drop_rate or stall_rate or dropouts:
        plan = faults_mod.FaultPlan(
            seed=fault_seed, drop_rate=drop_rate, stall_rate=stall_rate,
            dropouts=tuple(tuple(d) for d in dropouts))
        if plan.trivial:
            plan = None          # all-zero plan: keep the PR-5 code path
    if plan is not None and topology == "none":
        raise ValueError(
            "topology 'none' has no wire to drop packets from; valid "
            "fault-injection topologies: 'server', 'ring', 'gossip', "
            "'async_stale', 'push_sum', 'hierarchical'")
    c = codecs_mod.get_codec(codec, impl=impl, chunk=chunk,
                             topk_frac=topk_frac,
                             seed=faults_mod.codec_seed(seed, "params"))
    # moment streams share one codec instance seeded apart from the params
    # stream so their stochastic-rounding bits are independent of it
    # (registry lane "moments" — faults.CODEC_SEED_OFFSETS)
    mc = (_FP32 if moment_codec == "fp32" else
          codecs_mod.get_codec(moment_codec, impl=impl, chunk=chunk,
                               topk_frac=topk_frac,
                               seed=faults_mod.codec_seed(seed,
                                                          "moments")))
    # the downlink codec gets its own seed lane too (its rounding bits
    # must not correlate with either uplink stream's)
    dc = (codecs_mod.get_codec(downlink_codec, impl=impl, chunk=chunk,
                               topk_frac=topk_frac,
                               seed=faults_mod.codec_seed(seed,
                                                          "downlink"))
          if downlink_codec else None)
    # the cross-tier codec draws from the registry's "inter" lane
    xc = (codecs_mod.get_codec(inter_codec, impl=impl, chunk=chunk,
                               topk_frac=topk_frac,
                               seed=faults_mod.codec_seed(seed, "inter"))
          if inter_codec else None)
    w = None
    if topology in ("ring", "gossip"):
        w = topo_mod.mixing_matrix(topology, n_groups, seed=seed)
    return Exchange(topology=topology, codec=c, n_groups=n_groups,
                    mix_rounds=mix_rounds,
                    staleness=staleness if topology == "async_stale" else 0,
                    w=w, moment_codec=mc, downlink_codec=dc, fused=fused,
                    fault_plan=plan, overlap=overlap, n_pods=n_pods,
                    intra_topology=intra_topology,
                    inter_topology=inter_topology, inter_codec=xc)


def default_exchange(n_groups: int) -> Exchange:
    """The pre-comm behavior: star mean, uncompressed — bit-exact with
    ``average_groups``."""
    return get_exchange("server", "fp32", n_groups)
