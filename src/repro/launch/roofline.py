"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x input-shape) on the single-pod mesh, derive the three terms

  compute    = FLOPs_per_device / peak_FLOPs          (MXU)
  memory     = HBM_bytes_per_device / HBM_bandwidth   (HBM)
  collective = collective_bytes_per_device / ICI_bw   (interconnect)

from the trip-count-corrected HLO analysis (``repro.launch.hlocost`` — the
stock ``cost_analysis()`` counts scan bodies once, see that module), plus

  MODEL_FLOPS        = 6 * N(_active) * tokens  (the useful-work floor)
  MODEL_FLOPS / HLO  = fraction of compiled compute that is "useful"
                       (catches remat / densemask / rect-schedule waste)
  fit                = per-device argument bytes vs HBM capacity

Hardware constants: TPU v5e.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (~per-chip effective here)
HBM_CAP = 16e9             # v5e HBM per chip

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def comm_round_seconds(wire_bytes: float, bandwidth: float = ICI_BW) -> float:
    """Seconds one exchange round's payload spends on the slow link.

    ``wire_bytes`` is the EXACT codec-aware payload the comm subsystem
    reports (``Exchange.wire_bytes_per_round`` / round
    ``metrics["wire_bytes"]``). Feeds ``AdaptiveT.from_comm_bytes`` — the
    measured replacement for the HLO all-reduce estimate this module
    otherwise derives r from."""
    return wire_bytes / bandwidth


def model_flops(arch: str, shape_name: str, meta: Dict) -> float:
    """Global useful FLOPs for the step: 6*N(_active)*D training tokens
    (incl. the local T_i inner steps), 2*N*D for forward-only steps."""
    from repro.configs.base import INPUT_SHAPES, get_config

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    if shape.kind == "train":
        tokens = meta.get("tokens",
                          shape.global_batch * shape.seq_len)
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def load_records(multi_pod: bool = False, tag: str = "") -> List[Dict]:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    end = f"_{mesh}" + (f"_{tag}" if tag else "")
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        if not p.stem.endswith(end):
            continue
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "hlocost" not in rec:
        return None
    hc = rec["hlocost"]
    if "error" in hc:
        return None
    n_dev = rec["n_devices"]
    t_c = hc["flops"] / PEAK_FLOPS
    t_m = hc["hbm_bytes"] / HBM_BW
    t_x = hc["collective_bytes"] / ICI_BW
    slow_gb = hc.get("collective_bytes_slowlink", 0) / 1e9
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"], rec.get("meta", {}))
    hlo_global = hc["flops"] * n_dev
    arg_b = rec.get("arg_bytes_per_device", 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(d) for d in rec["mesh"]),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_frac": mf / hlo_global if hlo_global else 0.0,
        "arg_gb_per_device": arg_b / 1e9,
        "fits_hbm": arg_b <= HBM_CAP,
        "coll_by_kind": hc.get("collectives_by_kind", {}),
        "slowlink_gb": slow_gb,
        "tag": rec.get("tag", ""),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def render_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful% | GB/dev | fits | x-group GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {100 * r['useful_frac']:.1f} | "
            f"{r['arg_gb_per_device']:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | "
            f"{r['slowlink_gb']:.1f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = [r for r in (roofline_row(rec) for rec in
                        load_records(args.multi_pod, args.tag)) if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(render_table(rows))
        out = DRYRUN_DIR.parent / (
            "roofline" + ("_mp" if args.multi_pod else "")
            + (f"_{args.tag}" if args.tag else "") + ".json")
        out.write_text(json.dumps(rows, indent=1))
        print(f"\nsaved -> {out}")


if __name__ == "__main__":
    main()
