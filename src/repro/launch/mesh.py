"""Production meshes.

Target: TPU v5e pods — (data=16, model=16) = 256 chips per pod, and the
2-pod mesh (pod=2, data=16, model=16) = 512 chips. Local-SGD groups live on
the ("pod","data") axes (cheap averaging cadence over the slow links);
tensor parallelism lives on the fast "model" axis.

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False, fsdp: int = 1) -> Mesh:
    """Default: (data=16, model=16) per pod / (pod=2, data=16, model=16).

    fsdp > 1 splits the data axis into (data, fsdp): local-SGD groups stay
    on ("pod","data") while params additionally shard over "fsdp" inside a
    group (the §Perf memory hillclimb for 100B+ archs)."""
    if fsdp > 1:
        assert 16 % fsdp == 0, fsdp
        d = 16 // fsdp
        shape = (2, d, fsdp, 16) if multi_pod else (d, fsdp, 16)
        axes = (("pod", "data", "fsdp", "model") if multi_pod
                else ("data", "fsdp", "model"))
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh for CPU tests (defaults to the single local device)."""
    n = data * model
    devices = jax.devices()[:n]
    return Mesh(np.array(devices).reshape(data, model), ("data", "model"))
