"""Parse collective-communication volume out of optimized HLO text.

``cost_analysis()`` does not report collective bytes, so §Roofline's
collective term is derived here: scan the compiled module for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and sum their tensor sizes (result shape; for reduce-scatter the
pre-scatter input = result x group size).

Shapes are parsed from the HLO type syntax ``dtype[d0,d1,...]{layout}``.
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of every dtype[dims] occurrence in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [n_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


_IOTA_FULL_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}")


def replica_group_members(line: str):
    """Expand replica_groups (explicit or iota form) to lists of device
    ids. Returns None if no groups are present."""
    m = _IOTA_FULL_RE.search(line)
    if m:
        import numpy as np

        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s).tolist()
    m = _EXPLICIT_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",")]
                for grp in m.group(1).strip("{}").split("},{")]
    return None


def groups_cross_slow(line: str, slow_block: int) -> bool:
    """True if any replica group spans devices in different slow-axis
    blocks (block = 256 devices/pod on the multi-pod mesh; 16 devices per
    data-row on the single-pod mesh). These collectives ride the slow
    links — the traffic the paper's algorithm amortizes by T."""
    groups = replica_group_members(line)
    if not groups:
        return False
    for grp in groups:
        blocks = {d // slow_block for d in grp}
        if len(blocks) > 1:
            return True
    return False


def parse_collectives(hlo_text: str) -> List[Dict]:
    """One record per collective op: kind, tensor bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        nbytes = shape_bytes(result_type)
        g = _group_size(line)
        if kind.startswith("all-reduce") and "-start" in line:
            # start op result is a tuple (operand, result): halve
            nbytes //= 2
        moved = nbytes
        if kind == "reduce-scatter":
            moved = nbytes * g  # result is 1/g of the reduced input
        out.append({"kind": kind, "bytes": nbytes, "group": g,
                    "moved": moved})
    return out


def collective_summary(hlo_text: str) -> Dict:
    """Aggregate collective volume (per-device bytes, from SPMD module)."""
    recs = parse_collectives(hlo_text)
    by_kind: Dict[str, int] = {}
    for r in recs:
        by_kind[r["kind"]] = by_kind.get(r["kind"], 0) + r["moved"]
    return {
        "n_collectives": len(recs),
        "bytes_by_kind": by_kind,
        "total_bytes": sum(by_kind.values()),
    }
