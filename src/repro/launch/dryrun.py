import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with abstract inputs (no allocation), record
memory/cost/collective analysis for EXPERIMENTS.md.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host platform devices. Smoke
tests and benchmarks never import this module and keep seeing 1 device.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all            # every pair, single-pod
  python -m repro.launch.dryrun --all --multi-pod
Driver mode (--all) runs each combo in a subprocess so one failure or
compile-memory spike cannot take down the sweep; results are cached
incrementally in experiments/dryrun/*.json.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _result_path(arch: str, shape: str, multi_pod: bool, tag: str) -> Path:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"_{tag}" if tag else ""
    return OUT_DIR / f"{arch}_{shape}_{mesh}{suffix}.json"


def sharded_arg_bytes(args, shardings) -> int:
    """Per-device bytes of the step inputs under their shardings."""
    import jax

    total = 0

    def leafbytes(leaf, sh):
        shape = getattr(leaf, "shape", None)
        if shape is None:
            return 0
        dt = jax.numpy.dtype(leaf.dtype)
        if sh is not None and hasattr(sh, "shard_shape"):
            shape = sh.shard_shape(tuple(shape))
        n = 1
        for d in shape:
            n *= d
        return n * dt.itemsize

    for a, s in zip(args, shardings if shardings else [None] * len(args)):
        la = jax.tree.leaves(a)
        ls = jax.tree.leaves(
            s, is_leaf=lambda x: hasattr(x, "shard_shape")) if s is not None \
            else [None] * len(la)
        if len(ls) != len(la):
            ls = [None] * len(la)
        total += sum(leafbytes(x, y) for x, y in zip(la, ls))
    return total


def run_one(arch: str, shape_name: str, multi_pod: bool, tag: str = "",
            mode: str = "localsgd", t_inner: int = 4, opt_name: str = "sgd",
            moe_impl: str = "", save_hlo: bool = False,
            policy: str = "tp", fsdp: int = 1, param_dtype: str = "",
            schedule: str = "rect", embed_impl: str = "",
            packed: bool = False, comm: str = "server",
            codec: str = "fp32", mix_rounds: int = 1,
            staleness: int = 1, impl: str = "auto",
            moment_codec: str = "fp32", downlink_codec: str = "",
            drop_rate: float = 0.0, stall_rate: float = 0.0,
            fault_seed: int = 0, overlap: bool = False,
            trace: str = "") -> dict:
    import dataclasses as _dc

    import jax

    from repro import obs
    from repro.configs.base import INPUT_SHAPES, get_config
    from repro.launch import hlo as hlomod
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    cfg = get_config(arch)
    if param_dtype:
        cfg = _dc.replace(cfg, param_dtype=param_dtype)
    if embed_impl:
        cfg = _dc.replace(cfg, embed_impl=embed_impl)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, fsdp=fsdp)
    kw = {}
    if shape.kind == "train":
        kw = {"mode": mode, "t_inner": t_inner, "opt_name": opt_name,
              "policy": policy, "schedule": schedule, "packed": packed,
              "comm": comm, "codec": codec, "mix_rounds": mix_rounds,
              "staleness": staleness, "impl": impl,
              "moment_codec": moment_codec,
              "downlink_codec": downlink_codec,
              "drop_rate": drop_rate, "stall_rate": stall_rate,
              "fault_seed": fault_seed, "overlap": overlap}
        if moe_impl:
            kw["moe_impl"] = moe_impl
    elif shape.kind == "prefill":
        kw = {"policy": policy, "schedule": schedule}
    built = build_step(cfg, shape, mesh, **kw)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_devices": mesh.devices.size, "tag": tag, "meta": built.meta,
        "status": "started",
    }
    # null sink when --trace is unset: phases still time through the
    # same fenced path the launchers use (DESIGN.md §13)
    tr = obs.Trace(trace or None, meta={
        "arch": arch, "shape": shape_name, "mode": mode,
        "packed": packed, "comm": comm, "codec": codec,
        "mesh": list(mesh.devices.shape)})
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=getattr(built, "donate_argnums",
                                                ()))
        with tr.phase("lower"):
            lowered = jitted.lower(*built.args)
        with tr.phase("compile"):
            compiled = lowered.compile()
    phases = tr.take_phases()
    rec["lower_s"] = round(phases["lower"], 2)
    rec["compile_s"] = round(phases["compile"], 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory_analysis"] = {"error": str(e)}
    rec["arg_bytes_per_device"] = sharded_arg_bytes(
        built.args, built.in_shardings)

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals", "optimal_seconds")
                or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": str(e)}

    txt = compiled.as_text()
    rec["hlo_chars"] = len(txt)
    rec["collectives"] = hlomod.collective_summary(txt)
    try:
        from repro.launch import hlocost
        from repro.sharding import specs as shsp
        # slow-link boundary = the local-SGD GROUP boundary (the paper's
        # cross-group traffic): devices-per-group contiguous blocks.
        # (data=16,model=16) -> 16; (data=2,fsdp=8,model=16) -> 128;
        # multi-pod (pod,data,...) groups span pods -> same formula.
        slow_block = mesh.devices.size // max(shsp.n_groups(mesh), 1)
        rec["slow_block"] = slow_block
        rec["hlocost"] = hlocost.analyze(txt, slow_block=slow_block)
    except Exception as e:  # pragma: no cover
        rec["hlocost"] = {"error": str(e)}
    if save_hlo:
        p = _result_path(arch, shape_name, multi_pod, tag)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.with_suffix(".hlo.txt").write_text(txt)
    rec["status"] = "ok"
    tr.emit("dryrun", arch=arch, shape=shape_name,
            lower_s=rec["lower_s"], compile_s=rec["compile_s"],
            hlo_chars=rec["hlo_chars"], collectives=rec["collectives"])
    tr.close()
    return rec


def save(rec: dict, arch: str, shape: str, multi_pod: bool, tag: str):
    p = _result_path(arch, shape, multi_pod, tag)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(rec, indent=1))
    return p


def drive_all(multi_pod: bool, tag: str, force: bool, extra: list) -> int:
    """Run every (arch x shape) in subprocesses; cache results."""
    from repro.configs.base import ARCH_IDS, INPUT_SHAPES

    failures = 0
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            p = _result_path(arch, shape, multi_pod, tag)
            if p.exists() and not force:
                st = json.loads(p.read_text()).get("status")
                if st == "ok":
                    print(f"[skip] {p.name}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if multi_pod:
                cmd.append("--multi-pod")
            if tag:
                cmd += ["--tag", tag]
            cmd += extra
            print(f"[run ] {arch} x {shape} "
                  f"({'2x16x16' if multi_pod else '16x16'})", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            dt = time.time() - t0
            if r.returncode != 0:
                failures += 1
                err = (r.stderr or "")[-2000:]
                save({"arch": arch, "shape": shape, "status": "error",
                      "error": err, "tag": tag}, arch, shape, multi_pod, tag)
                print(f"[FAIL] {arch} x {shape} ({dt:.0f}s)\n{err}",
                      flush=True)
            else:
                print(f"[ ok ] {arch} x {shape} ({dt:.0f}s)", flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mode", default="localsgd",
                    choices=["localsgd", "sync"])
    ap.add_argument("--t-inner", type=int, default=4)
    ap.add_argument("--opt", default="sgd")
    ap.add_argument("--packed", action="store_true",
                    help="flat-buffer train round (DESIGN.md §6): records "
                         "the packed engine's memory/collective profile "
                         "(sharded over the in-group axes when the mesh "
                         "has them — DESIGN.md §9)")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="packed update/codec kernels (pallas needs the "
                         "sharded packed path on multi-device meshes)")
    ap.add_argument("--comm", default="server",
                    choices=["server", "ring", "gossip", "async_stale",
                             "push_sum", "none"],
                    help="exchange topology (repro.comm, DESIGN.md §8; "
                         "push_sum is loss-tolerant ratio consensus)")
    ap.add_argument("--codec", default="fp32",
                    choices=["fp32", "fp16", "bf16", "int8", "int8z",
                             "topk"],
                    help="wire codec; int8/int8z/topk need --packed")
    ap.add_argument("--moment-codec", default="fp32",
                    choices=["fp32", "fp16", "bf16", "int8", "int8z"],
                    help="wire codec for the optimizer moment streams "
                         "(DESIGN.md §10); meta reports per-stream "
                         "wire_bytes_per_round_by_stream")
    ap.add_argument("--downlink-codec", default="",
                    choices=["", "fp32", "fp16", "bf16", "int8", "int8z"],
                    help="compress the server/async broadcast reply "
                         "independently of the uplink (DESIGN.md §11); "
                         "wire_bytes_down_per_round prices it")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered delayed mixing (DESIGN.md §14): "
                         "records the overlapped round's collective "
                         "profile (encode+mix scheduled beside the local "
                         "steps in one graph); needs --packed")
    ap.add_argument("--mix-rounds", type=int, default=1,
                    help="mixing hops per round (ring/gossip)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="bounded staleness s (async_stale)")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="deterministic fault injection (DESIGN.md §12): "
                         "per-edge packet-drop probability in [0, 1)")
    ap.add_argument("--stall-rate", type=float, default=0.0,
                    help="per-round node stall probability in [0, 1)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="FaultPlan mask-stream seed")
    ap.add_argument("--moe-impl", default="")
    ap.add_argument("--save-hlo", action="store_true")
    # §Perf hillclimb knobs ---------------------------------------------
    ap.add_argument("--policy", default="tp", choices=["tp", "dp"])
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--param-dtype", default="")
    ap.add_argument("--schedule", default="rect",
                    choices=["rect", "tri"])
    ap.add_argument("--embed-impl", default="",
                    choices=["", "onehot", "gather"])
    ap.add_argument("--trace", default="",
                    help="append lower/compile phase records to this "
                         "JSONL sink (single-run mode; --all subprocesses "
                         "would clobber one file)")
    args = ap.parse_args()
    if args.impl != "auto" and not args.packed:
        ap.error("--impl selects the packed fused kernels; add --packed")
    if args.trace and args.all:
        ap.error("--trace is single-run only; --all runs subprocesses")

    if args.all:
        extra = []
        if args.mode != "localsgd":
            extra += ["--mode", args.mode]
        if args.t_inner != 4:
            extra += ["--t-inner", str(args.t_inner)]
        if args.opt != "sgd":
            extra += ["--opt", args.opt]
        if args.moe_impl:
            extra += ["--moe-impl", args.moe_impl]
        if args.comm != "server":
            extra += ["--comm", args.comm]
        if args.codec != "fp32":
            extra += ["--codec", args.codec]
        if args.moment_codec != "fp32":
            extra += ["--moment-codec", args.moment_codec]
        if args.downlink_codec:
            extra += ["--downlink-codec", args.downlink_codec]
        if args.mix_rounds != 1:
            extra += ["--mix-rounds", str(args.mix_rounds)]
        if args.staleness != 1:
            extra += ["--staleness", str(args.staleness)]
        if args.drop_rate:
            extra += ["--drop-rate", str(args.drop_rate)]
        if args.stall_rate:
            extra += ["--stall-rate", str(args.stall_rate)]
        if args.fault_seed:
            extra += ["--fault-seed", str(args.fault_seed)]
        if args.overlap:
            extra += ["--overlap"]
        if args.impl != "auto":
            extra += ["--impl", args.impl]
        sys.exit(1 if drive_all(args.multi_pod, args.tag, args.force,
                                extra) else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    try:
        rec = run_one(args.arch, args.shape, args.multi_pod, args.tag,
                      mode=args.mode, t_inner=args.t_inner,
                      opt_name=args.opt, moe_impl=args.moe_impl,
                      save_hlo=args.save_hlo, policy=args.policy,
                      fsdp=args.fsdp, param_dtype=args.param_dtype,
                      schedule=args.schedule, embed_impl=args.embed_impl,
                      packed=args.packed, comm=args.comm, codec=args.codec,
                      mix_rounds=args.mix_rounds, staleness=args.staleness,
                      impl=args.impl, moment_codec=args.moment_codec,
                      downlink_codec=args.downlink_codec,
                      drop_rate=args.drop_rate,
                      stall_rate=args.stall_rate,
                      fault_seed=args.fault_seed, overlap=args.overlap,
                      trace=args.trace)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "status": "error",
               "error": traceback.format_exc()[-4000:], "tag": args.tag}
        save(rec, args.arch, args.shape, args.multi_pod, args.tag)
        print(rec["error"], file=sys.stderr)
        sys.exit(1)
    p = save(rec, args.arch, args.shape, args.multi_pod, args.tag)
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "compile_s", "collectives")
                      if k in rec}, indent=1))
    print(f"saved -> {p}")


if __name__ == "__main__":
    main()
