"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` lowered to a while loop therefore reports the flops of a
single iteration (verified empirically: a scan of 10 matmuls reports the
flops of 1). All our stacks scan over layers and the local-SGD round scans
over inner steps, so the built-in numbers undercount by orders of
magnitude. Fortunately XLA annotates every scan-derived while op with
``backend_config={"known_trip_count":{"n":...}}``; this module re-derives

  * matmul FLOPs           (dot ops, weighted by the product of enclosing
                            while trip counts),
  * HBM traffic estimate   (TPU-fusion model: kernel-boundary ops (dot,
                            fusion, reduce, gather/scatter) count operands
                            + result; dynamic-(update-)slice counts the
                            slice only (in-place on TPU); elementwise /
                            convert / transpose / broadcast count their
                            result once, assuming producer fusion),
  * collective bytes       (all-gather / all-reduce / reduce-scatter /
                            all-to-all / collective-permute, trip-weighted)

by walking the call graph from ENTRY with a multiplier.

Caveats (documented in EXPERIMENTS.md):
  * FLOPs counts dot ops only — elementwise/transcendental flops are not
    MXU work and are ignored (they show up in the memory term instead).
  * ``conditional`` branches are both counted once (upper bound).
  * The HLO module is the per-device SPMD program: all numbers are
    PER DEVICE.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.hlo import _DTYPE_BYTES
from repro.launch.hlo import groups_cross_slow as hlo_groups_cross

# --------------------------------------------------------------------------
# Parsing
# --------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE_RE = re.compile(r"([a-z][0-9a-z]*)\[([0-9,]*)\]")

_FREE_OPS = {  # no data movement of their own
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "iota", "after-all", "partition-id", "replica-id", "bitcast-convert",
    "reshape",
}
# Kernel boundaries: operands are genuinely streamed from HBM.
_BOUNDARY_OPS = {
    "dot", "fusion", "custom-call", "reduce", "reduce-window", "sort",
    "scatter", "gather", "convolution", "cholesky", "triangular-solve",
    "rng", "rng-bit-generator",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _split_top(s: str) -> List[str]:
    """Split on commas at paren/bracket/brace depth 0."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [x for x in out if x]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _type_dims(type_str: str) -> List[int]:
    """Dims of a single (non-tuple) array type."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _tuple_elem(type_str: str, idx: int) -> str:
    t = type_str.strip()
    if t.startswith("("):
        inner = t[1:t.rfind(")")]
        elems = _split_top(inner)
        if idx < len(elems):
            return elems[idx]
    return t


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    operands: List[str]
    attrs: str
    trip: int = 1  # for while ops


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]          # param name -> type str
    ops: List[Op]
    symtab: Dict[str, str]          # op/param name -> type str


def _parse_op_rhs(rhs: str) -> Optional[Tuple[str, str, List[str], str]]:
    """rhs = '<type> <opkind>(<operands>), attrs' -> parts."""
    # type is everything before the op kind token; op kind is the last
    # word before the first '(' that starts the operand list.
    m = re.match(r"(\(.*?\)|[a-z][0-9a-z]*\[[0-9,]*\](?:\{[^}]*\})?"
                 r"|[a-z][0-9a-z]*\[\])\s+([\w\-]+)\((.*)$", rhs)
    if not m:
        # scalar types like 's32[]' handled above; tokens w/o type: skip
        m2 = re.match(r"(\S+)\s+([\w\-]+)\((.*)$", rhs)
        if not m2:
            return None
        m = m2
    type_str, kind, rest = m.group(1), m.group(2), m.group(3)
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    operand_str = rest[:i - 1] if depth == 0 else rest
    attrs = rest[i:] if depth == 0 else ""
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return type_str, kind, operands, attrs


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _COMP_HDR.match(line.strip())
            if m:
                is_entry, name, params_str = m.group(1), m.group(2), m.group(3)
                params = {}
                for p in _split_top(params_str):
                    pm = re.match(r"%?([\w.\-]+)\s*:\s*(.+)", p)
                    if pm:
                        params[pm.group(1)] = pm.group(2)
                cur = Computation(name, params, [], dict(params))
                comps[name] = cur
                if is_entry:
                    entry = name
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parsed = _parse_op_rhs(rhs)
        if not parsed:
            continue
        type_str, kind, operands, attrs = parsed
        op = Op(name, type_str, kind, operands, attrs)
        if kind == "while":
            tm = _TRIP_RE.search(line)
            op.trip = int(tm.group(1)) if tm else 1
        if kind == "get-tuple-element":
            im = re.search(r"index=(\d+)", attrs)
            src = operands[0] if operands else None
            if im and src and src in cur.symtab:
                type_str = _tuple_elem(cur.symtab[src], int(im.group(1)))
                op.type_str = type_str
        cur.ops.append(op)
        cur.symtab[name] = op.type_str
    return comps, entry


# --------------------------------------------------------------------------
# Cost accumulation
# --------------------------------------------------------------------------

_CALLEE_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"(\{[^}]*\}|%[\w.\-]+)")


def _callees(op: Op) -> List[Tuple[str, str]]:
    """(role, computation-name) pairs referenced by this op."""
    out = []
    for m in _CALLEE_RE.finditer(op.attrs):
        blob = m.group(1)
        role = m.group(0).split("=")[0]
        for name in re.findall(r"%([\w.\-]+)", blob):
            out.append((role, name))
    return out


def _dot_flops(op: Op, comp: Computation) -> int:
    res_dims = _type_dims(op.type_str)
    n = 1
    for d in res_dims:
        n *= d
    lhs = op.operands[0] if op.operands else None
    lhs_dims = _type_dims(comp.symtab.get(lhs, "")) if lhs else []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    k = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2 * n * k


def _fusion_operand_bytes(comps, sub_names, comp, op) -> int:
    """Bytes a boundary fusion reads: operands that the fused computation
    touches only through dynamic-slice are charged at slice size (the
    gather-from-carried-buffer pattern); everything else reads fully."""
    full = [_type_bytes(comp.symtab.get(o, "")) for o in op.operands]
    for _, sub in sub_names:
        fc = comps.get(sub)
        if fc is None:
            continue
        pnames = list(fc.params)
        uses = {p: [] for p in pnames}
        for o in fc.ops:
            for opr in o.operands:
                if opr in uses:
                    uses[opr].append(o)
        for i, p in enumerate(pnames):
            if i >= len(full):
                break
            us = uses[p]
            if us and all(u.kind == "dynamic-slice" for u in us):
                full[i] = min(full[i],
                              sum(_type_bytes(u.type_str) for u in us))
    return sum(full)


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: float = 0.0
    n_while: int = 0
    max_trip_product: int = 1


def analyze(hlo_text: str, slow_block: Optional[int] = None) -> Dict:
    comps, entry = parse_module(hlo_text)
    totals = CostTotals()
    # memoize (comp) -> per-invocation partial costs is unsafe because
    # flops depend only on comp; multipliers applied at call sites. So
    # compute per-comp costs once, then weight by total invocation count.
    comp_cost: Dict[str, Dict] = {}

    def fusion_is_elementwise(name: str) -> bool:
        comp = comps.get(name)
        if comp is None:
            return True
        kinds = {o.kind for o in comp.ops}
        return not (kinds & (_BOUNDARY_OPS - {"fusion"})
                    | (kinds & {"dynamic-update-slice", "dynamic-slice"}))

    def fusion_inplace_bytes(name: str) -> Optional[int]:
        """If the fused computation is an in-place slice update (the scan
        carry pattern: DUS into a buffer the loop aliases), return the
        read-modify-write bytes of the slices; else None."""
        comp = comps.get(name)
        if comp is None:
            return None
        dus = [o for o in comp.ops if o.kind == "dynamic-update-slice"]
        if not dus:
            return None
        others = {o.kind for o in comp.ops} - {
            "dynamic-update-slice", "dynamic-slice"} - _FREE_OPS
        if others & _BOUNDARY_OPS:
            return None
        total = 0
        for o in dus:
            upd = (comp.symtab.get(o.operands[1], "")
                   if len(o.operands) > 1 else "")
            total += 2 * _type_bytes(upd)
        for o in comp.ops:
            if o.kind == "dynamic-slice":
                total += 2 * _type_bytes(o.type_str)
        return total

    def comp_local_cost(name: str) -> Dict:
        """Costs of one invocation of `name`, including callees."""
        if name in comp_cost:
            return comp_cost[name]
        comp = comps.get(name)
        c = {"flops": 0.0, "hbm": 0.0, "coll": {}, "coll_n": 0.0,
             "coll_x": 0.0, "n_while": 0, "max_trip": 1}
        if comp is None:
            comp_cost[name] = c
            return c
        comp_cost[name] = c  # pre-insert to break cycles (shouldn't occur)
        for op in comp.ops:
            mult = 1
            sub_names = _callees(op)
            if op.kind == "while":
                mult = op.trip
                c["n_while"] += 1
            if op.kind == "dot":
                c["flops"] += _dot_flops(op, comp)
            if op.kind in _FREE_OPS or op.kind == "while":
                pass
            elif op.kind == "fusion":
                rb = _type_bytes(op.type_str)
                inplace = [fusion_inplace_bytes(s) for _, s in sub_names]
                if sub_names and all(b is not None for b in inplace):
                    # scan-carry pattern: the loop aliases the buffer and
                    # only the updated slice moves.
                    c["hbm"] += sum(inplace)
                elif all(fusion_is_elementwise(s) for _, s in sub_names):
                    # CPU backend wraps single elementwise ops in kLoop
                    # fusions; on the TPU target these fuse with their
                    # producers — stream the result once.
                    c["hbm"] += rb
                else:
                    ob = _fusion_operand_bytes(
                        comps, sub_names, comp, op)
                    c["hbm"] += rb + ob
            elif op.kind in _BOUNDARY_OPS:
                # kernel boundary: operands streamed from HBM + result
                rb = _type_bytes(op.type_str)
                ob = sum(_type_bytes(comp.symtab.get(o, ""))
                         for o in op.operands)
                c["hbm"] += rb + ob
            elif op.kind == "dynamic-update-slice":
                # in-place on TPU: read-modify-write of the update slice
                upd = (comp.symtab.get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                c["hbm"] += 2 * _type_bytes(upd)
            elif op.kind == "dynamic-slice":
                c["hbm"] += 2 * _type_bytes(op.type_str)
            else:
                # elementwise / convert / copy / transpose / broadcast /
                # select / concatenate / pad: assume producer fusion on the
                # TPU target — stream the result once.
                c["hbm"] += _type_bytes(op.type_str)
            for kind in _COLLECTIVES:
                if op.kind.startswith(kind):
                    b = _type_bytes(op.type_str)
                    if op.kind.endswith("-start"):
                        b //= 2
                    if kind == "reduce-scatter":
                        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]",
                                       op.attrs)
                        g = int(gm.group(2)) if gm else 1
                        if not gm:
                            gm2 = re.search(r"replica_groups=\{\{([0-9,]+)\}",
                                            op.attrs)
                            g = len(gm2.group(1).split(",")) if gm2 else 1
                        b *= g
                    c["coll"][kind] = c["coll"].get(kind, 0.0) + b
                    c["coll_n"] += 1
                    if slow_block and hlo_groups_cross(op.attrs,
                                                       slow_block):
                        c["coll_x"] += b
                    break
            is_fusion = op.kind == "fusion"
            for _, sub in sub_names:
                s = comp_local_cost(sub)
                c["flops"] += mult * s["flops"]
                if not is_fusion:
                    # fusion internals live in registers/VMEM: only the
                    # boundary (counted above) touches HBM.
                    c["hbm"] += mult * s["hbm"]
                c["coll_n"] += mult * s["coll_n"]
                c["coll_x"] += mult * s["coll_x"]
                c["n_while"] += s["n_while"]
                c["max_trip"] = max(c["max_trip"], mult * s["max_trip"])
                for k, v in s["coll"].items():
                    c["coll"][k] = c["coll"].get(k, 0.0) + mult * v
        return c

    root = comp_local_cost(entry)
    totals.flops = root["flops"]
    totals.hbm_bytes = root["hbm"]
    totals.collectives_by_kind = root["coll"]
    totals.collective_bytes = sum(root["coll"].values())
    totals.collective_count = root["coll_n"]
    totals.n_while = root["n_while"]
    totals.max_trip_product = root["max_trip"]
    return {
        "collective_bytes_slowlink": root["coll_x"],
        "flops": totals.flops,
        "hbm_bytes": totals.hbm_bytes,
        "collective_bytes": totals.collective_bytes,
        "collectives_by_kind": totals.collectives_by_kind,
        "collective_count": totals.collective_count,
        "n_while": totals.n_while,
        "max_trip_product": totals.max_trip_product,
    }


def main() -> None:  # pragma: no cover - CLI convenience
    import sys

    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=1))


if __name__ == "__main__":
    main()
