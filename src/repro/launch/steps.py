"""Step builders for the dry-run / launcher: per (arch, input-shape, mesh)
produce a jit-able step function plus abstract inputs and shardings.

Modes (from InputShape.kind):
  train    local-SGD round (paper Alg 1: T local steps + averaging) or the
           conventional sync-DP baseline
  prefill  forward over the full sequence + last-position logits
  decode   one token against a KV cache of cache_len (sliding window for
           long_500k on attention archs — see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import comm as comm_mod
from repro import obs
from repro import optim
from repro.configs.base import ArchConfig, InputShape
from repro.core import localsgd as lsgd
from repro.optim import packing
from repro.models import build_model
from repro.sharding import shardexec as shx
from repro.sharding import specs as sh

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class BuiltStep:
    fn: Any                       # callable to jit
    args: Tuple                   # abstract args (ShapeDtypeStructs)
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]
    # args to donate when jitting (train states: XLA updates the model in
    # place over the T-step round instead of double-buffering it)
    donate_argnums: Tuple[int, ...] = ()


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch inputs (ShapeDtypeStruct stand-ins + shardings)
# ---------------------------------------------------------------------------


def batch_abstract(cfg: ArchConfig, batch_dims: Tuple[int, ...],
                   seq_len: int, mesh: Mesh, leading_group: bool,
                   inner_axis: Optional[str] = None):
    """Abstract model inputs with leading batch dims (e.g. (G, b) or (B,)).

    inner_axis: mesh axis the per-group batch dim shards over — "fsdp"
    under the fsdp policy, "model" under the dp policy (params
    replicated, the model axis acts as extra data parallelism)."""
    dp = sh.dp_axes(mesh)
    lead = P(dp) if leading_group else sh.batch_spec(mesh, batch_dims[0],
                                                     False)
    pad: Tuple = (None,) * (len(batch_dims) - 1)
    if (inner_axis and len(batch_dims) > 1
            and inner_axis in mesh.axis_names
            and batch_dims[1] % mesh.shape[inner_axis] == 0):
        pad = (inner_axis,) + (None,) * (len(batch_dims) - 2)
    toks = SDS(batch_dims + (seq_len,), jnp.int32)
    spec_t = P(*(tuple(lead) + pad + (None,)))
    batch = {"tokens": toks}
    specs = {"tokens": spec_t}
    if cfg.family == "vlm":
        batch["patches"] = SDS(batch_dims + (cfg.n_patches, cfg.d_model),
                               jnp.float32)
        specs["patches"] = P(*(tuple(lead) + pad + (None, None)))
    if cfg.family == "audio":
        batch["frames"] = SDS(batch_dims + (cfg.n_frames, cfg.d_model),
                              jnp.float32)
        specs["frames"] = P(*(tuple(lead) + pad + (None, None)))
    return batch, specs


# ---------------------------------------------------------------------------
# Cache shardings (decode)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, cache_abs, mesh: Mesh, batch: int):
    """Name/rank-based PartitionSpecs for decode caches (see DESIGN.md):
    batch over ("pod","data") when divisible; for attention KV the cache
    *length* axis shards over "model" when divisible (kv heads rarely divide
    16); mamba heads / xlstm channels shard over "model"."""
    bx = sh.serve_batch_axes(mesh)
    bsz = 1
    for a in bx:
        bsz *= mesh.shape[a]
    b_ax = bx if (bsz > 1 and batch % bsz == 0) else None
    msz = mesh.shape.get("model", 1)

    def for_leaf(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        shp = leaf.shape
        if "slot_pos" in names:
            return P()
        if names[0] == "kv" or "cross" in names[0]:
            # (L, B, W, KV, hd)
            w_ax = "model" if shp[2] % msz == 0 else None
            return P(None, b_ax, w_ax, None, None)
        if names[0] == "mamba":
            if names[-1] == "conv":      # (L, B, K, di)
                return P(None, b_ax, None,
                         "model" if shp[3] % msz == 0 else None)
            # ssm (L, B, H, N, P)
            return P(None, b_ax, "model" if shp[2] % msz == 0 else None,
                     None, None)
        if names[0] == "mlstm":
            # (g, n_m, B, H, P, P) or (g, n_m, B, H, P)
            h_ax = "model" if shp[3] % msz == 0 else None
            rest = (None,) * (len(shp) - 4)
            return P(None, None, b_ax, h_ax, *rest)
        if names[0] == "slstm":
            # (g, B, di)
            return P(None, b_ax, "model" if shp[2] % msz == 0 else None)
        return P(*( (None,) * len(shp) ))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    out = [for_leaf(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                     *, t_inner: int = 4, opt_name: str = "sgd",
                     lr: float = 1e-3, mode: str = "localsgd",
                     schedule: str = "rect", moe_impl: Optional[str] = None,
                     policy: str = "tp", packed: bool = False,
                     comm: str = "server", codec: str = "fp32",
                     mix_rounds: int = 1, staleness: int = 1,
                     impl: str = "auto", moment_codec: str = "fp32",
                     downlink_codec: str = "", drop_rate: float = 0.0,
                     stall_rate: float = 0.0,
                     fault_seed: int = 0,
                     overlap: bool = False, n_pods: int = 0,
                     intra_topology: str = "ring",
                     inter_topology: str = "push_sum",
                     inter_codec: str = "",
                     intra_drop_rate: float = 0.0,
                     intra_stall_rate: float = 0.0) -> BuiltStep:
    """policy (see sharding.specs.spec_for): "tp" (baseline), "dp"
    (replicate params, batch over the model axis — small archs), or "tp"
    on an fsdp mesh (params additionally sharded over "fsdp").

    packed=True runs the round on the flat-buffer fast path (DESIGN.md
    §6): state leaves are single (G, Np) f32 buffers, every inner step is
    one fused update pass, and the state args are donated. On meshes with
    an in-group axis ("model"/"fsdp" > 1) the buffer additionally shards
    over those axes and the fused/codec kernels run inside shard_map
    blocks on the local shards (sharded execution, DESIGN.md §9);
    otherwise the buffer is replicated within a group.

    comm/codec select the exchange backend (repro.comm, DESIGN.md §8) for
    local-SGD rounds; moment_codec applies to every moment stream of the
    payload (DESIGN.md §10 — fp32/fp16/bf16/int8, topk refused). Flat-only
    codecs (int8) on either stream need packed=True; comm state (per-stream
    codec residuals, staleness buffers) rides in the train state and
    shares its shardings.

    impl picks the packed-update/codec kernels: "pallas" (fused kernels —
    sharded or single-device packed paths only), "jnp" (one XLA fusion),
    "auto" (pallas where supported, else jnp)."""
    if mode == "sync" and (comm != "server" or codec != "fp32"
                           or moment_codec != "fp32" or downlink_codec
                           or drop_rate or stall_rate or overlap
                           or n_pods or inter_codec
                           or intra_drop_rate or intra_stall_rate):
        raise ValueError(
            "comm/codec/fault flags select the local-SGD model exchange; "
            "sync-DP all-reduces gradients every step and has no "
            "exchange — drop the flags or use mode='localsgd'")
    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    model = build_model(cfg, schedule=schedule)
    if "fsdp" in mesh.axis_names and policy == "tp" and not packed:
        # (packed rounds skip the per-layer fsdp hooks: the fsdp axis
        # shards the flat buffer itself via shardexec, and constraining
        # the unpacked views would fight that layout)
        model = _fsdp_model(cfg, mesh, model, schedule,
                            act_axes=("fsdp",))
    if cfg.param_dtype != "float32":
        from repro.models.layers import is_pdef
        model.defs = jax.tree.map(
            lambda d: dataclasses.replace(d, dtype=cfg.param_dtype),
            model.defs, is_leaf=is_pdef)
    if packed:
        # the packed buffer has its own sharding story (G axis + in-group
        # shard axes via shardexec); the per-tensor policies don't apply
        if policy != "tp":
            raise NotImplementedError(
                "packed train steps ignore per-tensor policies (the flat "
                "buffer shards over the in-group axes via shardexec, "
                "DESIGN.md §9); drop --packed or the policy flag")
        if mode == "sync" and "fsdp" in mesh.axis_names:
            # sync keeps the replicated (N,) buffer (no G axis, no
            # shard_map path) — refuse rather than silently record a
            # replicated profile on a mesh the caller built for sharding
            raise NotImplementedError(
                "packed sync steps keep the replicated (N,) buffer; "
                "in-group sharding is a localsgd feature (DESIGN.md §9) "
                "— drop the fsdp axis or use mode='localsgd'")
        return _build_packed_train_step(cfg, shape, mesh, model, opt_name,
                                        lr, mode, t_inner, comm, codec,
                                        mix_rounds, staleness, impl,
                                        moment_codec, downlink_codec,
                                        drop_rate, stall_rate, fault_seed,
                                        overlap, n_pods, intra_topology,
                                        inter_topology, inter_codec,
                                        intra_drop_rate, intra_stall_rate)
    if impl != "auto":
        # same no-silent-fallback rule as optim.get: the pytree round has
        # no fused-kernel path for impl to select
        raise ValueError(
            f"impl={impl!r} selects the packed fused kernels; pass "
            "packed=True (the pytree round has no Pallas path)")
    opt = optim.get(opt_name, lr)
    dp = sh.dp_axes(mesh)
    pspecs = sh.resolve_specs(model.defs, mesh, policy=policy)
    pspecs = _drop_fsdp_outside_blocks(pspecs)
    params_abs = model.abstract()

    if mode == "sync":
        step = lsgd.make_sync_step(model.loss, opt)
        B = shape.global_batch
        batch_abs, bspecs = batch_abstract(cfg, (B,), shape.seq_len, mesh,
                                           leading_group=False)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = _opt_specs(opt_abs, pspecs, group=())
        state_abs = {"params": params_abs, "opt": opt_abs}
        sspecs = {"params": pspecs, "opt": ospecs}
        return BuiltStep(
            step, (state_abs, batch_abs),
            (_ns(mesh, sspecs), _ns(mesh, bspecs)),
            (_ns(mesh, sspecs), None),
            {"mode": "sync", "tokens": B * shape.seq_len, "t_inner": 1})

    # local-SGD round (the paper's algorithm)
    G = sh.n_groups(mesh)
    assert shape.global_batch % G == 0, (shape.global_batch, G)
    b = shape.global_batch // G
    exchange, avg_opt = _build_exchange(comm, codec, G, mix_rounds,
                                        staleness,
                                        moment_codec=moment_codec,
                                        downlink_codec=downlink_codec,
                                        drop_rate=drop_rate,
                                        stall_rate=stall_rate,
                                        fault_seed=fault_seed,
                                        overlap=overlap, n_pods=n_pods,
                                        intra_topology=intra_topology,
                                        inter_topology=inter_topology,
                                        inter_codec=inter_codec,
                                        intra_drop_rate=intra_drop_rate,
                                        intra_stall_rate=intra_stall_rate)
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner,
                               inner_mode="fixed_batch",
                               average_opt_state=avg_opt)
    round_ = lsgd.make_local_round(model.loss, opt, lcfg,
                                   exchange=exchange)

    params_G = jax.tree.map(lambda s: SDS((G,) + s.shape, s.dtype),
                            params_abs)
    pspecs_G = _drop_fsdp_outside_blocks(
        sh.resolve_specs(model.defs, mesh, leading=dp, policy=policy))
    opt_1 = jax.eval_shape(opt.init, params_abs)
    opt_G = jax.tree.map(lambda s: SDS((G,) + s.shape, s.dtype), opt_1)
    ospecs_G = _opt_specs(opt_G, pspecs_G, group=dp)
    state_abs = {"params": params_G, "opt": opt_G}
    sspecs = {"params": pspecs_G, "opt": ospecs_G}
    _add_comm_state(exchange, params_G, state_abs, sspecs, dp, G,
                    param_specs=pspecs_G,
                    moments={k: v for k, v in opt_G.items()
                             if k != "count"})
    inner_axis = None
    if policy == "dp":
        inner_axis = "model"
    elif "fsdp" in mesh.axis_names:
        inner_axis = "fsdp"
    batch_abs, bspecs = batch_abstract(cfg, (G, b), shape.seq_len, mesh,
                                       leading_group=True,
                                       inner_axis=inner_axis)
    def _n(tree):
        return sum(int(np.prod(s.shape)) if s.shape else 1
                   for s in jax.tree.leaves(tree))

    # stream-resolved wire accounting mirrors the round's
    # _round_wire_bytes: each moment stream rides its own codec; the step
    # counter is never exchanged
    moment_sizes = ({k: _n(v) for k, v in opt_1.items() if k != "count"}
                    if avg_opt else {})
    n_p = _n(params_abs)
    return BuiltStep(
        round_, (state_abs, batch_abs),
        (_ns(mesh, sspecs), _ns(mesh, bspecs)),
        (_ns(mesh, sspecs), None),
        {"mode": "localsgd", "groups": G, "per_group": b,
         "tokens": shape.global_batch * shape.seq_len * t_inner,
         "t_inner": t_inner, "policy": policy,
         "param_dtype": cfg.param_dtype, "comm": exchange.name,
         "overlap": exchange.overlap,
         "wire_bytes_per_round": exchange.wire_bytes_per_round(
             n_p, moment_sizes=moment_sizes),
         "wire_bytes_up_per_round": exchange.wire_bytes_up(
             n_p, moment_sizes=moment_sizes),
         "wire_bytes_down_per_round": exchange.wire_bytes_down(
             n_p, moment_sizes=moment_sizes),
         "wire_bytes_per_round_by_stream": exchange.wire_bytes_by_stream(
             n_p, moment_sizes),
         "wire_bytes_per_round_by_tier": exchange.wire_bytes_by_tier(
             n_p, moment_sizes),
         "delivery_rate": exchange.delivery_rate,
         "metrics_schema": list(obs.round_metric_keys(
             ("params",) + tuple(moment_sizes)))})


def _packed_impl(impl: str, mesh: Mesh, sexec) -> str:
    """Resolve the fused-kernel/codec impl for a packed mesh step. With a
    sharded plan (sexec, localsgd only — sync never enters shard_map) or
    a single-device mesh any impl is executable: the kernels run on
    shard-local (or whole) buffers. Everywhere else a pallas_call is not
    GSPMD-partitionable — over the G-sharded localsgd buffer it would
    all-gather the state every step, and even over sync's replicated
    buffer it is the exact on-mesh configuration DESIGN.md §6 rules out —
    so an explicit "pallas" raises a clear error (never a silent jnp
    substitution) and "auto" resolves to "jnp"."""
    from repro.kernels import resolve_impl
    if sexec is not None or mesh.devices.size == 1:
        return resolve_impl(impl)
    if impl == "pallas":
        raise NotImplementedError(
            "impl='pallas' on a multi-device mesh only runs inside the "
            "sharded localsgd path (a pallas_call is not "
            "GSPMD-partitionable outside shard_map). Use a mesh with "
            "'model'/'fsdp' > 1 and mode='localsgd' (DESIGN.md §9), a "
            "single-device mesh, or impl='jnp'")
    return "jnp" if impl == "auto" else resolve_impl(impl)


def _build_exchange(comm: str, codec: str, n_groups: int,
                    mix_rounds: int = 1, staleness: int = 1,
                    impl: str = "jnp", moment_codec: str = "fp32",
                    downlink_codec: str = "", drop_rate: float = 0.0,
                    stall_rate: float = 0.0, fault_seed: int = 0,
                    overlap: bool = False, n_pods: int = 0,
                    intra_topology: str = "ring",
                    inter_topology: str = "push_sum",
                    inter_codec: str = "",
                    intra_drop_rate: float = 0.0,
                    intra_stall_rate: float = 0.0):
    """Exchange for a mesh step builder; ``impl`` selects the codec
    kernels and must already be resolved for the execution path
    (``_packed_impl`` — shard_map runs the Pallas quantize kernels on
    shard-local rows; the replicated fallback keeps the jnp reference).
    ``moment_codec`` applies to every moment stream (DESIGN.md §10);
    drop_rate/stall_rate/fault_seed arm the deterministic FaultPlan
    (DESIGN.md §12 — zero rates keep the exchange bit-exact fault-free).
    Returns (exchange, average_opt_state) — True on every topology since
    the per-stream staleness buffers landed."""
    exchange = comm_mod.get_exchange(comm, codec, n_groups, impl=impl,
                                     mix_rounds=mix_rounds,
                                     staleness=staleness,
                                     moment_codec=moment_codec,
                                     downlink_codec=downlink_codec,
                                     drop_rate=drop_rate,
                                     stall_rate=stall_rate,
                                     fault_seed=fault_seed,
                                     overlap=overlap, n_pods=n_pods,
                                     intra_topology=intra_topology,
                                     inter_topology=inter_topology,
                                     inter_codec=inter_codec,
                                     intra_drop_rate=intra_drop_rate,
                                     intra_stall_rate=intra_stall_rate)
    return exchange, exchange.supports_opt_state_averaging


def _add_comm_state(exchange, params_G, state_abs, sspecs, dp, G,
                    param_specs, moments=None):
    """Thread stateful-exchange memory (per-stream codec residuals,
    staleness buffers, counters) into the abstract state + shardings.
    The ``pushed`` staleness buffer and every ``pushed_opt`` stream
    mirror the params' geometry, so they take the params' OWN specs
    (keeping TP/fsdp sharding — a lead-only spec would replicate the
    whole per-group model and reshard every round); other G-leading
    leaves shard on the group axis, scalars replicate."""
    if not exchange.stateful:
        return
    comm_abs = jax.eval_shape(
        lambda p, m: exchange.init(p, moments=m), params_G, moments)
    lead = P(dp) if dp else P()

    def spec(s):
        if s.ndim >= 1 and s.shape[0] == G:
            return P(*(tuple(lead) + (None,) * (s.ndim - 1)))
        return P(*((None,) * s.ndim))

    def _lead_offset(spec_tree):
        # per-edge backlog buffers stack the stream's geometry under a
        # small leading offset axis (len(push_sum_offsets),) — replicate
        # that axis, keep the stream's own sharding behind it
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def for_key(k, v):
        if k == "pushed":
            return param_specs
        if k == "pushed_opt":
            return {name: param_specs for name in v}
        if k == "inflight":
            # the double-buffered in-flight payload mirrors each stream's
            # geometry exactly (DESIGN.md §14) — params' own specs, same
            # rule as the staleness buffers
            return {name: param_specs for name in v}
        if k == "backlog":
            return {name: _lead_offset(param_specs) for name in v}
        if k == "backlog_w":
            return P(*((None,) + tuple(lead)))
        if k == "codec":
            # per-stream codec state: error-feedback residuals mirror the
            # stream's geometry and must shard like the params (the
            # shard_map exchange declares them at buf_spec — a lead-only
            # spec would reshard the O(Np) residual every round);
            # counters keep the generic rule
            return {name: {kk: (param_specs if kk == "residual"
                                else jax.tree.map(spec, vv))
                           for kk, vv in sub.items()}
                    for name, sub in v.items()}
        if k == "down":
            # each stream's broadcast reference mirrors the params'
            # geometry (DESIGN.md §11) — same rule as the staleness
            # buffers; the codec state (counters) follows the generic rule
            return {name: {"ref": param_specs,
                           "state": jax.tree.map(spec, sub["state"])}
                    for name, sub in v.items()}
        return jax.tree.map(spec, v)

    cspecs = {k: for_key(k, v) for k, v in comm_abs.items()}
    state_abs["comm"] = comm_abs
    sspecs["comm"] = cspecs


def _build_packed_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                             model, opt_name: str, lr: float, mode: str,
                             t_inner: int, comm: str = "server",
                             codec: str = "fp32", mix_rounds: int = 1,
                             staleness: int = 1,
                             impl: str = "auto",
                             moment_codec: str = "fp32",
                             downlink_codec: str = "",
                             drop_rate: float = 0.0,
                             stall_rate: float = 0.0,
                             fault_seed: int = 0,
                             overlap: bool = False, n_pods: int = 0,
                             intra_topology: str = "ring",
                             inter_topology: str = "push_sum",
                             inter_codec: str = "",
                             intra_drop_rate: float = 0.0,
                             intra_stall_rate: float = 0.0) -> BuiltStep:
    """Flat-buffer train step (DESIGN.md §6/§9): one (G, Np) f32 buffer
    per state part, donated so XLA updates the model in place across the
    T-step round. When the mesh has an in-group axis ("model"/"fsdp" > 1)
    the buffer shards over it via a chunk-aligned ShardedLayout and the
    fused-update + codec kernels run inside shard_map on the local shards
    (shardexec); otherwise the buffer is replicated within a group and the
    update stays one GSPMD-partitioned XLA fusion (impl='pallas' refuses
    there — see _packed_impl)."""
    sexec = shx.plan_for(mesh) if mode != "sync" else None
    impl = _packed_impl(impl, mesh, sexec)
    opt = optim.get(opt_name, lr, packed=True, impl=impl)
    layout = packing.layout_of(model.abstract())
    if sexec is not None:
        layout = packing.shard_layout(layout, sexec.n_shards)

    if mode == "sync":
        # sync-DP keeps the single replicated (N,) buffer: there is no
        # G axis to pair the shard_map exchange with, and the per-step
        # gradient all-reduce dominates anyway
        step = lsgd.make_sync_step(model.loss, opt, layout=layout)
        B = shape.global_batch
        batch_abs, bspecs = batch_abstract(cfg, (B,), shape.seq_len, mesh,
                                           leading_group=False)
        buf = layout.abstract()
        opt_abs = jax.eval_shape(opt.init, buf)
        state_abs = {"params": buf, "opt": opt_abs}
        sspecs = {"params": P(), "opt": {k: P() for k in opt_abs}}
        return BuiltStep(
            step, (state_abs, batch_abs),
            (_ns(mesh, sspecs), _ns(mesh, bspecs)),
            (_ns(mesh, sspecs), None),
            {"mode": "sync", "tokens": B * shape.seq_len, "t_inner": 1,
             "packed": True, "n_flat": layout.size, "impl": impl},
            donate_argnums=(0,))

    G = sh.n_groups(mesh)
    assert shape.global_batch % G == 0, (shape.global_batch, G)
    b = shape.global_batch // G
    exchange, avg_opt = _build_exchange(comm, codec, G, mix_rounds,
                                        staleness, impl=impl,
                                        moment_codec=moment_codec,
                                        downlink_codec=downlink_codec,
                                        drop_rate=drop_rate,
                                        stall_rate=stall_rate,
                                        fault_seed=fault_seed,
                                        overlap=overlap, n_pods=n_pods,
                                        intra_topology=intra_topology,
                                        inter_topology=inter_topology,
                                        inter_codec=inter_codec,
                                        intra_drop_rate=intra_drop_rate,
                                        intra_stall_rate=intra_stall_rate)
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t_inner,
                               inner_mode="fixed_batch",
                               average_opt_state=avg_opt)
    round_ = lsgd.make_local_round(model.loss, opt, lcfg, layout=layout,
                                   exchange=exchange, shardexec=sexec)
    dp = sh.dp_axes(mesh)
    buf_G = layout.abstract((G,))
    opt_abs = jax.eval_shape(opt.init, buf_G)
    state_abs = {"params": buf_G, "opt": opt_abs}
    lead = P(dp) if dp else P()
    buf_spec = sexec.buf_spec() if sexec is not None else lead
    sspecs = {"params": buf_spec,
              "opt": {k: (P() if k == "count" else buf_spec)
                      for k in opt_abs}}
    _add_comm_state(exchange, buf_G, state_abs, sspecs, dp, G,
                    param_specs=buf_spec,
                    moments={k: v for k, v in opt_abs.items()
                             if k != "count"})
    batch_abs, bspecs = batch_abstract(cfg, (G, b), shape.seq_len, mesh,
                                       leading_group=True)
    n_wire = layout.padded       # the buffer IS the wire format, pad incl.
    slayout = packing.stream_layout_for(opt, layout)
    moment_sizes = ({k: n_wire for k in slayout.moment_streams}
                    if avg_opt else {})
    return BuiltStep(
        round_, (state_abs, batch_abs),
        (_ns(mesh, sspecs), _ns(mesh, bspecs)),
        (_ns(mesh, sspecs), None),
        {"mode": "localsgd", "groups": G, "per_group": b,
         "tokens": shape.global_batch * shape.seq_len * t_inner,
         "t_inner": t_inner, "policy": "packed", "packed": True,
         "n_flat": layout.size, "n_flat_padded": layout.padded,
         "sharded": sexec is not None,
         "n_shards": sexec.n_shards if sexec is not None else 1,
         "impl": impl, "param_dtype": cfg.param_dtype,
         "comm": exchange.name, "overlap": exchange.overlap,
         "streams": list(slayout.streams),
         # packed rounds exchange every moment stream through its own
         # codec but never the shared step counter (mirrors
         # _round_wire_bytes); totals == sums of the per-stream splits
         "wire_bytes_per_round": exchange.wire_bytes_per_round(
             n_wire, moment_sizes=moment_sizes),
         "wire_bytes_up_per_round": exchange.wire_bytes_up(
             n_wire, moment_sizes=moment_sizes),
         "wire_bytes_down_per_round": exchange.wire_bytes_down(
             n_wire, moment_sizes=moment_sizes),
         "wire_bytes_per_round_by_stream": exchange.wire_bytes_by_stream(
             n_wire, moment_sizes),
         "wire_bytes_per_round_by_tier": exchange.wire_bytes_by_tier(
             n_wire, moment_sizes),
         "delivery_rate": exchange.delivery_rate,
         "metrics_schema": list(obs.round_metric_keys(
             ("params",) + tuple(moment_sizes)))},
        donate_argnums=(0,))


def _fsdp_model(cfg, mesh: Mesh, model, schedule: str, act_axes):
    """Rebuild the model with the fsdp hooks (see DESIGN.md §5b):
    params rest fsdp-sharded; a with_sharding_constraint in the scan
    body gathers ONE layer's weights at a time (its transpose
    reduce-scatters the grads), and a second constraint pins activations
    to batch-over-act_axes — without them XLA's propagation re-shards
    seq-length activations instead."""
    from repro.models.layers import is_pdef

    blocks = model.defs.get("blocks")
    if blocks is None:
        return model
    per_layer = jax.tree.map(
        lambda d: dataclasses.replace(d, shape=d.shape[1:],
                                      axes=d.axes[1:]),
        blocks, is_leaf=is_pdef)
    gspecs = jax.tree.map(
        lambda s: P(*[None if e == "fsdp" else e for e in tuple(s)]),
        sh.resolve_specs(per_layer, mesh),
        is_leaf=lambda x: isinstance(x, P))

    def hook(p, _gs=gspecs):
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), p, _gs)

    ax = act_axes[0] if len(act_axes) == 1 else tuple(act_axes)

    def act_hook(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(ax, None, None)))

    return build_model(cfg, schedule=schedule, layer_param_hook=hook,
                       layer_act_hook=act_hook)


def _drop_fsdp_outside_blocks(pspecs):
    """Embed / lm_head / final_norm keep vocab->model sharding only:
    fsdp-sharding their d_model axis is the matmul contraction dim of the
    LM head, which would force all-gathers of (B,S,D) activations."""
    if not isinstance(pspecs, dict):
        return pspecs
    out = {}
    for k, v in pspecs.items():
        if k == "blocks":
            out[k] = v
        else:
            out[k] = jax.tree.map(
                lambda s: P(*[None if e == "fsdp" else e
                              for e in tuple(s)]),
                v, is_leaf=lambda x: isinstance(x, P))
    return out


def _opt_specs(opt_abs, pspecs, group):
    out = {}
    for k in opt_abs:
        if k == "count":
            out[k] = P(group) if group else P()
        else:
            out[k] = pspecs
    return out


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                       schedule: str = "rect", policy: str = "tp"
                       ) -> BuiltStep:
    """policy="dp": replicate params and shard the batch over every mesh
    axis — removes the TP activation all-reduces that dominate small
    archs (xlstm/zamba prefill, §Perf)."""
    model = build_model(cfg, schedule=schedule)
    if "fsdp" in mesh.axis_names and policy == "tp":
        # serving has no local-SGD groups: the whole batch shards over
        # (data, fsdp); layer hooks gather weights layer-by-layer
        model = _fsdp_model(cfg, mesh, model, schedule,
                            act_axes=sh.serve_batch_axes(mesh))
    if cfg.param_dtype != "float32":
        from repro.models.layers import is_pdef
        model.defs = jax.tree.map(
            lambda d: dataclasses.replace(d, dtype=cfg.param_dtype),
            model.defs, is_leaf=is_pdef)
    pspecs = _drop_fsdp_outside_blocks(
        sh.resolve_specs(model.defs, mesh, policy=policy))
    params_abs = model.abstract()
    B = shape.global_batch
    batch_abs, bspecs = batch_abstract(cfg, (B,), shape.seq_len, mesh,
                                       leading_group=False)
    if policy == "dp":
        # batch over ALL axes (serve axes + model)
        axes = tuple(a for a in mesh.axis_names if mesh.shape[a] > 1)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if total > 1 and B % total == 0:
            bspecs = jax.tree.map(
                lambda s: P(*((axes,) + tuple(s)[1:])), bspecs,
                is_leaf=lambda x: isinstance(x, P))

    def prefill(params, batch):
        x, _ = model.forward(params, batch)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        last = x[:, -1:]
        return jnp.einsum("bsd,dv->bsv", last,
                          head.astype(last.dtype)).astype(jnp.float32)

    return BuiltStep(
        prefill, (params_abs, batch_abs),
        (_ns(mesh, pspecs), _ns(mesh, bspecs)), None,
        {"mode": "prefill", "tokens": B * shape.seq_len})


def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh
                      ) -> BuiltStep:
    """One-token serve step with a cache sized for the shape.

    long_500k: attention-bearing archs use the sliding-window variant
    (cache_len = cfg.long_context_window); SSM state is O(1) regardless.
    """
    model = build_model(cfg)
    if cfg.param_dtype != "float32":
        from repro.models.layers import is_pdef
        model.defs = jax.tree.map(
            lambda d: dataclasses.replace(d, dtype=cfg.param_dtype),
            model.defs, is_leaf=is_pdef)
    B = shape.global_batch
    if shape.name == "long_500k":
        cache_len = min(cfg.long_context_window, shape.seq_len)
    else:
        cache_len = shape.seq_len
    pspecs = _drop_fsdp_outside_blocks(sh.resolve_specs(model.defs, mesh))
    params_abs = model.abstract()
    cache_abs = model.init_cache(B, cache_len, abstract=True)
    cspecs = cache_specs(cfg, cache_abs, mesh, B)
    tok = SDS((B, 1), jnp.int32)
    tok_spec = sh.batch_spec(mesh, B, False)
    tspec = P(*(tuple(tok_spec) + (None,)))
    pos = SDS((), jnp.int32)

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return BuiltStep(
        decode, (params_abs, cache_abs, tok, pos),
        (_ns(mesh, pspecs), _ns(mesh, cspecs), NamedSharding(mesh, tspec),
         NamedSharding(mesh, P())),
        None,
        {"mode": "decode", "cache_len": cache_len, "tokens": B})


def build_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, **kw
               ) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh,
                                  schedule=kw.get("schedule", "rect"),
                                  policy=kw.get("policy", "tp"))
    return build_decode_step(cfg, shape, mesh)
