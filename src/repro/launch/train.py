"""Training launcher: the paper's local-SGD schedule (or the sync-DP
baseline) on any assigned architecture.

On this CPU container run reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
      --rounds 10 --t-inner 4
On a TPU pod the same entry point runs the full config on the production
mesh (--mesh pod).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm as comm_mod
from repro import obs
from repro import optim
from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.core.controller import AdaptiveT, OnlineT
from repro.data.synthetic import TokenPipeline
from repro.models import build_model
from repro.optim import packing


def add_modalities(batch, cfg, rng):
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(rng.randn(
            *batch["tokens"].shape[:-1], cfg.n_patches, cfg.d_model)
            .astype(np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.randn(
            *batch["tokens"].shape[:-1], cfg.n_frames, cfg.d_model)
            .astype(np.float32))
    return batch


def calibrate_fences(loss_fn, opt, lcfg, layout, exchange, sexec, params,
                     batch, n_groups):
    """Measure the two references ``obs.exchange_phases`` derives the
    honest exchange-time split from (DESIGN.md §14): the SAME round
    built with comm='none' gives the pure-local-compute time, and (in
    overlap mode) the barrier variant of the same exchange gives the
    standalone exchange cost — both fenced, best of two runs after a
    warmup. Returns ``(local_ref_per_step_s, exch_ref_s)``; the local
    reference scales linearly in T when the controller later changes it,
    so one calibration covers the whole run."""

    def best_round_s(exch):
        rnd = jax.jit(lsgd.make_local_round(loss_fn, opt, lcfg,
                                            layout=layout, exchange=exch,
                                            shardexec=sexec))
        st = lsgd.init_state(params, opt, n_groups=n_groups,
                             layout=layout, exchange=exch)
        st, m = rnd(st, batch)
        jax.block_until_ready(m)
        best = float("inf")
        for _ in range(2):
            with obs.PhaseTimer() as t:
                st, m = t(rnd(st, batch))
            best = min(best, t.seconds)
        return best

    local_ref_s = best_round_s(comm_mod.get_exchange("none", "fp32",
                                                     n_groups))
    exch_ref_s = 0.0
    if exchange.overlap:
        import dataclasses
        barrier = dataclasses.replace(exchange, overlap=False)
        exch_ref_s = max(0.0, best_round_s(barrier) - local_ref_s)
    return local_ref_s / max(lcfg.inner_steps, 1), exch_ref_s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lenet")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="localsgd",
                    choices=["localsgd", "sync"])
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--per-group", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--t-inner", type=int, default=4)
    ap.add_argument("--t-i", default="",
                    help="comma-separated per-node T_i (paper Alg 1), "
                         "e.g. --t-i 1,4,8,16; max becomes the scan bound")
    ap.add_argument("--threshold", type=float, default=None,
                    help="T_i=inf mode: local steps until ||g||^2<=eps")
    ap.add_argument("--adaptive-t", nargs="?", const="static", default="",
                    choices=["static", "online"],
                    help="T controller: 'static' (bare --adaptive-t, the "
                         "Sec-4 fit from the decay trajectory alone) or "
                         "'online' (DESIGN.md §14: re-estimates the cost "
                         "ratio from fenced phase times and scales T by "
                         "the measured consensus contraction each round)")
    ap.add_argument("--cost-ratio", type=float, default=0.01,
                    help="r = C_g/C_c for the adaptive controller "
                         "(online mode uses it as the prior and refines "
                         "it from measured phase times)")
    ap.add_argument("--opt", default="sgd")
    ap.add_argument("--packed", action="store_true",
                    help="flat-buffer fast path: fused whole-model updates"
                         " on one (G, N) f32 buffer (see DESIGN.md)")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="packed update/codec kernels: fused Pallas "
                         "kernels or the jnp fusion (DESIGN.md §6/§9)")
    ap.add_argument("--shard", type=int, default=1,
                    help="in-group shard count S (packed localsgd only): "
                         "shards the flat buffer over a (G, S) device "
                         "mesh and runs the fused/codec kernels in "
                         "shard_map blocks on the local shards "
                         "(DESIGN.md §9; needs G*S devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--comm", "--topology", dest="comm", default="server",
                    choices=["server", "ring", "gossip", "async_stale",
                             "push_sum", "hierarchical", "none"],
                    help="exchange topology (repro.comm, DESIGN.md §8; "
                         "push_sum is loss-tolerant ratio consensus, "
                         "DESIGN.md §12; hierarchical is the two-tier "
                         "pod/DCN factoring, DESIGN.md §16)")
    ap.add_argument("--n-pods", type=int, default=0,
                    help="hierarchical only: pod count P; must divide "
                         "--groups (pods of G/P nodes, DESIGN.md §16)")
    ap.add_argument("--intra-topology", default="ring",
                    choices=["ring", "server"],
                    help="hierarchical within-pod stage (reliable "
                         "interconnect tier)")
    ap.add_argument("--inter-topology", default="push_sum",
                    choices=["push_sum", "server"],
                    help="hierarchical cross-pod stage: push_sum ratio "
                         "consensus over the lossy DCN, or the reliable "
                         "parameter-server baseline")
    ap.add_argument("--inter-codec", default="",
                    choices=["", "fp32", "fp16", "bf16", "int8", "int8z"],
                    help="independent wire codec for the cross-pod tier "
                         "(DESIGN.md §16); default: same as --codec. "
                         "int8/int8z need --inter-topology server")
    ap.add_argument("--intra-drop-rate", type=float, default=0.0,
                    help="hierarchical: per-edge drop probability on the "
                         "within-pod tier (its own seed lane; --drop-rate "
                         "arms the cross-pod tier)")
    ap.add_argument("--intra-stall-rate", type=float, default=0.0,
                    help="hierarchical: per-round node stall probability "
                         "on the within-pod tier")
    ap.add_argument("--codec", default="fp32",
                    choices=["fp32", "fp16", "bf16", "int8", "int8z",
                             "topk"],
                    help="wire codec for the model exchange; int8/int8z/"
                         "topk need --packed (the flat buffer is the "
                         "wire format)")
    ap.add_argument("--moment-codec", default="fp32",
                    choices=["fp32", "fp16", "bf16", "int8", "int8z"],
                    help="wire codec for the optimizer moment streams "
                         "(DESIGN.md §10); int8/int8z need --packed, "
                         "topk is refused for moments; int8z is the "
                         "zero-preserving moment-friendly variant "
                         "(DESIGN.md §10/§14)")
    ap.add_argument("--downlink-codec", default="",
                    choices=["", "fp32", "fp16", "bf16", "int8", "int8z"],
                    help="compress the server/async broadcast reply "
                         "independently of the uplink codec (DESIGN.md "
                         "§11); default: idealized broadcast priced at "
                         "uplink widths (the pre-§11 behavior, bit-exact)")
    ap.add_argument("--hop-impl", default="ppermute",
                    choices=["ppermute", "allgather"],
                    help="sharded ring/gossip hop collective (DESIGN.md "
                         "§11): ppermute neighbor exchange (O(deg*shard) "
                         "wire) or the dense all_gather reference")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered delayed mixing (DESIGN.md "
                         "§14): the previous round's payload mixes while "
                         "this round's local steps run; needs --packed "
                         "and a server/ring/gossip topology")
    ap.add_argument("--mix-rounds", type=int, default=1,
                    help="mixing hops per round (ring/gossip)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="bounded staleness s (async_stale)")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="deterministic fault injection (DESIGN.md §12): "
                         "per-edge packet-drop probability in [0, 1); "
                         "0 keeps the exchange bit-exact fault-free")
    ap.add_argument("--stall-rate", type=float, default=0.0,
                    help="per-round node stall probability in [0, 1) "
                         "(a stalled node skips the exchange entirely)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the FaultPlan mask stream — faults are "
                         "a pure function of (round, seed), so reruns and "
                         "checkpoint resumes replay the same faults")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--trace", default="",
                    help="append phase-fenced JSONL round records here "
                         "(DESIGN.md §13); summarize/validate with "
                         "PYTHONPATH=src python -m repro.obs.report")
    ap.add_argument("--profile", default="",
                    help="dump a perfetto trace of the run under this "
                         "directory (jax.profiler.start_trace)")
    args = ap.parse_args()
    if args.mode == "sync" and (args.comm != "server"
                                or args.codec != "fp32"
                                or args.moment_codec != "fp32"
                                or args.downlink_codec or args.overlap
                                or args.drop_rate or args.stall_rate
                                or args.n_pods or args.inter_codec
                                or args.intra_drop_rate
                                or args.intra_stall_rate):
        ap.error("--comm/--codec/--drop-rate select the local-SGD model "
                 "exchange; sync-DP all-reduces gradients every step and "
                 "has no exchange to configure")
    if args.impl != "auto" and not args.packed:
        ap.error("--impl selects the packed fused kernels; add --packed")
    if args.shard > 1 and not (args.packed and args.mode == "localsgd"):
        ap.error("--shard shards the packed flat buffer over a mesh; it "
                 "needs --packed and --mode localsgd")
    if args.overlap and not args.packed:
        ap.error("--overlap double-buffers the packed flat stream payload "
                 "(comm['inflight'], DESIGN.md §14); add --packed")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, schedule="rect")
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mode={args.mode}")

    # one Trace regardless of --trace: the null sink still fences every
    # phase with block_until_ready, so printed timings are honest even
    # when nothing is written (DESIGN.md §13)
    trace = obs.Trace(args.trace or None, meta={
        "arch": cfg.name, "mode": args.mode, "groups": args.groups,
        "t_inner": args.t_inner, "comm": args.comm, "codec": args.codec,
        "rounds": args.rounds, "n_params": n_params,
        "packed": bool(args.packed), "shard": args.shard,
        "overlap": bool(args.overlap), "adaptive_t": args.adaptive_t,
        "drop_rate": args.drop_rate, "stall_rate": args.stall_rate})

    layout = packing.layout_of(params) if args.packed else None
    G = args.groups
    mesh, sexec = None, None
    if args.shard > 1:
        from jax.sharding import Mesh
        from repro.sharding import shardexec as shx

        n_dev = G * args.shard
        devices = jax.devices()
        if len(devices) < n_dev:
            raise SystemExit(
                f"--shard {args.shard} with --groups {G} needs {n_dev} "
                f"devices, found {len(devices)}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_dev}")
        mesh = Mesh(np.array(devices[:n_dev]).reshape(G, args.shard),
                    ("data", "model"))
        sexec = shx.plan_for(mesh, require=True, hop_impl=args.hop_impl)
        layout = packing.shard_layout(layout, sexec.n_shards)
        print(f"sharded execution: G={G} x {args.shard} shards, "
              f"buffer {layout.size} -> {layout.padded} padded "
              f"({layout.shard_size}/shard)")
    opt = optim.get(args.opt, args.lr, packed=args.packed,
                    **({"impl": args.impl} if args.packed else {}))
    pipe = TokenPipeline(cfg.vocab_size, args.seq, seed=args.seed)
    rng = np.random.RandomState(args.seed)

    if args.mode == "sync":
        step = jax.jit(lsgd.make_sync_step(model.loss, opt, layout=layout),
                       donate_argnums=(0,))
        state = lsgd.init_state(params, opt, layout=layout)
        batches = pipe.batches((G * args.per_group,))
        with obs.profile_span(args.profile):
            for n in range(args.rounds):
                with trace.phase("data"):
                    batch = add_modalities(
                        {"tokens": jnp.asarray(next(batches)["tokens"])},
                        cfg, rng)
                with trace.phase("step") as f:
                    state, m = f(step(state, batch))
                rec = trace.emit_round(n, m, kind="step")
                if n % args.log_every == 0:
                    print(f"step {n:4d} loss {float(m['loss']):.4f} "
                          f"gsq {float(m['grad_sq']):.3e} "
                          f"({rec['phase_s'].get('step', 0.0):.2f}s)")
        final = (packing.unpack(state["params"], layout)
                 if args.packed else state["params"])
    else:
        t_i = None
        t_inner = args.t_inner
        if args.t_i:
            t_i = tuple(int(v) for v in args.t_i.split(","))
            assert len(t_i) == G, (t_i, G)
            t_inner = max(t_i)
        # the packed hot path skips per-step metric trajectories unless
        # the adaptive-T controller needs them
        metrics = "traj" if args.adaptive_t else "final"
        exchange = comm_mod.get_exchange(
            args.comm, args.codec, G, mix_rounds=args.mix_rounds,
            staleness=args.staleness,
            impl=args.impl if args.packed else "auto",
            moment_codec=args.moment_codec,
            downlink_codec=args.downlink_codec,
            drop_rate=args.drop_rate, stall_rate=args.stall_rate,
            fault_seed=args.fault_seed, overlap=args.overlap,
            n_pods=args.n_pods, intra_topology=args.intra_topology,
            inter_topology=args.inter_topology,
            inter_codec=args.inter_codec,
            intra_drop_rate=args.intra_drop_rate,
            intra_stall_rate=args.intra_stall_rate)
        # every topology averages opt state now that the per-stream
        # staleness buffers exist (DESIGN.md §10)
        avg_opt = exchange.supports_opt_state_averaging
        lcfg = lsgd.LocalSGDConfig(
            n_groups=G, inner_steps=t_inner, t_i=t_i,
            threshold=args.threshold, max_inner=500, metrics=metrics,
            average_opt_state=avg_opt)
        rnd = jax.jit(lsgd.make_local_round(model.loss, opt, lcfg,
                                            layout=layout,
                                            exchange=exchange,
                                            shardexec=sexec),
                      donate_argnums=(0,))
        state = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                                exchange=exchange,
                                average_opt_state=avg_opt)
        if sexec is not None:
            # place the buffers on the mesh once; donation keeps every
            # subsequent round's state resident in place
            from jax.sharding import NamedSharding, PartitionSpec as P

            buf_sh = NamedSharding(mesh, sexec.buf_spec())
            rep_sh = NamedSharding(mesh, P())
            state = jax.tree.map(
                lambda x: jax.device_put(
                    x, buf_sh if (x.ndim == 2
                                  and x.shape[-1] == layout.padded)
                    else rep_sh), state)
        batches = pipe.batches((G, args.per_group))
        # on a lossy network each useful round costs a full attempt's
        # worth of link time (AdaptiveT.from_exchange's delivery_rate
        # repricing): comm is 1/delivery more expensive, so r shrinks
        # and the controller pushes T* up — fewer, longer rounds
        ctl = None
        if args.adaptive_t == "online":
            # DESIGN.md §14: the prior r is refined online from the
            # calibrated fences; the delivery repricing still applies
            ctl = OnlineT(r=args.cost_ratio * exchange.delivery_rate)
        elif args.adaptive_t:
            ctl = AdaptiveT(r=args.cost_ratio * exchange.delivery_rate)
        t_cur = args.t_inner
        wire_total = 0
        # the exchange-time split needs the packed path's uniform round
        # shape to calibrate against; pytree rounds skip it (the
        # report's phase gate is conditional on the keys being present)
        calibrate = args.packed and (args.overlap or bool(args.trace)
                                     or args.adaptive_t == "online")
        local_ref_step = exch_ref_s = 0.0
        trace.meta.update({"comm": exchange.name,
                           "delivery_rate": exchange.delivery_rate})
        with obs.profile_span(args.profile):
            for n in range(args.rounds):
                with trace.phase("data"):
                    batch = add_modalities(
                        {"tokens": jnp.asarray(next(batches)["tokens"])},
                        cfg, rng)
                if calibrate and n == 0:
                    local_ref_step, exch_ref_s = calibrate_fences(
                        model.loss, opt, lcfg, layout, exchange, sexec,
                        params, batch, G)
                if ctl is not None and t_cur != lcfg.inner_steps:
                    lcfg = lsgd.LocalSGDConfig(
                        n_groups=G, inner_steps=t_cur, max_inner=500,
                        metrics=metrics, average_opt_state=avg_opt)
                    rnd = jax.jit(lsgd.make_local_round(
                        model.loss, opt, lcfg, layout=layout,
                        exchange=exchange, shardexec=sexec),
                        donate_argnums=(0,))
                with trace.phase("round") as f:
                    state, m = f(rnd(state, batch))
                t_used = int(jnp.max(m["inner_steps"]))
                fences = None
                if calibrate:
                    fences = obs.exchange_phases(
                        trace.phase_seconds("round"),
                        local_ref_step * t_used, exch_ref_s,
                        overlap=args.overlap)
                    for k, v in fences.items():
                        trace.add_phase(k, v)
                if ctl is not None and "grad_sq_traj" in m:
                    traj = np.asarray(m["grad_sq_traj"])[0]
                    if isinstance(ctl, OnlineT):
                        cerr = sum(float(jnp.mean(v))
                                   for k, v in m.items()
                                   if k.startswith("codec_err/"))
                        t_cur = ctl.update(
                            traj, t_used=t_used,
                            local_s=(local_ref_step * t_used) or None,
                            exchange_s=(fences or {}).get(
                                "exchange_total") or None,
                            consensus_pre=float(
                                jnp.mean(m["consensus_sq"])),
                            consensus_post=float(
                                jnp.mean(m["consensus_sq_post"])),
                            codec_err=cerr)
                    else:
                        t_cur = ctl.update(traj)
                rec = trace.emit_round(n, m)
                wire_total += int(m["wire_bytes"])
                if n % args.log_every == 0:
                    print(f"round {n:4d} "
                          f"loss {float(jnp.mean(m['loss'])):.4f} "
                          f"gsq {float(jnp.mean(m['grad_sq'])):.3e} "
                          f"T {int(jnp.max(m['inner_steps']))} "
                          f"wire {int(m['wire_bytes']):,}B "
                          f"part {float(m['participation']):.2f} "
                          f"cons {float(jnp.mean(m['consensus_sq'])):.3e} "
                          f"({rec['phase_s'].get('round', 0.0):.2f}s)")
        print(f"comm {exchange.name}: {wire_total:,} wire bytes over "
              f"{args.rounds} rounds")
        final = lsgd.server_params(state, layout=layout)

    if args.checkpoint:
        with trace.phase("checkpoint"):
            ckpt_io.save(args.checkpoint, final,
                         metadata={"arch": cfg.name, "rounds": args.rounds,
                                   "mode": args.mode})
        trace.emit("checkpoint", path=args.checkpoint,
                   seconds=round(trace.take_phases()["checkpoint"], 6))
        print(f"checkpoint -> {args.checkpoint}.npz")
    trace.close()
    if args.trace:
        print(f"trace -> {args.trace} ({trace.n_records} records)")


if __name__ == "__main__":
    main()
