"""Serving launcher: the continuous-batching engine (repro.serve) over a
request workload, with checkpoint->serve handoff.

On this CPU container run reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
      --requests 8 --rate 4 --gen 8
Restore trained weights from a ``launch/train.py --checkpoint`` file
(pytree or packed flat-buffer format):
  ... --from-checkpoint experiments/ckpt/qwen3
All timings are phase-fenced (obs.Trace): prefill / decode_step phases
block_until_ready before reading the clock, and ``--trace`` writes the
per-step JSONL that ``python -m repro.obs.report <file> --check``
validates. ``--check-parity`` replays every request through an isolated
single-slot engine and asserts identical tokens (the CI serve smoke).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model
from repro.obs.trace import Trace
from repro.serve import (Engine, EngineConfig, Request, drive_workload,
                         poisson_workload, restore_params)


def build_engine(model, params, args, policy: str,
                 trace=None) -> Engine:
    return Engine(model, params, EngineConfig(
        n_slots=args.slots, page_size=args.page_size,
        max_prompt=args.prompt_max, max_new=args.gen_max,
        impl=args.impl, policy=policy), trace=trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate (req/s, virtual clock)")
    ap.add_argument("--prompt-min", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8,
                    help="min generated tokens per request")
    ap.add_argument("--gen-max", type=int, default=16)
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="decode-attention impl")
    ap.add_argument("--from-checkpoint", default="",
                    help="restore params saved by launch/train.py "
                         "(pytree or packed)")
    ap.add_argument("--trace", default="", help="JSONL trace sink")
    ap.add_argument("--check-parity", action="store_true",
                    help="replay each request isolated; assert identical "
                         "tokens")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    if args.from_checkpoint:
        params = restore_params(args.from_checkpoint, model)
        print(f"params <- {args.from_checkpoint}.npz")
    else:
        params = model.init(jax.random.PRNGKey(args.seed))

    trace = Trace(args.trace or None,
                  meta={"launcher": "serve", "arch": cfg.name,
                        "engine": args.engine, "slots": args.slots,
                        "page_size": args.page_size})
    engine = build_engine(model, params, args, args.engine, trace)
    engine.warmup()

    gen = (min(args.gen, args.gen_max), args.gen_max)
    reqs = poisson_workload(args.rate, args.requests, seed=args.seed,
                            prompt_len=(args.prompt_min, args.prompt_max),
                            max_new=gen, vocab=cfg.vocab_size)
    done, makespan = drive_workload(
        engine, [Request(r.rid, r.prompt.copy(), r.max_new, r.arrival)
                 for r in reqs])
    trace.close()

    lat = np.sort([c.latency for c in done])
    committed = sum(len(c.tokens) for c in done)
    print(f"arch={cfg.name} engine={args.engine} slots={args.slots} "
          f"page={args.page_size} impl={args.impl}")
    print(f"{len(done)} requests, {committed} tokens committed in "
          f"{makespan:.2f}s virtual ({committed / max(makespan, 1e-9):.1f}"
          " tok/s)")
    print(f"latency p50 {np.percentile(lat, 50):.3f}s "
          f"p99 {np.percentile(lat, 99):.3f}s")
    if args.trace:
        print(f"trace -> {args.trace} ({trace.n_records} records)")

    if args.check_parity:
        iso = Engine(model, params, EngineConfig(
            n_slots=1, page_size=args.page_size, max_prompt=args.prompt_max,
            max_new=args.gen_max, impl=args.impl))
        got = {c.rid: c.tokens for c in done}
        bad = 0
        for r in reqs:
            ref = iso.run([Request(r.rid, r.prompt.copy(), r.max_new)])
            if got[r.rid] != ref[0].tokens:
                bad += 1
                print(f"PARITY FAIL rid={r.rid}: engine {got[r.rid]} "
                      f"!= isolated {ref[0].tokens}")
        if bad:
            raise SystemExit(f"parity check failed for {bad} request(s)")
        print(f"parity OK: {len(reqs)} requests identical to isolated "
              "decode")


if __name__ == "__main__":
    main()
