"""Serving launcher: batched greedy decoding against the KV/state cache.

On this CPU container run reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
      --batch 4 --prompt-len 16 --gen 16
The same decode_step is what the decode_32k / long_500k dry-run shapes
lower on the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="ring-buffer length (0: prompt+gen)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B = args.batch
    W = args.cache_len or (args.prompt_len + args.gen)
    cache = model.init_cache(B, W)
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                      (B, args.prompt_len), dtype=np.int32))

    step = jax.jit(model.decode_step)
    # ---- prefill ----------------------------------------------------------
    # dense/moe families: ONE batched forward fills the cache; recurrent
    # families (ssm/hybrid) step their O(1) state token-by-token.
    t0 = time.time()
    if hasattr(model, "prefill"):
        pf = jax.jit(model.prefill, static_argnames=("cache_len",))
        logits, cache = pf(params, {"tokens": prompts}, cache_len=W)
    else:
        logits = None
        for t in range(args.prompt_len):
            logits, cache = step(params, cache, prompts[:, t:t + 1],
                                 jnp.asarray(t, jnp.int32))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # ---- decode: greedy generation ---------------------------------------
    out_tokens = []
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(
        jnp.int32)
    t0 = time.time()
    for t in range(args.prompt_len, args.prompt_len + args.gen):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, cache = step(params, cache, tok,
                             jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(
            jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen} cache={W}")
    print(f"prefill: {t_prefill:.2f}s "
          f"({B * args.prompt_len / max(t_prefill, 1e-9):.1f} tok/s)")
    print(f"decode:  {t_decode:.2f}s "
          f"({B * args.gen / max(t_decode, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b][:12].tolist()} ...")


if __name__ == "__main__":
    main()
