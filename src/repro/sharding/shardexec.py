"""shard_map execution layer for the packed round (DESIGN.md §9).

The flat-buffer engine (§6) runs the T-step hot path as fused whole-buffer
passes, but under GSPMD the Pallas kernels are not partitionable — a
``pallas_call`` over a sharded operand silently all-gathers it — so the
mesh builders used to pin ``impl="jnp"`` AND replicate the packed buffer
within each group. This module removes both limits: the buffer shards over
the in-group mesh axes (``"fsdp"``/``"model"``) via a chunk-aligned
``packing.ShardedLayout``, and the fused optimizer kernels, the int8
quantize/dequantize codec kernels, and the ``sq_norm`` metric reduction
run inside ``jax.shard_map`` blocks on each device's LOCAL shard.

Mapping (one ``ShardExec`` per mesh):

* state buffers ``(G, Np)`` carry spec ``P(group_axes, shard_axes)`` —
  one group per slice of the slow axes, ``Np/n_shards`` elements per
  device inside the group;
* the per-step optimizer update is ``shard_map(opt.step)`` — element-wise,
  zero collectives;
* the group exchange routes through ``comm.Exchange`` semantics expressed
  with collectives: server/async mean = ``psum`` over the group axes,
  ring/gossip = per-hop ``all_gather`` + this group's row of the mixing
  matrix (with per-hop recompression, matching the replicated path);
* metric ``||g||²`` = shard-local ``sq_norm`` + ``psum`` over shard axes.

Parity contract (tests/test_shardexec.py): sharded packed rounds match the
replicated path on the SAME ``ShardedLayout`` to fp32 tolerance for
sgd/momentum/adamw × server/ring × fp32/int8 — int8 exactly, because the
stochastic-rounding noise is generated OUTSIDE the shard_map block at the
full rows shape (``Codec.noise``) and each device consumes its own slice.

Refused here (use the replicated path): ``topk`` (global per-group
selection + a residual that error feedback must update consistently —
shard-local top-k would change the payload).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim import packing

# in-group axes a packed buffer may shard over, major-to-minor — must stay
# consistent everywhere a buffer spec is built
SHARD_AXES = ("fsdp", "model")


@dataclasses.dataclass(frozen=True)
class ShardExec:
    """Static plan: which mesh axes carry groups vs in-group shards."""
    mesh: Mesh
    group_axes: Tuple[str, ...]    # the local-SGD G axis (pod/data)
    shard_axes: Tuple[str, ...]    # in-group buffer axes (fsdp/model)

    @property
    def n_shards(self) -> int:
        n = 1
        for a in self.shard_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_groups(self) -> int:
        n = 1
        for a in self.group_axes:
            n *= self.mesh.shape[a]
        return n

    def _entry(self, axes):
        return axes[0] if len(axes) == 1 else tuple(axes)

    def buf_spec(self) -> P:
        """Spec for a (G, Np) packed buffer: groups over the slow axes,
        the flat model axis over the in-group shard axes."""
        return P(self._entry(self.group_axes), self._entry(self.shard_axes))

    def group_spec(self) -> P:
        """Spec for per-group scalars/vectors of leading dim G."""
        return P(self._entry(self.group_axes))

    def check_layout(self, layout: packing.Layout, chunk: int = 0) -> None:
        if not isinstance(layout, packing.ShardedLayout):
            raise ValueError(
                "sharded execution needs a packing.ShardedLayout "
                "(packing.shard_layout(layout, n_shards)) — got a plain "
                "Layout whose buffer does not split into shards")
        if layout.n_shards != self.n_shards:
            raise ValueError(
                f"layout sharded {layout.n_shards}-way but the mesh's "
                f"in-group axes {self.shard_axes} hold {self.n_shards} "
                "devices")
        if chunk and layout.shard_size % chunk:
            raise ValueError(
                f"shard size {layout.shard_size} is not a multiple of the "
                f"codec chunk {chunk}; build the layout with "
                f"packing.shard_layout(..., align={chunk}) so per-chunk "
                "scales stay shard-local")

    def _gidx(self):
        """Linear group index of this device, matching how the G axis
        flattens over ``group_axes`` in the buffer spec (major-to-minor)."""
        idx = jnp.zeros((), jnp.int32)
        for a in self.group_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    # -- fused optimizer update -------------------------------------------

    def opt_step(self, opt):
        """shard_map-wrapped ``opt.step`` on (G, Np) buffers: each device
        updates its (1, shard) block with the real fused kernel (or the
        jnp fusion); the scalar step counter rides replicated."""
        spec = self.buf_spec()

        def step(buf_G, grads_G, opt_state):
            sspec = {k: (P() if k == "count" else spec) for k in opt_state}
            f = shard_map(opt.step, mesh=self.mesh,
                          in_specs=(spec, spec, sspec),
                          out_specs=(spec, sspec), check_rep=False)
            return f(buf_G, grads_G, opt_state)

        return step

    # -- metrics -----------------------------------------------------------

    def sq_norm_groups(self, use_pallas: bool):
        """Per-group ||g||² of a (G, Np) buffer: shard-local reduction
        (Pallas sq_norm kernel or one jnp fusion) + psum over the shard
        axes -> (G,)."""
        spec = self.buf_spec()
        sax = self._entry(self.shard_axes)

        def local(g):
            if use_pallas:
                from repro.kernels import use_interpret
                from repro.kernels.sq_norm import sq_norm_groups
                part = sq_norm_groups(g, interpret=use_interpret())
            else:
                part = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=-1)
            return jax.lax.psum(part, sax)

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=self.group_spec(), check_rep=False)

    # -- codec-free mixing (opt-state moments) ----------------------------

    def mix(self, exch):
        """Sharded ``Exchange.mix`` for ONE (G, Np) buffer: psum-mean for
        server/async, k hops of all_gather + this group's W row for
        ring/gossip (moments ride codec-free at fp32, DESIGN.md §8)."""
        if exch.topology == "none":
            return lambda x: x
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        w = None if exch.w is None else jnp.asarray(exch.w, jnp.float32)

        def local(x):
            if w is None:
                return jax.lax.pmean(x, gax)
            y = x
            for _ in range(exch.mix_rounds):
                y = self._mix_hop(y, w, gax)
            return y

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=spec, check_rep=False)

    def _mix_hop(self, y, w, gax):
        """One W hop on a local (1, shard) block: gather the G neighbor
        blocks for THIS shard range, weight by this group's W row."""
        full = jax.lax.all_gather(y, gax, axis=0, tiled=True)   # (G, shard)
        row = jnp.take(w, self._gidx(), axis=0)                 # (G,)
        return jnp.tensordot(row, full, axes=[[0], [0]])[None]

    # -- the communication step -------------------------------------------

    def exchange(self, exch, layout: packing.Layout):
        """shard_map'd ``Exchange.params``: (x_G, x0_G, comm_state) ->
        (mixed_x_G, new_comm_state), semantics-matched to the replicated
        path (incl. per-hop recompression for decentralized lossy rounds).
        Codec handling on the local shard:

        * fp32 / topology "none": no codec work (bit-exact semantics),
        * fp16/bf16: element-wise cast on the local block (identical
          values to the replicated path by construction),
        * int8: noise generated OUTSIDE at the full rows shape via
          ``Codec.noise`` — per-chunk scales and rounding bits match the
          replicated path bit-for-bit on every shard,
        * topk: refused (global per-group selection; see module doc).
        """
        codec = exch.codec
        if not codec.shardable:
            raise NotImplementedError(
                f"codec {codec.name!r} is not shardable: its payload is a "
                "global per-group selection with an error-feedback "
                "residual — run it on the replicated path (DESIGN.md §9)")
        lossy = (not codec.identity) and exch.topology != "none"
        chunked = lossy and codec.chunk > 0
        if chunked:
            self.check_layout(layout, codec.chunk)
        else:
            self.check_layout(layout)
        hops = exch.mix_rounds if exch.w is not None else 1
        n_compress = hops if (lossy and exch.w is not None) else (
            1 if lossy else 0)
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)
        w = None if exch.w is None else jnp.asarray(exch.w, jnp.float32)
        G = self.n_groups
        chunk = codec.chunk

        def compress_local(y, ref, u):
            d = y - ref
            if chunked:
                rows = d.reshape(-1, chunk)
                out = codec.compress_rows(rows, u.reshape(rows.shape))
                return ref + out.reshape(d.shape)
            d_hat, _ = codec.compress(d, {})
            return ref + d_hat

        def local(x, x0, us, pushed, rnd):
            if w is not None:                      # ring / gossip
                y, ref = x, x0
                for h in range(hops):
                    if lossy:
                        y = compress_local(y, ref, us[h] if chunked
                                           else None)
                        ref = y
                    y = self._mix_hop(y, w, gax)
                return y, pushed
            y = compress_local(x, x0, us[0] if chunked else None) \
                if lossy else x
            if exch.topology == "async_stale":
                keep = ((self._gidx() + rnd) % (exch.staleness + 1)) == 0
                pushed = jnp.where(keep, y, pushed)
                return jax.lax.pmean(pushed, gax), pushed
            if exch.topology == "none":
                return y, pushed
            return jax.lax.pmean(y, gax), pushed   # server

        def fn(x_G, x0_G, comm_state):
            new_state = dict(comm_state)
            us = jnp.zeros((1, 1), jnp.float32)    # placeholder
            us_spec = P(None, None)
            if chunked:
                cnt = comm_state["codec"]["count"]
                rows_shape = (G * layout.padded // chunk, chunk)
                us = jnp.stack([codec.noise(cnt + h, rows_shape)
                                .reshape(G, -1, chunk)
                                for h in range(n_compress)])
                us_spec = P(None, self._entry(self.group_axes), sax, None)
                new_state["codec"] = {"count": cnt + n_compress}
            pushed = comm_state.get("pushed", jnp.zeros((1, 1), jnp.float32))
            pushed_spec = spec if "pushed" in comm_state else P(None, None)
            rnd = comm_state.get("round", jnp.zeros((), jnp.int32))
            x0 = x0_G if lossy else x_G            # unused when not lossy
            f = shard_map(local, mesh=self.mesh,
                          in_specs=(spec, spec, us_spec, pushed_spec, P()),
                          out_specs=(spec, pushed_spec), check_rep=False)
            mixed, new_pushed = f(x_G, x0, us, pushed, rnd)
            if exch.topology == "async_stale":
                new_state["pushed"] = new_pushed
                new_state["round"] = rnd + 1
            return mixed, new_state

        return fn


def plan_for(mesh: Mesh, require: bool = False) -> Optional[ShardExec]:
    """The mesh's sharded-execution plan, or None when no in-group axis
    has more than one device (the replicated path is then both correct
    and free — nothing to shard over)."""
    shard_axes = tuple(a for a in SHARD_AXES
                       if a in mesh.axis_names and mesh.shape[a] > 1)
    if not shard_axes:
        if require:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has no in-group axis "
                f"({'/'.join(SHARD_AXES)}) larger than 1 to shard the "
                "packed buffer over")
        return None
    group_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardExec(mesh=mesh, group_axes=group_axes,
                     shard_axes=shard_axes)
