"""shard_map execution layer for the packed round (DESIGN.md §9).

The flat-buffer engine (§6) runs the T-step hot path as fused whole-buffer
passes, but under GSPMD the Pallas kernels are not partitionable — a
``pallas_call`` over a sharded operand silently all-gathers it — so the
mesh builders used to pin ``impl="jnp"`` AND replicate the packed buffer
within each group. This module removes both limits: the buffer shards over
the in-group mesh axes (``"fsdp"``/``"model"``) via a chunk-aligned
``packing.ShardedLayout``, and the fused optimizer kernels, the int8
quantize/dequantize codec kernels, and the ``sq_norm`` metric reduction
run inside ``jax.shard_map`` blocks on each device's LOCAL shard. The
moment streams shard exactly like the params (same ShardedLayout, §10)
and ride the same shard_map exchange via ``exchange_streams``.

Mapping (one ``ShardExec`` per mesh):

* state buffers ``(G, Np)`` carry spec ``P(group_axes, shard_axes)`` —
  one group per slice of the slow axes, ``Np/n_shards`` elements per
  device inside the group;
* the per-step optimizer update is ``shard_map(opt.step)`` — element-wise,
  zero collectives;
* the group exchange routes through ``comm.Exchange`` semantics expressed
  with collectives: server/async mean = ``psum`` over the group axes,
  ring/gossip = per-hop ``all_gather`` + this group's row of the mixing
  matrix (with per-hop recompression, matching the replicated path);
* metric ``||g||²`` = shard-local ``sq_norm`` + ``psum`` over shard axes.

Parity contract (tests/test_shardexec.py): sharded packed rounds match the
replicated path on the SAME ``ShardedLayout`` to fp32 tolerance for
sgd/momentum/adamw × server/ring × fp32/int8 — int8 exactly, because the
stochastic-rounding noise is generated OUTSIDE the shard_map block at the
full rows shape (``Codec.noise``) and each device consumes its own slice.

Refused here (use the replicated path): ``topk`` (global per-group
selection + a residual that error feedback must update consistently —
shard-local top-k would change the payload).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim import packing

# in-group axes a packed buffer may shard over, major-to-minor — must stay
# consistent everywhere a buffer spec is built
SHARD_AXES = ("fsdp", "model")


@dataclasses.dataclass(frozen=True)
class ShardExec:
    """Static plan: which mesh axes carry groups vs in-group shards."""
    mesh: Mesh
    group_axes: Tuple[str, ...]    # the local-SGD G axis (pod/data)
    shard_axes: Tuple[str, ...]    # in-group buffer axes (fsdp/model)

    @property
    def n_shards(self) -> int:
        n = 1
        for a in self.shard_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_groups(self) -> int:
        n = 1
        for a in self.group_axes:
            n *= self.mesh.shape[a]
        return n

    def _entry(self, axes):
        return axes[0] if len(axes) == 1 else tuple(axes)

    def buf_spec(self) -> P:
        """Spec for a (G, Np) packed buffer: groups over the slow axes,
        the flat model axis over the in-group shard axes."""
        return P(self._entry(self.group_axes), self._entry(self.shard_axes))

    def group_spec(self) -> P:
        """Spec for per-group scalars/vectors of leading dim G."""
        return P(self._entry(self.group_axes))

    def check_layout(self, layout: packing.Layout, chunk: int = 0) -> None:
        if not isinstance(layout, packing.ShardedLayout):
            raise ValueError(
                "sharded execution needs a packing.ShardedLayout "
                "(packing.shard_layout(layout, n_shards)) — got a plain "
                "Layout whose buffer does not split into shards")
        if layout.n_shards != self.n_shards:
            raise ValueError(
                f"layout sharded {layout.n_shards}-way but the mesh's "
                f"in-group axes {self.shard_axes} hold {self.n_shards} "
                "devices")
        if chunk and layout.shard_size % chunk:
            raise ValueError(
                f"shard size {layout.shard_size} is not a multiple of the "
                f"codec chunk {chunk}; build the layout with "
                f"packing.shard_layout(..., align={chunk}) so per-chunk "
                "scales stay shard-local")

    def _gidx(self):
        """Linear group index of this device, matching how the G axis
        flattens over ``group_axes`` in the buffer spec (major-to-minor)."""
        idx = jnp.zeros((), jnp.int32)
        for a in self.group_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    # -- fused optimizer update -------------------------------------------

    def opt_step(self, opt):
        """shard_map-wrapped ``opt.step`` on (G, Np) buffers: each device
        updates its (1, shard) block with the real fused kernel (or the
        jnp fusion); the scalar step counter rides replicated."""
        spec = self.buf_spec()

        def step(buf_G, grads_G, opt_state):
            sspec = {k: (P() if k == "count" else spec) for k in opt_state}
            f = shard_map(opt.step, mesh=self.mesh,
                          in_specs=(spec, spec, sspec),
                          out_specs=(spec, sspec), check_rep=False)
            return f(buf_G, grads_G, opt_state)

        return step

    # -- metrics -----------------------------------------------------------

    def sq_norm_groups(self, use_pallas: bool):
        """Per-group ||g||² of a (G, Np) buffer: shard-local reduction
        (Pallas sq_norm kernel or one jnp fusion) + psum over the shard
        axes -> (G,)."""
        spec = self.buf_spec()
        sax = self._entry(self.shard_axes)

        def local(g):
            if use_pallas:
                from repro.kernels import use_interpret
                from repro.kernels.sq_norm import sq_norm_groups
                part = sq_norm_groups(g, interpret=use_interpret())
            else:
                part = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=-1)
            return jax.lax.psum(part, sax)

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=self.group_spec(), check_rep=False)

    # -- codec-free mixing ------------------------------------------------

    def mix(self, exch):
        """Sharded ``Exchange.mix`` for ONE (G, Np) buffer: psum-mean for
        server/async, k hops of all_gather + this group's W row for
        ring/gossip. Identity-codec streams ride these same ops inside
        ``exchange_streams`` (DESIGN.md §10); kept as the standalone
        codec-free utility (and the §10 bit-exactness reference)."""
        if exch.topology == "none":
            return lambda x: x
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        w = None if exch.w is None else jnp.asarray(exch.w, jnp.float32)

        def local(x):
            if w is None:
                return jax.lax.pmean(x, gax)
            y = x
            for _ in range(exch.mix_rounds):
                y = self._mix_hop(y, w, gax)
            return y

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=spec, check_rep=False)

    def _mix_hop(self, y, w, gax):
        """One W hop on a local (1, shard) block: gather the G neighbor
        blocks for THIS shard range, weight by this group's W row."""
        full = jax.lax.all_gather(y, gax, axis=0, tiled=True)   # (G, shard)
        row = jnp.take(w, self._gidx(), axis=0)                 # (G,)
        return jnp.tensordot(row, full, axes=[[0], [0]])[None]

    # -- the communication step -------------------------------------------

    def exchange_streams(self, exch, layout: packing.Layout):
        """shard_map'd ``Exchange.streams`` (DESIGN.md §10): every stream
        of the round's payload — params plus averaged moment buffers —
        goes through ITS codec and the topology inside ONE shard_map
        block, semantics-matched to the replicated path (incl. per-hop
        recompression for decentralized lossy rounds, per-stream codec
        state, and per-stream async staleness buffers). Codec handling on
        the local shard:

        * fp32 / topology "none": no codec work (bit-exact semantics),
        * fp16/bf16: element-wise cast on the local block (identical
          values to the replicated path by construction),
        * int8: noise generated OUTSIDE at the full rows shape via
          ``Codec.noise``, per stream from that stream's rng counter —
          per-chunk scales and rounding bits match the replicated path
          bit-for-bit on every shard,
        * topk: refused (global per-group selection; see module doc).

        Returns ``fn(xs, xs0, comm_state) -> (mixed, new_comm_state)``
        over ``{stream: (G, Np) buffer}`` dicts.
        """
        for c in (exch.codec, exch.mcodec):
            if not (c.shardable or c.identity):
                raise NotImplementedError(
                    f"codec {c.name!r} is not shardable: its payload is a "
                    "global per-group selection with an error-feedback "
                    "residual — run it on the replicated path "
                    "(DESIGN.md §9)")
        for c in (exch.codec, exch.mcodec):
            if (not c.identity) and c.chunk > 0:
                self.check_layout(layout, c.chunk)
        self.check_layout(layout)
        hops = exch.mix_rounds if exch.w is not None else 1
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)
        w = None if exch.w is None else jnp.asarray(exch.w, jnp.float32)
        G = self.n_groups
        dummy_spec = P(None, None)

        def is_lossy(codec):
            return (not codec.identity) and exch.topology != "none"

        def compress_local(codec, y, ref, u):
            d = y - ref
            if codec.chunk > 0:
                rows = d.reshape(-1, codec.chunk)
                out = codec.compress_rows(rows, u.reshape(rows.shape))
                return ref + out.reshape(d.shape)
            d_hat, _ = codec.compress(d, {})
            return ref + d_hat

        def fn(xs, xs0, comm_state):
            names = tuple(xs)
            codecs = {k: exch.stream_codec(k) for k in names}
            lossy = {k: is_lossy(codecs[k]) for k in names}
            chunked = {k: lossy[k] and codecs[k].chunk > 0 for k in names}
            n_compress = {k: (hops if (lossy[k] and w is not None)
                              else (1 if lossy[k] else 0)) for k in names}
            new_state = dict(comm_state)
            cstates = dict(comm_state.get("codec", {}))

            def local(xs_t, x0s_t, us_t, pushed_t, rnd):
                outs, new_pushed = [], []
                for i, k in enumerate(names):
                    codec, x, x0 = codecs[k], xs_t[i], x0s_t[i]
                    if w is not None:              # ring / gossip
                        y, ref = x, x0
                        for h in range(hops):
                            if lossy[k]:
                                y = compress_local(
                                    codec, y, ref,
                                    us_t[i][h] if chunked[k] else None)
                                ref = y
                            y = self._mix_hop(y, w, gax)
                        outs.append(y)
                        new_pushed.append(pushed_t[i])
                        continue
                    y = compress_local(codec, x, x0,
                                       us_t[i][0] if chunked[k] else None) \
                        if lossy[k] else x
                    if exch.topology == "async_stale":
                        keep = ((self._gidx() + rnd)
                                % (exch.staleness + 1)) == 0
                        p = jnp.where(keep, y, pushed_t[i])
                        new_pushed.append(p)
                        outs.append(jax.lax.pmean(p, gax))
                    elif exch.topology == "none":
                        outs.append(y)
                        new_pushed.append(pushed_t[i])
                    else:                          # server
                        outs.append(jax.lax.pmean(y, gax))
                        new_pushed.append(pushed_t[i])
                return tuple(outs), tuple(new_pushed)

            dummy = jnp.zeros((1, 1), jnp.float32)
            us, us_specs = [], []
            for k in names:
                if not chunked[k]:
                    us.append(dummy)
                    us_specs.append(dummy_spec)
                    continue
                chunk = codecs[k].chunk
                cnt = comm_state["codec"][k]["count"]
                rows_shape = (G * layout.padded // chunk, chunk)
                us.append(jnp.stack([codecs[k].noise(cnt + h, rows_shape)
                                     .reshape(G, -1, chunk)
                                     for h in range(n_compress[k])]))
                us_specs.append(P(None, gax, sax, None))
                cstates[k] = {"count": cnt + n_compress[k]}
            if any(chunked.values()):
                new_state["codec"] = cstates
            stale = exch.topology == "async_stale"
            pushed, pushed_specs = [], []
            for k in names:
                if not stale:
                    pushed.append(dummy)
                    pushed_specs.append(dummy_spec)
                    continue
                pushed.append(comm_state["pushed"] if k == "params"
                              else comm_state["pushed_opt"][k])
                pushed_specs.append(spec)
            rnd = comm_state.get("round", jnp.zeros((), jnp.int32))
            x0s = tuple(xs0.get(k, xs[k]) for k in names)  # dummy when
            # the stream is not lossy (never read inside the block)
            f = shard_map(local, mesh=self.mesh,
                          in_specs=((spec,) * len(names),
                                    (spec,) * len(names),
                                    tuple(us_specs), tuple(pushed_specs),
                                    P()),
                          out_specs=((spec,) * len(names),
                                     tuple(pushed_specs)),
                          check_rep=False)
            mixed_t, new_pushed = f(tuple(xs[k] for k in names), x0s,
                                    tuple(us), tuple(pushed), rnd)
            mixed = dict(zip(names, mixed_t))
            if stale:
                new_state["pushed"] = new_pushed[names.index("params")]
                mnames = [k for k in names if k != "params"]
                if mnames:
                    po = dict(comm_state["pushed_opt"])
                    for k in mnames:
                        po[k] = new_pushed[names.index(k)]
                    new_state["pushed_opt"] = po
                new_state["round"] = rnd + 1
            return mixed, new_state

        return fn

    def exchange(self, exch, layout: packing.Layout):
        """Single-stream convenience wrapper over ``exchange_streams``:
        (x_G, x0_G, comm_state) -> (mixed_x_G, new_comm_state) for the
        params buffer only (the pre-§10 signature, kept for tests)."""
        fn = self.exchange_streams(exch, layout)

        def one(x_G, x0_G, comm_state):
            xs0 = {} if x0_G is None else {"params": x0_G}
            mixed, new_state = fn({"params": x_G}, xs0, comm_state)
            return mixed["params"], new_state

        return one


def plan_for(mesh: Mesh, require: bool = False) -> Optional[ShardExec]:
    """The mesh's sharded-execution plan, or None when no in-group axis
    has more than one device (the replicated path is then both correct
    and free — nothing to shard over)."""
    shard_axes = tuple(a for a in SHARD_AXES
                       if a in mesh.axis_names and mesh.shape[a] > 1)
    if not shard_axes:
        if require:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has no in-group axis "
                f"({'/'.join(SHARD_AXES)}) larger than 1 to shard the "
                "packed buffer over")
        return None
    group_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardExec(mesh=mesh, group_axes=group_axes,
                     shard_axes=shard_axes)
