"""shard_map execution layer for the packed round (DESIGN.md §9).

The flat-buffer engine (§6) runs the T-step hot path as fused whole-buffer
passes, but under GSPMD the Pallas kernels are not partitionable — a
``pallas_call`` over a sharded operand silently all-gathers it — so the
mesh builders used to pin ``impl="jnp"`` AND replicate the packed buffer
within each group. This module removes both limits: the buffer shards over
the in-group mesh axes (``"fsdp"``/``"model"``) via a chunk-aligned
``packing.ShardedLayout``, and the fused optimizer kernels, the int8
quantize/dequantize codec kernels, and the ``sq_norm`` metric reduction
run inside ``jax.shard_map`` blocks on each device's LOCAL shard. The
moment streams shard exactly like the params (same ShardedLayout, §10)
and ride the same shard_map exchange via ``exchange_streams``.

Mapping (one ``ShardExec`` per mesh):

* state buffers ``(G, Np)`` carry spec ``P(group_axes, shard_axes)`` —
  one group per slice of the slow axes, ``Np/n_shards`` elements per
  device inside the group;
* the per-step optimizer update is ``shard_map(opt.step)`` — element-wise,
  zero collectives;
* the group exchange routes through ``comm.Exchange`` semantics expressed
  with collectives: server/async mean = ``psum`` over the group axes,
  ring/gossip = per-hop NEIGHBOR exchange — one ``ppermute`` per nonzero
  circulant offset of W ships O(deg·shard) wire per hop instead of the
  old all_gather's O(G·shard) (DESIGN.md §11; ``hop_impl="allgather"``
  keeps the dense hop as the bit-exact parity reference) — with per-hop
  recompression matching the replicated path;
* ``topk`` runs SHARDED (DESIGN.md §11): distributed selection — shard-
  local top-k bounds + a psum'd bisection refine the per-group threshold
  over the shard axes; entries with ``|c| >= tau`` (and never the zero
  pad) ship, at most k per group; the error-feedback residual is shard-
  local and everything unselected is re-offered next round;
* metric ``||g||²`` = shard-local ``sq_norm`` + ``psum`` over shard axes.

Parity contract (tests/test_shardexec.py + test_exchange_engine.py):
sharded packed rounds match the replicated path on the SAME
``ShardedLayout`` to fp32 tolerance for sgd/momentum/adamw × server/ring
× fp32/int8 — int8 exactly, because the stochastic-rounding noise is
generated OUTSIDE the shard_map block at the full rows shape
(``Codec.noise``) and each device consumes its own slice; the ppermute
hop is bit-exact vs the all_gather hop (same assembled (G, shard) rows,
same W-row contraction). Sharded top-k is NOT bit-matched to the
replicated exact selection (threshold rule, §11) — it is convergence-
matched (fig2 suite) and property-tested instead.

Refused here (use the replicated path): a ``downlink_codec`` (its
broadcast-reference state is not threaded through the shard_map block).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm import faults as faults_mod
from repro.comm import topology as topo_mod
from repro.optim import packing

# in-group axes a packed buffer may shard over, major-to-minor — must stay
# consistent everywhere a buffer spec is built
SHARD_AXES = ("fsdp", "model")

# psum'd bisection steps refining the sharded top-k threshold: each step
# halves the [lo, hi] bracket, so 26 resolves ~1e-8 of the value range —
# below that the unselected near-threshold mass just waits one round in
# the error-feedback residual (DESIGN.md §11)
TOPK_BISECT_ITERS = 26


@dataclasses.dataclass(frozen=True)
class ShardExec:
    """Static plan: which mesh axes carry groups vs in-group shards."""
    mesh: Mesh
    group_axes: Tuple[str, ...]    # the local-SGD G axis (pod/data)
    shard_axes: Tuple[str, ...]    # in-group buffer axes (fsdp/model)
    # ring/gossip hop collective: "ppermute" (neighbor exchange, the
    # bandwidth-optimal default — O(deg·shard) wire) or "allgather" (the
    # dense O(G·shard) hop, kept as the bit-exact parity/benchmark
    # reference — DESIGN.md §11)
    hop_impl: str = "ppermute"

    @property
    def n_shards(self) -> int:
        n = 1
        for a in self.shard_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_groups(self) -> int:
        n = 1
        for a in self.group_axes:
            n *= self.mesh.shape[a]
        return n

    def _entry(self, axes):
        return axes[0] if len(axes) == 1 else tuple(axes)

    def buf_spec(self) -> P:
        """Spec for a (G, Np) packed buffer: groups over the slow axes,
        the flat model axis over the in-group shard axes."""
        return P(self._entry(self.group_axes), self._entry(self.shard_axes))

    def group_spec(self) -> P:
        """Spec for per-group scalars/vectors of leading dim G."""
        return P(self._entry(self.group_axes))

    def check_layout(self, layout: packing.Layout, chunk: int = 0) -> None:
        if not isinstance(layout, packing.ShardedLayout):
            raise ValueError(
                "sharded execution needs a packing.ShardedLayout "
                "(packing.shard_layout(layout, n_shards)) — got a plain "
                "Layout whose buffer does not split into shards")
        if layout.n_shards != self.n_shards:
            raise ValueError(
                f"layout sharded {layout.n_shards}-way but the mesh's "
                f"in-group axes {self.shard_axes} hold {self.n_shards} "
                "devices")
        if chunk and layout.shard_size % chunk:
            raise ValueError(
                f"shard size {layout.shard_size} is not a multiple of the "
                f"codec chunk {chunk}; build the layout with "
                f"packing.shard_layout(..., align={chunk}) so per-chunk "
                "scales stay shard-local")

    def _gidx(self):
        """Linear group index of this device, matching how the G axis
        flattens over ``group_axes`` in the buffer spec (major-to-minor)."""
        idx = jnp.zeros((), jnp.int32)
        for a in self.group_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    # -- fused optimizer update -------------------------------------------

    def opt_step(self, opt):
        """shard_map-wrapped ``opt.step`` on (G, Np) buffers: each device
        updates its (1, shard) block with the real fused kernel (or the
        jnp fusion); the scalar step counter rides replicated."""
        spec = self.buf_spec()

        def step(buf_G, grads_G, opt_state):
            sspec = {k: (P() if k == "count" else spec) for k in opt_state}
            f = shard_map(opt.step, mesh=self.mesh,
                          in_specs=(spec, spec, sspec),
                          out_specs=(spec, sspec), check_rep=False)
            return f(buf_G, grads_G, opt_state)

        return step

    # -- metrics -----------------------------------------------------------

    def sq_norm_groups(self, use_pallas: bool):
        """Per-group ||g||² of a (G, Np) buffer: shard-local reduction
        (Pallas sq_norm kernel or one jnp fusion) + psum over the shard
        axes -> (G,)."""
        spec = self.buf_spec()
        sax = self._entry(self.shard_axes)

        def local(g):
            if use_pallas:
                from repro.kernels import use_interpret
                from repro.kernels.sq_norm import sq_norm_groups
                part = sq_norm_groups(g, interpret=use_interpret())
            else:
                part = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=-1)
            return jax.lax.psum(part, sax)

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=self.group_spec(), check_rep=False)

    def consensus_sq_groups(self, use_pallas: bool):
        """Per-group consensus distance ||x_g - x̄||² of a (G, Np) buffer:
        pmean over the group axes gives the fleet mean, the deviation is
        reduced shard-local (Pallas sq_norm kernel or one jnp fusion) and
        psum'd over the shard axes -> (G,). Matches the replicated
        ``x - mean(x, axis=0)`` reduction to float32 accumulation order
        within each shard (parity ≤1e-5, DESIGN.md §13)."""
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)

        def local(x):
            x32 = x.astype(jnp.float32)
            d = x32 - jax.lax.pmean(x32, gax)
            if use_pallas:
                from repro.kernels import use_interpret
                from repro.kernels.sq_norm import sq_norm_groups
                part = sq_norm_groups(d, interpret=use_interpret())
            else:
                part = jnp.sum(jnp.square(d), axis=-1)
            return jax.lax.psum(part, sax)

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=self.group_spec(), check_rep=False)

    # -- codec-free mixing ------------------------------------------------

    def mix(self, exch):
        """Sharded ``Exchange.mix`` for ONE (G, Np) buffer: psum-mean for
        server/async, k neighbor-exchange hops + this group's W row for
        ring/gossip. Identity-codec streams ride these same ops inside
        ``exchange_streams`` (DESIGN.md §10); kept as the standalone
        codec-free utility (and the §10 bit-exactness reference)."""
        if exch.topology == "none":
            return lambda x: x
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        hop = self._hop_fn(exch.w, gax)

        def local(x):
            if hop is None:
                return jax.lax.pmean(x, gax)
            y = x
            for _ in range(exch.mix_rounds):
                y = hop(y)
            return y

        return shard_map(local, mesh=self.mesh, in_specs=(spec,),
                         out_specs=spec, check_rep=False)

    def mix_streams(self, exch):
        """``Exchange.mix_inflight`` on sharded buffers (overlap mode,
        DESIGN.md §14): the codec-free mix of the previous round's
        in-flight payload, one ``mix`` application per stream. This is
        the collective the overlap round issues BEFORE its local-step
        block."""
        one = self.mix(exch)

        def fn(inflight: dict) -> dict:
            with jax.named_scope("mix_inflight"):
                return {k: one(v) for k, v in inflight.items()}

        return fn

    def _hop_fn(self, w_np, gax):
        """Build the one-W-hop closure for a local (1, shard) block, or
        None for mean topologies (no W).

        ``hop_impl="ppermute"`` (default): one ``ppermute`` per distinct
        nonzero circulant offset of W ships each neighbor block point-to-
        point — O(deg·shard) wire per hop for a ring (offsets exactly
        {1, G-1}); irregular gossip graphs ship the offset UNION, with
        zero-weight slots a real per-link transport would elide (the
        byte accounting counts only true edges — ``n_edge_sends``). The
        received blocks are assembled into the same (G, shard) rows the
        all_gather produced (absent neighbors stay zero) and contracted
        with this group's W row — 0-weight × 0-value terms make the
        result BIT-EXACT vs the all_gather hop.

        ``hop_impl="allgather"``: the dense O(G·shard) hop (parity and
        benchmark reference).

        With ``mrow``/``act_s`` set (an active FaultPlan, DESIGN.md §12)
        the hop is MASKED: this group's W row is gated by its
        ``matrix_mask`` row, the lost weight substitutes the receiver's
        own value (``deficit`` term — rows stay stochastic), and a
        stalled receiver keeps its block — the same arithmetic as the
        replicated ``_masked_hop_leaf``."""
        if w_np is None:
            return None
        w = jnp.asarray(w_np, jnp.float32)
        G = self.n_groups

        def contract(y, full, gidx, mrow, act_s):
            row = jnp.take(w, gidx, axis=0)                     # (G,)
            if mrow is None:
                return jnp.tensordot(row, full, axes=[[0], [0]])[None]
            rm = row * mrow
            out = jnp.tensordot(rm, full, axes=[[0], [0]])[None]
            out = out + (1.0 - jnp.sum(rm)) * y
            return jnp.where(act_s > 0, out, y)

        if self.hop_impl == "allgather":
            def hop(y, mrow=None, act_s=None):
                full = jax.lax.all_gather(y, gax, axis=0, tiled=True)
                return contract(y, full, self._gidx(), mrow, act_s)

            return hop
        if self.hop_impl != "ppermute":
            raise ValueError(f"unknown hop_impl {self.hop_impl!r} "
                             "(have 'ppermute', 'allgather')")
        offs = topo_mod.neighbor_offsets(w_np)

        def hop(y, mrow=None, act_s=None):
            gidx = self._gidx()
            full = jnp.zeros((G,) + y.shape[1:], y.dtype)
            full = jax.lax.dynamic_update_slice(full, y, (gidx, 0))
            for d in offs:
                # dest g receives the block of group (g + d) % G; the
                # flattened multi-axis order matches _gidx (major->minor)
                perm = [(src, (src - d) % G) for src in range(G)]
                recv = jax.lax.ppermute(y, gax, perm)
                full = jax.lax.dynamic_update_slice(
                    full, recv, ((gidx + d) % G, 0))
            return contract(y, full, gidx, mrow, act_s)

        return hop

    # -- sharded top-k selection (DESIGN.md §11) --------------------------

    def _topk_threshold(self, a, k: int, sax, shard_size: int):
        """Per-group selection threshold for the sharded top-k codec:
        shard-local top-k bounds the global k-th value (the shard whose
        local k-th is largest proves count(>= lo) >= k; hi = global
        amax), then ``TOPK_BISECT_ITERS`` psum'd bisection steps shrink
        the bracket. Returns ``hi`` — the conservative end, so at most k
        entries are selected (near-threshold mass defers one round into
        the error-feedback residual). ``a``: shard-local |c| (shard,)."""
        k_loc = min(k, shard_size)
        top = jax.lax.top_k(a, k_loc)[0]
        hi0 = jax.lax.pmax(top[0], sax)
        lo0 = (jax.lax.pmax(top[-1], sax) if k <= shard_size
               else jnp.zeros((), a.dtype))

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            cnt = jax.lax.psum(jnp.sum((a >= mid).astype(jnp.int32)), sax)
            big = cnt > k
            return jnp.where(big, mid, lo), jnp.where(big, hi, mid)

        _, hi = jax.lax.fori_loop(0, TOPK_BISECT_ITERS, body, (lo0, hi0))
        return hi

    @staticmethod
    def _topk_select(c, tau):
        """Threshold selection with exact error feedback on the local
        block: ship ``|c| >= tau`` (never zeros — the pad region and
        dead coordinates stay off the wire), carry the rest. The EF
        identity ``c == d_hat + residual`` holds exactly."""
        keep = (jnp.abs(c) >= tau) & (jnp.abs(c) > 0.0)
        d_hat = jnp.where(keep, c, 0.0)
        return d_hat, c - d_hat

    # -- the communication step -------------------------------------------

    def exchange_streams(self, exch, layout: packing.Layout):
        """shard_map'd ``Exchange.streams`` (DESIGN.md §10/§11): every
        stream of the round's payload — params plus averaged moment
        buffers — goes through ITS codec and the topology inside ONE
        shard_map block, semantics-matched to the replicated path (incl.
        per-hop recompression for decentralized lossy rounds, per-stream
        codec state, and per-stream async staleness buffers). Codec
        handling on the local shard:

        * fp32 / topology "none": no codec work (bit-exact semantics),
        * fp16/bf16: element-wise cast on the local block (identical
          values to the replicated path by construction),
        * int8: noise generated OUTSIDE at the full rows shape via
          ``Codec.noise``, per stream from that stream's rng counter —
          per-chunk scales and rounding bits match the replicated path
          bit-for-bit on every shard (the pallas impl runs the fused
          qdq kernel — one VMEM pass, DESIGN.md §11),
        * topk: DISTRIBUTED selection (§11) — per-group threshold from
          shard-local top-k + psum'd bisection, shard-local error-
          feedback residual under ``comm_state["codec"][stream]``; at
          most k entries ship (threshold rule, not the replicated exact
          selection — convergence-matched, see module doc).

        Returns ``fn(xs, xs0, comm_state) -> (mixed, new_comm_state)``
        over ``{stream: (G, Np) buffer}`` dicts.

        Fault injection (DESIGN.md §12): an active ``exch.fault_plan``
        generates its delivery/liveness masks OUTSIDE the shard_map
        block at full (G,)/(G, G) shape — the same pattern as the int8
        rounding noise — so the sharded round consumes IDENTICAL masks
        to the replicated path; push_sum dispatches to its own
        ratio-consensus block (``_push_sum_fn``).
        """
        if exch.topology == "push_sum":
            return self._push_sum_fn(exch, layout)
        if exch.topology == "hierarchical":
            return self._hier_fn(exch, layout)
        for c in (exch.codec, exch.mcodec):
            if not (c.shardable or c.identity):
                raise NotImplementedError(
                    f"codec {c.name!r} is not shardable — run it on the "
                    "replicated path (DESIGN.md §9)")
        if exch.downlink_codec is not None:
            raise NotImplementedError(
                "downlink_codec is replicated-path only: its broadcast-"
                "reference state is not threaded through the shard_map "
                "exchange (DESIGN.md §11)")
        if exch.topology == "async_stale" and exch.codec.topk_frac > 0:
            raise NotImplementedError(
                "async_stale + topk: the staleness schedule drops "
                "non-pushing rounds, error feedback assumes delivery "
                "(DESIGN.md §8)")
        for c in (exch.codec, exch.mcodec):
            if (not c.identity) and c.chunk > 0:
                self.check_layout(layout, c.chunk)
        self.check_layout(layout)
        hops = exch.mix_rounds if exch.w is not None else 1
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)
        hop = self._hop_fn(exch.w, gax)
        G = self.n_groups
        shard_size = layout.shard_size
        dummy_spec = P(None, None)
        plan = exch.fault_plan
        faulty = plan is not None and exch.topology != "none"
        # faulty server keeps the async-style per-stream staleness
        # buffers: a dropped push contributes its last delivered model
        buffered = (exch.topology == "async_stale"
                    or (faulty and exch.topology == "server"))

        def is_lossy(codec):
            return (not codec.identity) and exch.topology != "none"

        def compress_local(codec, y, ref, u):
            d = y - ref
            if codec.chunk > 0:
                rows = d.reshape(-1, codec.chunk)
                out = codec.compress_rows(rows, u.reshape(rows.shape))
                return ref + out.reshape(d.shape)
            d_hat, _ = codec.compress(d, {})
            return ref + d_hat

        def fn(xs, xs0, comm_state):
            names = tuple(xs)
            codecs = {k: exch.stream_codec(k) for k in names}
            lossy = {k: is_lossy(codecs[k]) for k in names}
            chunked = {k: lossy[k] and codecs[k].chunk > 0 for k in names}
            selective = {k: lossy[k] and codecs[k].topk_frac > 0
                         for k in names}
            k_sel = {k: max(1, int(round(codecs[k].topk_frac
                                         * layout.padded)))
                     for k in names if selective[k]}
            n_compress = {k: (hops if (lossy[k] and exch.w is not None)
                              else (1 if lossy[k] else 0)) for k in names}
            new_state = dict(comm_state)
            cstates = dict(comm_state.get("codec", {}))

            def topk_step(name, y, ref, res):
                """One selective-codec application on the local block:
                distributed threshold + EF residual (DESIGN.md §11)."""
                c = (y - ref) + res
                tau = self._topk_threshold(jnp.abs(c)[0], k_sel[name],
                                           sax, shard_size)
                d_hat, res = self._topk_select(c, tau)
                return ref + d_hat, res

            def local(xs_t, x0s_t, us_t, res_t, pushed_t, fm_t, rnd):
                # fm_t: fault-mask blocks — ring/gossip (mmasks, act),
                # server/async (deliver,), else a dummy (see fn below)
                outs, new_res, new_pushed = [], [], []
                for i, k in enumerate(names):
                    codec, x, x0 = codecs[k], xs_t[i], x0s_t[i]
                    res = res_t[i]
                    if exch.w is not None:         # ring / gossip
                        y, ref = x, x0
                        for h in range(hops):
                            if selective[k]:
                                y, res = topk_step(k, y, ref, res)
                                ref = y
                            elif lossy[k]:
                                y = compress_local(
                                    codec, y, ref,
                                    us_t[i][h] if chunked[k] else None)
                                ref = y
                            if faulty:
                                y = hop(y, mrow=fm_t[0][h, 0],
                                        act_s=fm_t[1][0])
                            else:
                                y = hop(y)
                        outs.append(y)
                        new_res.append(res)
                        new_pushed.append(pushed_t[i])
                        continue
                    if selective[k]:
                        y, res = topk_step(k, x, x0, res)
                    elif lossy[k]:
                        y = compress_local(codec, x, x0,
                                           us_t[i][0] if chunked[k]
                                           else None)
                    else:
                        y = x
                    if exch.topology == "async_stale":
                        keep = ((self._gidx() + rnd)
                                % (exch.staleness + 1)) == 0
                    else:
                        keep = jnp.asarray(True)
                    if faulty and buffered:
                        arrived = fm_t[0][0] > 0
                        if selective[k]:
                            # EF deferral (DESIGN.md §12): a scheduled
                            # push that DROPPED re-offers its shipped
                            # entries (d_hat == y - x0) next round
                            res = jnp.where(
                                jnp.logical_and(keep,
                                                jnp.logical_not(arrived)),
                                res + (y - x0), res)
                        keep = jnp.logical_and(keep, arrived)
                    new_res.append(res)
                    if buffered:
                        p = jnp.where(keep, y, pushed_t[i])
                        new_pushed.append(p)
                        outs.append(jax.lax.pmean(p, gax))
                    elif exch.topology == "none":
                        outs.append(y)
                        new_pushed.append(pushed_t[i])
                    else:                          # server
                        outs.append(jax.lax.pmean(y, gax))
                        new_pushed.append(pushed_t[i])
                return tuple(outs), tuple(new_res), tuple(new_pushed)

            dummy = jnp.zeros((1, 1), jnp.float32)
            us, us_specs = [], []
            for k in names:
                if not chunked[k]:
                    us.append(dummy)
                    us_specs.append(dummy_spec)
                    continue
                chunk = codecs[k].chunk
                cnt = comm_state["codec"][k]["count"]
                rows_shape = (G * layout.padded // chunk, chunk)
                us.append(jnp.stack([codecs[k].noise(cnt + h, rows_shape)
                                     .reshape(G, -1, chunk)
                                     for h in range(n_compress[k])]))
                us_specs.append(P(None, gax, sax, None))
                cstates[k] = {"count": cnt + n_compress[k]}
            res, res_specs = [], []
            for k in names:
                if not selective[k]:
                    res.append(dummy)
                    res_specs.append(dummy_spec)
                    continue
                # the EF residual is element-wise state: it shards like
                # the stream it carries (DESIGN.md §11)
                res.append(comm_state["codec"][k]["residual"])
                res_specs.append(spec)
            pushed, pushed_specs = [], []
            for k in names:
                if not buffered:
                    pushed.append(dummy)
                    pushed_specs.append(dummy_spec)
                    continue
                pushed.append(comm_state["pushed"] if k == "params"
                              else comm_state["pushed_opt"][k])
                pushed_specs.append(spec)
            rnd = comm_state.get("round", jnp.zeros((), jnp.int32))
            # fault masks, generated OUTSIDE the block at full shape
            # (DESIGN.md §12) — the exact arrays the replicated path uses
            if faulty and exch.w is not None:
                fm = (jnp.stack([plan.matrix_mask(rnd, h, G)
                                 for h in range(hops)]),
                      plan.active_mask(rnd, G))
                fm_specs = (P(None, self._entry(self.group_axes), None),
                            self.group_spec())
            elif faulty:
                fm = (plan.push_mask(rnd, G),)
                fm_specs = (self.group_spec(),)
            else:
                fm = (dummy,)
                fm_specs = (dummy_spec,)
            x0s = tuple(xs0.get(k, xs[k]) for k in names)  # dummy when
            # the stream is not lossy (never read inside the block)
            f = shard_map(local, mesh=self.mesh,
                          in_specs=((spec,) * len(names),
                                    (spec,) * len(names),
                                    tuple(us_specs), tuple(res_specs),
                                    tuple(pushed_specs), fm_specs, P()),
                          out_specs=((spec,) * len(names),
                                     tuple(res_specs),
                                     tuple(pushed_specs)),
                          check_rep=False)
            mixed_t, new_res, new_pushed = f(
                tuple(xs[k] for k in names), x0s, tuple(us), tuple(res),
                tuple(pushed), fm, rnd)
            mixed = dict(zip(names, mixed_t))
            for i, k in enumerate(names):
                if selective[k]:
                    cstates[k] = {"residual": new_res[i]}
            if any(chunked.values()) or any(selective.values()):
                new_state["codec"] = cstates
            if buffered:
                new_state["pushed"] = new_pushed[names.index("params")]
                mnames = [k for k in names if k != "params"]
                if mnames:
                    po = dict(comm_state["pushed_opt"])
                    for k in mnames:
                        po[k] = new_pushed[names.index(k)]
                    new_state["pushed_opt"] = po
            if buffered or (faulty and exch.w is not None):
                new_state["round"] = rnd + 1
            if faulty:
                if exch.w is not None:
                    new_state["participation"] = \
                        exch._edge_participation(rnd)
                else:
                    deliver = fm[0]
                    if exch.topology == "async_stale":
                        sched = (jnp.arange(G) + rnd) \
                            % (exch.staleness + 1) == 0
                    else:
                        sched = jnp.ones((G,), bool)
                    n_sched = jnp.maximum(
                        jnp.sum(sched.astype(jnp.float32)), 1.0)
                    new_state["participation"] = (
                        jnp.sum(jnp.where(sched, deliver, 0.0)) / n_sched)
            return mixed, new_state

        return fn

    def _push_sum_fn(self, exch, layout: packing.Layout):
        """shard_map'd push-sum ratio consensus (DESIGN.md §12),
        semantics-matched to ``Exchange._push_sum_streams``: each group's
        (1, shard) block ships its equal share per circulant offset via
        ``ppermute`` (the same point-to-point transport as the ring
        hops), per-directed-edge backlog buffers shard like the params,
        and the scalar weight channel rides the group axis. The fault
        masks and liveness vector are generated OUTSIDE the block at
        full (G,) shape — identical arrays to the replicated path — so
        sharded and replicated rounds agree to fp32 tolerance (the
        arithmetic is elementwise + one ppermute per offset, in the
        same order)."""
        for c in (exch.codec, exch.mcodec):
            if not (c.identity or c.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"push_sum + {c.name}: the push-sum wire carries "
                    "cumulative mass, not round deltas (DESIGN.md §12); "
                    "valid push_sum codecs: 'fp32', 'fp16', 'bf16'")
        self.check_layout(layout)
        G = self.n_groups
        offs = topo_mod.push_sum_offsets(G)
        hops = exch.mix_rounds
        plan = exch.fault_plan
        a = 1.0 / (len(offs) + 1.0)
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        gspec = self.group_spec()
        gentry = self._entry(self.group_axes)

        def fn(xs, xs0, comm_state):
            del xs0
            names = tuple(xs)
            new_state = dict(comm_state)
            rnd = comm_state["round"]
            if not offs:                           # G == 1: no wire
                new_state["round"] = rnd + 1
                return dict(xs), new_state
            act = (plan.active_mask(rnd, G) if plan is not None
                   else jnp.ones((G,), jnp.float32))
            incs = jnp.stack([jnp.roll(act, d) for d in offs])
            # delivery = Bernoulli edge drop x sender liveness x receiver
            # liveness — the same product the replicated path consumes
            masks = jnp.stack(
                [jnp.stack([(plan.edge_mask(rnd, h, di, G)
                             if plan is not None
                             else jnp.ones((G,), jnp.float32))
                            * incs[di] * act
                            for di, _ in enumerate(offs)])
                 for h in range(hops)])            # (hops, n_offs, G)

            def local(xs_t, bl_t, w, blw, act_l, incs_l, masks_l):
                # shapes: x (1, shard), bl (n_offs, 1, shard), w (1,),
                # blw (n_offs, 1), act_l (1,), incs_l (n_offs, 1),
                # masks_l (hops, n_offs, 1)
                nums = [x.astype(jnp.float32) * w for x in xs_t]
                bls = list(bl_t)
                for h in range(hops):
                    new_w = jnp.where(act_l > 0, a * w, w)
                    nblw = []
                    for di, d in enumerate(offs):
                        perm = [(src, (src + d) % G) for src in range(G)]
                        recv = jax.lax.ppermute(a * w, gax, perm)
                        b = blw[di] + incs_l[di] * recv
                        m = masks_l[h, di]
                        new_w = new_w + m * b
                        nblw.append(b - m * b)
                    for i, k in enumerate(names):
                        codec = exch.stream_codec(k)
                        x = nums[i]
                        y = jnp.where(act_l > 0, a * x, x)
                        nb = []
                        for di, d in enumerate(offs):
                            perm = [(src, (src + d) % G)
                                    for src in range(G)]
                            recv = jax.lax.ppermute(a * x, gax, perm)
                            b = bls[i][di] + incs_l[di] * recv
                            t = b if codec.identity \
                                else codec.compress(b, {})[0]
                            m = masks_l[h, di]
                            y = y + m * t
                            nb.append(b - m * t)
                        nums[i] = y
                        bls[i] = jnp.stack(nb)
                    w = new_w
                    blw = jnp.stack(nblw)
                outs = tuple((nums[i] / w[..., None])
                             .astype(xs_t[i].dtype)
                             for i in range(len(names)))
                return outs, tuple(bls), w, blw

            bl_spec = P(None, gentry, self._entry(self.shard_axes))
            blw_spec = P(None, gentry)
            f = shard_map(local, mesh=self.mesh,
                          in_specs=((spec,) * len(names),
                                    (bl_spec,) * len(names),
                                    gspec, blw_spec, gspec,
                                    P(None, gentry),
                                    P(None, None, gentry)),
                          out_specs=((spec,) * len(names),
                                     (bl_spec,) * len(names),
                                     gspec, blw_spec),
                          check_rep=False)
            mixed_t, new_bl, new_mass, new_blw = f(
                tuple(xs[k] for k in names),
                tuple(comm_state["backlog"][k] for k in names),
                comm_state["mass"], comm_state["backlog_w"],
                act, incs, masks)
            backlog = dict(comm_state["backlog"])
            backlog.update(dict(zip(names, new_bl)))
            new_state["mass"] = new_mass
            new_state["backlog"] = backlog
            new_state["backlog_w"] = new_blw
            new_state["round"] = rnd + 1
            new_state["participation"] = jnp.mean(masks)
            return dict(zip(names, mixed_t)), new_state

        return fn

    def _hier_fn(self, exch, layout: packing.Layout):
        """shard_map'd two-tier hierarchical round (DESIGN.md §16),
        semantics-matched to ``Exchange._hier_streams``. Stage A mixes
        WITHIN each contiguous pod — one ``ppermute`` per pod-circulant
        offset (the contiguous tier factoring is exactly what makes the
        pod-local roll a single device permutation). Stage B is the
        cross-pod tier: pod-level push_sum ratio consensus over
        stride-``pod_size`` ppermutes with mass-conserving backlogs, or
        the leader-mean server step (a ``psum`` of the elected leaders'
        decoded payloads, int8 cross-tier codec included). Every fault
        mask, liveness vector, leader weight and rounding-noise tensor
        is generated OUTSIDE the block at full (G,) shape — the exact
        arrays the replicated path consumes — so sharded and replicated
        rounds agree to fp32 tolerance (the per-member summation order
        differs, nothing else)."""
        from repro.comm.exchange import elect_leaders
        plan = exch.fault_plan
        if plan is not None and not isinstance(plan,
                                               faults_mod.TieredFaultPlan):
            raise NotImplementedError(
                "hierarchical faults are per-tier: a flat FaultPlan does "
                "not say WHICH tier it masks — wrap it as "
                "faults.TieredFaultPlan(intra=..., inter=...); valid "
                "tiers: 'intra' (pod-internal), 'inter' (cross-pod)")
        for c in (exch.codec, exch.mcodec):
            if not (c.identity or c.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"hierarchical intra tier + {c.name}: pod-internal "
                    "hops carry whole-value payloads, not round deltas "
                    "(DESIGN.md §16); valid intra codecs: 'fp32', "
                    "'fp16', 'bf16' — put int8 on the cross-tier wire "
                    "via inter_codec with inter_topology='server'")
        inter_cs = ([exch.inter_codec] if exch.inter_codec is not None
                    else [exch.codec, exch.mcodec])
        for ic in inter_cs:
            if exch.inter_topology == "push_sum" and not (
                    ic.identity or ic.name in ("fp16", "bf16")):
                raise NotImplementedError(
                    f"hierarchical push_sum inter tier + {ic.name}: the "
                    "cross-pod wire carries cumulative (value, weight) "
                    "mass, not round deltas (DESIGN.md §12/§16); valid "
                    "push_sum inter codecs: 'fp32', 'fp16', 'bf16' — or "
                    "inter_topology='server' for 'int8'")
            if not (ic.shardable or ic.identity):
                raise NotImplementedError(
                    f"codec {ic.name!r} is not shardable — run it on the "
                    "replicated path (DESIGN.md §9)")
            if (not ic.identity) and ic.chunk > 0:
                self.check_layout(layout, ic.chunk)
        self.check_layout(layout)
        G = self.n_groups
        n_pods, s = exch.n_pods, exch.pod_len
        ip, xp = exch.intra_plan, exch.inter_plan
        hops = exch.mix_rounds
        offs_p = topo_mod.push_sum_offsets(n_pods)
        w_self, offs_pod, w_edge = topo_mod.ring_circulant(s)
        inter_ps = exch.inter_topology == "push_sum"
        ps_on = inter_ps and bool(offs_p)
        a_sh = 1.0 / (len(offs_p) + 1.0)
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)
        gspec = self.group_spec()
        gentry = self._entry(self.group_axes)
        dummy_spec = P(None, None)

        def perm_pod(d):
            # member i receives the block of pod-mate (i + d) % s — the
            # pod-local circulant expressed on the flat G axis (pods are
            # contiguous, so src stays inside its own pod)
            return [(src, (src // s) * s + ((src % s - d) % s))
                    for src in range(G)]

        def perm_pods(dp):
            # cross-pod circulant: stride pod_size on the G axis, every
            # member lane carries 1/pod_size of its pod's traffic
            return [(src, (src + dp * s) % G) for src in range(G)]

        def fn(xs, xs0, comm_state):
            names = tuple(xs)
            codecs = {k: exch.stream_codec(k) for k in names}
            icodecs = {k: exch.inter_stream_codec(k) for k in names}
            rnd = comm_state["round"]
            new_state = dict(comm_state)
            cstates = dict(comm_state.get("codec", {}))
            touched = False
            dummy = jnp.zeros((1, 1), jnp.float32)

            def pod_take(x, d):
                r = x.reshape((n_pods, s) + x.shape[1:])
                return jnp.roll(r, -d, axis=1).reshape(x.shape)

            # ---- full-shape mask/noise generation (DESIGN.md §12) ----
            act_i = (ip.active_mask(rnd, G) if ip is not None
                     else jnp.ones((G,), jnp.float32))
            part_intra = jnp.ones((), jnp.float32)
            masksA, masksA_spec = dummy, dummy_spec
            delivA, delivA_spec = dummy, dummy_spec
            denA, denA_spec = dummy, dummy_spec
            if s > 1 and exch.intra_topology == "ring":
                rows = []
                for h in range(hops):
                    per = []
                    for di, d in enumerate(offs_pod):
                        bern = (ip.edge_mask(rnd, h, di, G)
                                if ip is not None
                                else jnp.ones((G,), jnp.float32))
                        per.append(bern * pod_take(act_i, d) * act_i)
                    rows.append(jnp.stack(per))
                masksA = jnp.stack(rows)       # (hops, n_offs_pod, G)
                masksA_spec = P(None, None, gentry)
                if ip is not None:
                    part_intra = jnp.mean(masksA)
            elif s > 1:                        # intra "server"
                deliv = (ip.push_mask(rnd, G) if ip is not None
                         else jnp.ones((G,), jnp.float32))
                # row d = the delivery of the payload arriving at each
                # member from its pod-mate at offset d (row 0 = self)
                delivA = jnp.stack([pod_take(deliv, d) for d in range(s)])
                delivA_spec = P(None, gentry)
                denA = jnp.repeat(
                    jnp.sum(deliv.reshape(n_pods, s), axis=1), s)
                denA_spec = gspec
                if ip is not None:
                    part_intra = jnp.mean(deliv)
            mass = blw = act_pod = incsB = masksB = dummy
            lead_w = dummy
            n_live = jnp.ones((), jnp.float32)
            part_inter = jnp.ones((), jnp.float32)
            if ps_on:
                act_x = (xp.active_mask(rnd, G) if xp is not None
                         else jnp.ones((G,), jnp.float32))
                _, pod_live = elect_leaders(act_x, n_pods)
                act_pod = jnp.repeat(pod_live, s)
                incs, msks = [], []
                for di, dp in enumerate(offs_p):
                    bern = (xp.edge_mask(rnd, 0, di, n_pods)
                            if xp is not None
                            else jnp.ones((n_pods,), jnp.float32))
                    src = jnp.roll(act_pod, dp * s)
                    incs.append(src)
                    msks.append(jnp.repeat(bern, s) * src * act_pod)
                incsB, masksB = jnp.stack(incs), jnp.stack(msks)
                mass = comm_state["mass"]
                blw = comm_state["backlog_w"]
                if xp is not None:
                    part_inter = jnp.mean(masksB)
            elif not inter_ps:                 # inter "server"
                act_x = (xp.active_mask(rnd, G) if xp is not None
                         else jnp.ones((G,), jnp.float32))
                lead_w, plive = elect_leaders(act_i * act_x, n_pods)
                n_live = jnp.maximum(jnp.sum(plive), 1.0)
                if ip is not None or xp is not None:
                    part_inter = jnp.mean(plive)
            mass_spec = gspec if ps_on else dummy_spec
            blw_spec = P(None, gentry) if ps_on else dummy_spec
            pvec_spec = gspec if ps_on else dummy_spec
            pmat_spec = P(None, gentry) if ps_on else dummy_spec
            lead_spec = gspec if not inter_ps else dummy_spec
            # inter-server chunked codecs: noise outside at the full
            # rows shape, each device consumes its slice (like the flat
            # int8 path — bit-identical scales and rounding bits)
            lossy_x = {k: (not inter_ps) and not icodecs[k].identity
                       for k in names}
            chunked_x = {k: lossy_x[k] and icodecs[k].chunk > 0
                         for k in names}
            us, us_specs = [], []
            for k in names:
                if not chunked_x[k]:
                    us.append(dummy)
                    us_specs.append(dummy_spec)
                    continue
                chunk = icodecs[k].chunk
                cnt = comm_state["codec"]["inter:" + k]["count"]
                rows_shape = (G * layout.padded // chunk, chunk)
                us.append(icodecs[k].noise(cnt, rows_shape)
                          .reshape(G, -1, chunk))
                us_specs.append(P(gax, sax, None))
                cstates["inter:" + k] = {"count": cnt + 1}
                touched = True
            bl_spec = P(None, gentry, sax)
            bls, bl_specs = [], []
            for k in names:
                if ps_on:
                    bls.append(comm_state["backlog"][k])
                    bl_specs.append(bl_spec)
                else:
                    bls.append(dummy)
                    bl_specs.append(dummy_spec)

            def local(xs_t, x0s_t, us_t, bl_t, act_l, mA_l, dA_l, den_l,
                      w_l, blw_l, actp_l, incs_l, msks_l, lw_l, nlive_l):
                # ---- stage A: pod-internal tier ----------------------
                ys = []
                for i, k in enumerate(names):
                    codec = codecs[k]
                    v = xs_t[i].astype(jnp.float32)
                    if s > 1 and exch.intra_topology == "ring":
                        for h in range(hops):
                            out = w_self * v
                            for di, d in enumerate(offs_pod):
                                recv = jax.lax.ppermute(v, gax,
                                                        perm_pod(d))
                                t = recv if codec.identity \
                                    else codec.compress(recv, {})[0]
                                m = mA_l[h, di][:, None]
                                out = out + w_edge * (m * t
                                                      + (1.0 - m) * v)
                            v = jnp.where(act_l[:, None] > 0, out, v)
                    elif s > 1:                # intra "server"
                        t0 = v if codec.identity \
                            else codec.compress(v, {})[0]
                        num = dA_l[0][:, None] * t0
                        for d in range(1, s):
                            recv = jax.lax.ppermute(v, gax, perm_pod(d))
                            t = recv if codec.identity \
                                else codec.compress(recv, {})[0]
                            num = num + dA_l[d][:, None] * t
                        m = num / jnp.maximum(den_l[:, None], 1.0)
                        ok = jnp.logical_and(act_l[:, None] > 0,
                                             den_l[:, None] > 0)
                        v = jnp.where(ok, m, v)
                    ys.append(v)
                # ---- stage B: cross-pod tier -------------------------
                if ps_on:
                    new_w = jnp.where(actp_l > 0, a_sh * w_l, w_l)
                    nblw = []
                    for di, dp in enumerate(offs_p):
                        recv = jax.lax.ppermute(a_sh * w_l, gax,
                                                perm_pods(dp))
                        b = blw_l[di] + incs_l[di] * recv
                        m = msks_l[di]
                        new_w = new_w + m * b
                        nblw.append(b - m * b)
                    outs, new_bls = [], []
                    for i, k in enumerate(names):
                        ic = icodecs[k]
                        x = ys[i] * w_l[:, None]
                        y = jnp.where(actp_l[:, None] > 0, a_sh * x, x)
                        nb = []
                        for di, dp in enumerate(offs_p):
                            recv = jax.lax.ppermute(a_sh * x, gax,
                                                    perm_pods(dp))
                            b = bl_t[i][di] + incs_l[di][:, None] * recv
                            t = b if ic.identity \
                                else ic.compress(b, {})[0]
                            m = msks_l[di][:, None]
                            y = y + m * t
                            nb.append(b - m * t)
                        outs.append((y / new_w[:, None])
                                    .astype(xs_t[i].dtype))
                        new_bls.append(jnp.stack(nb))
                    return (tuple(outs), tuple(new_bls), new_w,
                            jnp.stack(nblw))
                if inter_ps:                   # single pod: no DCN wire
                    outs = tuple(ys[i].astype(xs_t[i].dtype)
                                 for i in range(len(names)))
                    return (outs, tuple(dummy for _ in names), dummy,
                            dummy)
                outs = []                      # inter "server"
                for i, k in enumerate(names):
                    ic = icodecs[k]
                    y = ys[i]
                    if lossy_x[k]:
                        # cross-tier codec codes the round DELTA vs the
                        # round-start reference (the int8 cell)
                        x0f = x0s_t[i].astype(jnp.float32)
                        d = y - x0f
                        if chunked_x[k]:
                            rows = d.reshape(-1, ic.chunk)
                            out = ic.compress_rows(
                                rows, us_t[i].reshape(rows.shape))
                            y = x0f + out.reshape(d.shape)
                        else:
                            y = x0f + ic.compress(d, {})[0]
                    m = jax.lax.psum(lw_l[:, None] * y, gax) / nlive_l
                    y = jnp.where(act_l[:, None] > 0, m, y)
                    outs.append(y.astype(xs_t[i].dtype))
                return (tuple(outs), tuple(dummy for _ in names), dummy,
                        dummy)

            x0s = tuple(xs0.get(k, xs[k]) for k in names)  # dummy when
            # the stream's inter codec is not lossy (never read inside)
            f = shard_map(local, mesh=self.mesh,
                          in_specs=((spec,) * len(names),
                                    (spec,) * len(names),
                                    tuple(us_specs), tuple(bl_specs),
                                    gspec, masksA_spec, delivA_spec,
                                    denA_spec, mass_spec, blw_spec,
                                    pvec_spec, pmat_spec, pmat_spec,
                                    lead_spec, P()),
                          out_specs=((spec,) * len(names),
                                     tuple(bl_specs), mass_spec,
                                     blw_spec),
                          check_rep=False)
            mixed_t, new_bl, new_mass, new_blw = f(
                tuple(xs[k] for k in names), x0s, tuple(us), tuple(bls),
                act_i, masksA, delivA, denA, mass, blw, act_pod, incsB,
                masksB, lead_w, n_live)
            mixed = dict(zip(names, mixed_t))
            if ps_on:
                backlog = dict(comm_state["backlog"])
                backlog.update(dict(zip(names, new_bl)))
                new_state["mass"] = new_mass
                new_state["backlog"] = backlog
                new_state["backlog_w"] = new_blw
            if touched:
                new_state["codec"] = cstates
            n_is = exch._intra_send_count()
            n_xs = exch._inter_send_count()
            tot = n_is + n_xs
            new_state["round"] = rnd + 1
            new_state["participation"] = (
                (part_intra * n_is + part_inter * n_xs) / tot if tot > 0
                else jnp.ones((), jnp.float32))
            new_state["participation_intra"] = part_intra
            new_state["participation_inter"] = part_inter
            return mixed, new_state

        return fn

    def encode_streams(self, exch, layout: packing.Layout):
        """shard_map'd ``Exchange.encode_streams`` (overlap mode,
        DESIGN.md §14): codec-encode every stream ONCE on its local
        (1, shard) block — no mixing, no group-axis collectives —
        producing the decoded payload the overlap round puts in flight.
        Codec handling matches ``exchange_streams``: int8-family noise
        is generated OUTSIDE the block at the full rows shape (each
        device consumes its slice — bit-identical to the replicated
        encode); topk uses the distributed threshold selection with its
        shard-local EF residual (psum'd bisection over the shard axes
        only — mechanism kept intact although ``get_exchange`` refuses
        overlap x topk as unstable, DESIGN.md §14 refusal matrix).
        Returns ``fn(xs, xs0, comm_state) -> (x_hat,
        new_comm_state)``."""
        for c in (exch.codec, exch.mcodec):
            if not (c.shardable or c.identity):
                raise NotImplementedError(
                    f"codec {c.name!r} is not shardable — run it on the "
                    "replicated path (DESIGN.md §9)")
            if (not c.identity) and c.chunk > 0:
                self.check_layout(layout, c.chunk)
        self.check_layout(layout)
        spec = self.buf_spec()
        gax = self._entry(self.group_axes)
        sax = self._entry(self.shard_axes)
        G = self.n_groups
        shard_size = layout.shard_size
        dummy_spec = P(None, None)

        def compress_local(codec, y, ref, u):
            d = y - ref
            if codec.chunk > 0:
                rows = d.reshape(-1, codec.chunk)
                out = codec.compress_rows(rows, u.reshape(rows.shape))
                return ref + out.reshape(d.shape)
            d_hat, _ = codec.compress(d, {})
            return ref + d_hat

        def fn(xs, xs0, comm_state):
            names = tuple(xs)
            codecs = {k: exch.stream_codec(k) for k in names}
            lossy = {k: not codecs[k].identity for k in names}
            chunked = {k: lossy[k] and codecs[k].chunk > 0 for k in names}
            selective = {k: lossy[k] and codecs[k].topk_frac > 0
                         for k in names}
            k_sel = {k: max(1, int(round(codecs[k].topk_frac
                                         * layout.padded)))
                     for k in names if selective[k]}
            new_state = dict(comm_state)
            cstates = dict(comm_state.get("codec", {}))

            def local(xs_t, x0s_t, us_t, res_t):
                outs, new_res = [], []
                for i, k in enumerate(names):
                    codec, x, x0 = codecs[k], xs_t[i], x0s_t[i]
                    res = res_t[i]
                    if selective[k]:
                        c = (x - x0) + res
                        tau = self._topk_threshold(
                            jnp.abs(c)[0], k_sel[k], sax, shard_size)
                        d_hat, res = self._topk_select(c, tau)
                        y = x0 + d_hat
                    elif lossy[k]:
                        y = compress_local(codec, x, x0,
                                           us_t[i] if chunked[k]
                                           else None)
                    else:
                        y = x
                    outs.append(y)
                    new_res.append(res)
                return tuple(outs), tuple(new_res)

            dummy = jnp.zeros((1, 1), jnp.float32)
            us, us_specs = [], []
            for k in names:
                if not chunked[k]:
                    us.append(dummy)
                    us_specs.append(dummy_spec)
                    continue
                chunk = codecs[k].chunk
                cnt = comm_state["codec"][k]["count"]
                rows_shape = (G * layout.padded // chunk, chunk)
                us.append(codecs[k].noise(cnt, rows_shape)
                          .reshape(G, -1, chunk))
                us_specs.append(P(gax, sax, None))
                cstates[k] = {"count": cnt + 1}
            res, res_specs = [], []
            for k in names:
                if not selective[k]:
                    res.append(dummy)
                    res_specs.append(dummy_spec)
                    continue
                res.append(comm_state["codec"][k]["residual"])
                res_specs.append(spec)
            x0s = tuple(xs0.get(k, xs[k]) for k in names)  # dummy when
            # the stream is not lossy (never read inside the block)
            f = shard_map(local, mesh=self.mesh,
                          in_specs=((spec,) * len(names),
                                    (spec,) * len(names),
                                    tuple(us_specs), tuple(res_specs)),
                          out_specs=((spec,) * len(names),
                                     tuple(res_specs)),
                          check_rep=False)
            out_t, new_res = f(tuple(xs[k] for k in names), x0s,
                               tuple(us), tuple(res))
            for i, k in enumerate(names):
                if selective[k]:
                    cstates[k] = {"residual": new_res[i]}
            if any(chunked.values()) or any(selective.values()):
                new_state["codec"] = cstates
            return dict(zip(names, out_t)), new_state

        return fn

    def exchange(self, exch, layout: packing.Layout):
        """Single-stream convenience wrapper over ``exchange_streams``:
        (x_G, x0_G, comm_state) -> (mixed_x_G, new_comm_state) for the
        params buffer only (the pre-§10 signature, kept for tests)."""
        fn = self.exchange_streams(exch, layout)

        def one(x_G, x0_G, comm_state):
            xs0 = {} if x0_G is None else {"params": x0_G}
            mixed, new_state = fn({"params": x_G}, xs0, comm_state)
            return mixed["params"], new_state

        return one


def plan_for(mesh: Mesh, require: bool = False,
             hop_impl: str = "ppermute") -> Optional[ShardExec]:
    """The mesh's sharded-execution plan, or None when no in-group axis
    has more than one device (the replicated path is then both correct
    and free — nothing to shard over). ``hop_impl`` selects the
    ring/gossip hop collective (DESIGN.md §11)."""
    shard_axes = tuple(a for a in SHARD_AXES
                       if a in mesh.axis_names and mesh.shape[a] > 1)
    if not shard_axes:
        if require:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has no in-group axis "
                f"({'/'.join(SHARD_AXES)}) larger than 1 to shard the "
                "packed buffer over")
        return None
    group_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardExec(mesh=mesh, group_axes=group_axes,
                     shard_axes=shard_axes, hop_impl=hop_impl)
