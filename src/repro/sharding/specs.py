"""Divisibility-aware PartitionSpec resolution from ParamDef logical axes.

Rules (see DESIGN.md): the first logical axis on each tensor that (a) has a
mesh rule and (b) is divisible by the mesh axis size gets sharded; remaining
axes are replicated. Leading group axes (local-SGD replicas) are added via
``leading``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamDef, is_pdef

# logical axis -> mesh axis.  "embed" shards over the optional "fsdp" axis
# (group-internal fully-sharded data parallelism, §Perf hillclimb) — inert
# on meshes without that axis.
RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "inner": "model",
    "embed": "fsdp",
}


def spec_for(d: ParamDef, mesh: Mesh, leading: Tuple[str, ...] = (),
             policy: str = "tp") -> P:
    """PartitionSpec for one ParamDef. ``leading`` names the mesh axes the
    single extra leading dim (the local-SGD G axis) shards over — one spec
    entry that may be a tuple of mesh axes, e.g. ("pod", "data").

    policy:
      tp    tensor parallel (default): first divisible logical axis per
            mesh axis gets sharded (model; plus fsdp if the mesh has it)
      dp    replicate all params (batch shards over "model" instead —
            the right layout for small archs where TP all-reduces of
            seq-length activations dwarf the matmuls)
    """
    if leading:
        entries = [leading[0] if len(leading) == 1 else tuple(leading)]
    else:
        entries = []
    if policy == "dp":
        return P(*entries) if entries else P()
    used = set()
    for i, ax in enumerate(d.axes):
        mesh_ax = RULES.get(ax)
        size = d.shape[i]
        if (mesh_ax and mesh_ax not in used and mesh_ax in mesh.axis_names
                and size % mesh.shape[mesh_ax] == 0 and size > 0
                and mesh.shape[mesh_ax] > 1):
            entries.append(mesh_ax)
            used.add(mesh_ax)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def resolve_specs(defs, mesh: Mesh, leading: Tuple[str, ...] = (),
                  policy: str = "tp"):
    """PartitionSpec tree matching a ParamDef tree."""
    return jax.tree.map(lambda d: spec_for(d, mesh, leading, policy), defs,
                        is_leaf=is_pdef)


def shardings(defs, mesh: Mesh, leading: Tuple[str, ...] = (),
              policy: str = "tp"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        resolve_specs(defs, mesh, leading, policy),
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_specs, opt_state_keys=("count", "m", "v"),
                    group_leading: Tuple[str, ...] = ()):
    """Optimizer state specs: moment trees mirror the param specs; the step
    counter is replicated (or group-sharded when a leading G axis exists)."""
    out = {}
    for k in opt_state_keys:
        if k == "count":
            out[k] = P(group_leading) if group_leading else P()
        else:
            out[k] = param_specs
    return out


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def serve_batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes a serving batch shards over: no local-SGD groups exist in
    prefill/decode, so the fsdp axis (if any) joins the data axes."""
    return tuple(a for a in ("pod", "data", "fsdp")
                 if a in mesh.axis_names and mesh.shape[a] > 1)


def n_groups(mesh: Mesh) -> int:
    total = 1
    for a in dp_axes(mesh):
        total *= mesh.shape[a]
    return total


def batch_spec(mesh: Mesh, batch_size: int, leading_group: bool) -> P:
    """Spec for data batches. leading_group: first axis is the G axis
    (always sharded over pod+data); otherwise (serve paths) the batch
    axis shards over pod+data+fsdp when divisible, else stays replicated
    (e.g. batch=1)."""
    if leading_group:
        return P(dp_axes(mesh))
    axes = serve_batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if axes and total > 1 and batch_size % total == 0:
        return P(axes)
    return P()
