"""Pytree checkpointing: npz tensors + json metadata (paths keep the tree
structure via '/'-joined keys)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


# npz only understands native numpy dtypes; ml_dtypes (bfloat16, fp8)
# round-trip through a bit-compatible integer view + a dtype sidecar key.
_NATIVE = set("?bhilqBHILQefdgFD")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.char not in _NATIVE:
            flat[key + "::dtype"] = np.array(str(arr.dtype))
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        flat[key] = arr
    return flat


def save(path: str, tree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path + ".npz", **_flatten(tree))
    with open(path + ".json", "w") as f:
        json.dump(metadata or {}, f, indent=2, default=str)


def load(path: str, like) -> Any:
    """Restore into the structure of ``like`` (a matching pytree)."""
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

    data = np.load(path + ".npz")
    flat = dict(data)
    keys = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        keys.append("/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                             for q in p))
    leaves = []
    for k in keys:
        arr = flat[k]
        if k + "::dtype" in flat:
            arr = arr.view(np.dtype(str(flat[k + "::dtype"])))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f)
