"""Parameter packing: pytree <-> one contiguous flat f32 buffer.

The T local steps between communications are the hot path of the paper's
algorithm (Alg 1): every inner step updates every parameter. Running that
loop leaf-by-leaf costs one HLO fusion chain per leaf per step; packing the
whole tree into a single flat float32 buffer lets the update run as ONE
fused pass (a Pallas kernel on TPU, one XLA fusion on CPU) and the
per-round server averaging lower to a single flat all-reduce.

Layout contract (see DESIGN.md §6): a ``Layout`` is a static description —
leaf order is the treedef flatten order; leaf i occupies
``buf[offsets[i]:offsets[i]+sizes[i]]`` reshaped to ``shapes[i]`` and cast
to ``dtypes[i]``. The buffer dtype is always float32. Leading batch axes
(the local-SGD G axis) stack as leading buffer axes: a G-grouped tree packs
to ``(G, size)``.

``unpack`` uses static slices (views inside an XLA fusion — no copy);
``pack`` is one concatenate. Gradients w.r.t. the packed buffer are taken
per-leaf and packed, NOT by differentiating through ``unpack`` — the
transpose of a slice is a pad-to-N scatter, which would materialize one
full-size buffer per leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static flat-buffer layout for one parameter pytree."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    size: int                      # total number of f32 elements

    @property
    def padded(self) -> int:
        """Buffer length including trailing zero padding (== size here;
        ShardedLayout pads to a shard/chunk multiple)."""
        return self.size

    def abstract(self, leading: Tuple[int, ...] = ()):
        """ShapeDtypeStruct of the packed buffer (with leading axes)."""
        return jax.ShapeDtypeStruct(tuple(leading) + (self.padded,),
                                    jnp.float32)


@dataclasses.dataclass(frozen=True)
class ShardedLayout(Layout):
    """Shard-aware Layout (DESIGN.md §9): the buffer is zero-padded to
    ``pad_to`` — a multiple of ``n_shards * align`` — so it splits evenly
    into ``n_shards`` equal in-group shards AND every shard holds whole
    ``align``-element codec chunks (int8 per-chunk scales stay shard-local;
    no scale ever straddles a device boundary).

    The padding is invisible to ``unpack`` (static slices stop at ``size``)
    and inert under every packed optimizer: zero params with zero grads and
    zero moments stay exactly zero through sgd/momentum/adamw, quantize to
    zero, and average to zero — so the pad region never leaks into real
    elements."""
    n_shards: int = 1
    align: int = 1
    pad_to: int = 0

    @property
    def padded(self) -> int:
        return self.pad_to

    @property
    def shard_size(self) -> int:
        return self.pad_to // self.n_shards


def shard_layout(layout: Layout, n_shards: int,
                 align: int = 256) -> ShardedLayout:
    """Pad a Layout for ``n_shards``-way in-group sharding.

    align: chunk quantum every shard must hold whole multiples of —
    defaults to the int8 codec's chunk (256) so the SAME padded geometry
    serves every codec (the few KiB of zero pad is noise next to N)."""
    assert n_shards >= 1 and align >= 1, (n_shards, align)
    q = n_shards * align
    pad_to = q * ((layout.size + q - 1) // q)
    return ShardedLayout(layout.treedef, layout.shapes, layout.dtypes,
                         layout.offsets, layout.sizes, layout.size,
                         n_shards=n_shards, align=align, pad_to=pad_to)


@dataclasses.dataclass(frozen=True)
class StreamLayout:
    """Named streams over ONE buffer geometry (DESIGN.md §10).

    A packed train state is several flat buffers that all share the params
    Layout: the params themselves plus the optimizer's moment buffers
    (momentum ``mu``, adamw ``m``/``v``). A StreamLayout names them —
    ``streams[0]`` is always ``"params"``, the rest are the optimizer's
    ``moment_keys`` — so every layer (codecs, wire accounting, staleness
    buffers, checkpoints) can address "the payload" per stream instead of
    special-casing params vs opaque opt state.

    Each stream is a ``(..., base.padded)`` f32 buffer; ``stack`` gives
    the one ``(S, ..., padded)`` stacked view fused whole-payload kernels
    and codecs can consume (streams share chunk alignment, so per-chunk
    codec metadata stays stream-local in the stacked view too).
    """
    base: Layout
    streams: Tuple[str, ...]

    def __post_init__(self):
        assert self.streams and self.streams[0] == "params", self.streams
        assert len(set(self.streams)) == len(self.streams), self.streams

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    @property
    def moment_streams(self) -> Tuple[str, ...]:
        return self.streams[1:]

    def index(self, name: str) -> int:
        return self.streams.index(name)

    def sizes(self) -> dict:
        """Per-stream wire element count (the buffer IS the wire format,
        padding included — same rule as the params stream)."""
        return {name: self.base.padded for name in self.streams}

    def abstract(self, leading: Tuple[int, ...] = ()) -> dict:
        return {name: self.base.abstract(leading) for name in self.streams}

    def stack(self, bufs: dict) -> jax.Array:
        """{name: (..., padded)} -> one (S, ..., padded) stacked view."""
        return jnp.stack([bufs[name] for name in self.streams])

    def unstack(self, stacked: jax.Array) -> dict:
        assert stacked.shape[0] == self.n_streams, stacked.shape
        return {name: stacked[i] for i, name in enumerate(self.streams)}


def stream_layout_for(opt, layout: Layout) -> StreamLayout:
    """StreamLayout of a packed optimizer's state on ``layout``: params
    plus the optimizer's declared moment streams (``opt.moment_keys``)."""
    return StreamLayout(layout, ("params",) + tuple(opt.moment_keys))


# XLA's packed-round lowering addresses the (G, Np) state buffers with
# int32 linear indices; a buffer past this limit dies mid-lower with a
# bare "Python int ... too large to convert to int32" (the billion-param
# dryrun overflow noted in PR 3) — refuse up front with the limit stated
INT32_INDEX_MAX = 2**31 - 1


def check_packed_index_space(layout: Layout, n_groups: int = 1) -> None:
    """Refuse packed layouts whose (n_groups, padded) state buffers
    overflow XLA's int32 index space (see INT32_INDEX_MAX)."""
    total = n_groups * layout.padded
    if total > INT32_INDEX_MAX:
        raise NotImplementedError(
            f"packed state buffer ({n_groups} group(s) x {layout.padded:,}"
            f" f32 elements = {total:,}) exceeds the int32 index space "
            f"(2**31-1 = {INT32_INDEX_MAX:,}) XLA's packed-round lowering "
            "uses — lowering would die with an int32 OverflowError. Run "
            "billion-param configs on the per-leaf pytree path (each leaf "
            "stays under the limit), or reduce the model / group count.")


def layout_of(tree) -> Layout:
    """Build the static layout from a pytree of arrays/ShapeDtypeStructs."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return Layout(treedef, shapes, dtypes, offsets, sizes,
                  int(sum(sizes)))


def pack(tree, layout: Layout) -> jax.Array:
    """Flatten a pytree into the contiguous f32 buffer.

    Leaves may carry extra leading axes (all identical, e.g. the local-SGD
    G axis); they become leading axes of the buffer.
    """
    leaves = layout.treedef.flatten_up_to(tree)
    lead = leaves[0].shape[:leaves[0].ndim - len(layout.shapes[0])]
    flat = [l.reshape(lead + (-1,)).astype(jnp.float32) for l in leaves]
    buf = jnp.concatenate(flat, axis=-1)
    pad = layout.padded - layout.size
    if pad:
        buf = jnp.pad(buf, [(0, 0)] * (buf.ndim - 1) + [(0, pad)])
    return buf


def unpack(buf: jax.Array, layout: Layout):
    """Rebuild the pytree (original shapes/dtypes) from the flat buffer.

    Extra leading axes on ``buf`` are carried onto every leaf. Slicing is
    static, so XLA reads leaves as views of the buffer inside fusions.
    """
    lead = buf.shape[:-1]
    leaves = [
        buf[..., o:o + s].reshape(lead + sh).astype(dt)
        for o, s, sh, dt in zip(layout.offsets, layout.sizes,
                                layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def chunk_rows(x: jax.Array, chunk: int) -> jax.Array:
    """(..., N) buffer -> (rows, chunk) 2-D view for per-chunk codecs.

    The flat buffer doubles as the WIRE format (repro.comm, DESIGN.md §8):
    codecs that carry per-chunk metadata (int8 scales) see the buffer as
    rows of ``chunk`` f32 elements, zero-padded to a chunk multiple —
    zeros quantize to zero, so padding never leaks into the payload."""
    n = x.shape[-1]
    pad = (-n) % chunk
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(-1, chunk)


def pad_rows(x: jax.Array, row: int) -> jax.Array:
    """(..., N) buffer -> (..., n_rows, row) zero-padded 2-D view.

    ``chunk_rows`` for callers that must KEEP the leading axes: the serve
    engine (repro.serve) scatters each batch slot's packed recurrent
    state into its own rows of the paged pool, so the row split may not
    flatten the slot axis away."""
    n = x.shape[-1]
    pad = (-n) % row
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (-1, row))


def unchunk_rows(rows: jax.Array, shape) -> jax.Array:
    """Invert ``chunk_rows``: (rows, chunk) back to the ``shape`` buffer
    (the zero padding on the last axis is sliced off)."""
    lead = tuple(shape[:-1])
    return rows.reshape(lead + (-1,))[..., :shape[-1]]


def value_and_flat_grad(loss_fn, layout: Layout):
    """``vg(buf, batch) -> (loss, flat_grad)`` for a pytree loss.

    Differentiates w.r.t. the UNPACKED tree and packs the grads (one
    concatenate) — never w.r.t. the buffer itself (see module docstring).
    """
    vg = jax.value_and_grad(loss_fn)

    def flat_vg(buf, batch):
        loss, g_tree = vg(unpack(buf, layout), batch)
        return loss, pack(g_tree, layout)

    return flat_vg
