"""Optimizers (built here — no external dependency).

API: ``opt = sgd(lr)``; ``state = opt.init(params)``;
``new_params, new_state = opt.step(params, grads, state)``.
All tree-structured state mirrors the param tree so the same PartitionSpecs
apply (plus replicated scalars).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    step: Callable[[Any, Any, Any], tuple]
    name: str = "opt"
    # Packed fast path (see optim.packing / DESIGN.md §6): params and grads
    # are flat f32 buffers of shape (..., N) instead of pytrees, and the
    # whole update is one fused pass. impl: "pallas" (fused kernels) or
    # "jnp" (one XLA fusion — the CPU fallback).
    packed: bool = False
    impl: str = "jnp"
    # Update depends on the step counter (adamw bias correction, lr
    # schedules). The packed round normally keeps ONE shared scalar count;
    # under per-node t_i, count-dependent packed updates run vmapped over
    # G with a per-group count vector instead (DESIGN.md §10).
    count_dependent: bool = False
    # Named moment STREAMS of the state (everything but the shared step
    # counter), in a fixed order. This is the multi-stream payload
    # contract (DESIGN.md §10): packed state is {"count"} + one flat
    # buffer per stream, each the same shape as the params buffer, so
    # comm codecs / staleness buffers / wire accounting address moments
    # by stream name instead of treating opt state as opaque.
    moment_keys: Tuple[str, ...] = ()
    # Streams that must stay >= 0 (adamw's second moment: sqrt(v) NaNs on
    # the slightly-negative values a lossy delta codec can decode). The
    # round projects these back onto [0, inf) after a LOSSY moment
    # exchange; identity moment codecs never touch them (bit-exactness).
    moment_nonneg: Tuple[str, ...] = ()


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
        return new, {"count": state["count"] + 1}

    return Optimizer(init, step, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(jnp.zeros_like, params)}

    def step(params, grads, state):
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype),
                          state["mu"], grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, step, "momentum", moment_keys=("mu",))


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params)}

    def step(params, grads, state):
        c = state["count"] + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(p.dtype)
            m_ = b1 * m + (1 - b1) * g
            v_ = b2 * v + (1 - b2) * jnp.square(g)
            upd_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return p - lr * (upd_ + weight_decay * p), m_, v_

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        outs = [upd(p, g, m, v) for p, g, m, v in
                zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(td, [o[0] for o in outs])
        new_m = jax.tree.unflatten(td, [o[1] for o in outs])
        new_v = jax.tree.unflatten(td, [o[2] for o in outs])
        return new_p, {"count": c, "m": new_m, "v": new_v}

    return Optimizer(init, step, "adamw", count_dependent=True,
                     moment_keys=("m", "v"), moment_nonneg=("v",))


# ---------------------------------------------------------------------------
# Packed fast path: flat f32 buffers + fused update kernels
# ---------------------------------------------------------------------------
#
# The T-step local loop is the paper's hot path. ``packed(name, lr)`` builds
# an optimizer whose params/grads are single contiguous f32 buffers (see
# optim.packing for the layout contract): the whole per-step update runs as
# one fused Pallas kernel (TPU) or one XLA fusion (CPU fallback), instead
# of ~10 element-wise HLO ops per pytree leaf. Buffers may carry leading
# axes (the local-SGD G axis); the update is element-wise so they are
# raveled through the kernels and reshaped back.


def _resolve_impl(impl: str) -> str:
    from repro.kernels import resolve_impl
    return resolve_impl(impl)


def map_moments(f, opt_state):
    """Apply ``f`` to the moment buffers of a packed opt state, leaving
    the shared scalar step counter untouched — the "'count' is the only
    shared scalar" convention. Replication and averaging go through here;
    the t_i mask in localsgd keeps the same convention inline (it needs
    old and new values per key)."""
    return {k: (v if k == "count" else f(v)) for k, v in opt_state.items()}


def _raveled(fn, *bufs):
    """Run a flat-kernel fn over arbitrarily-leading-axed buffers."""
    shape = bufs[0].shape
    out = fn(*(b.reshape(-1) for b in bufs))
    if isinstance(out, tuple):
        return tuple(o.reshape(shape) for o in out)
    return out.reshape(shape)


def packed_sgd(lr: float, *, impl: str = "auto") -> Optimizer:
    impl = _resolve_impl(impl)

    def init(buf):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(buf, grads, state):
        if impl == "pallas":
            from repro.kernels import use_interpret
            from repro.kernels.fused_sgd import fused_sgd
            new = _raveled(
                lambda p, g: fused_sgd(p, g, lr=lr,
                                       interpret=use_interpret()),
                buf, grads)
        else:
            new = buf - lr * grads
        return new, {"count": state["count"] + 1}

    return Optimizer(init, step, "sgd", packed=True, impl=impl)


def packed_momentum(lr: float, beta: float = 0.9, *,
                    impl: str = "auto") -> Optimizer:
    impl = _resolve_impl(impl)

    def init(buf):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jnp.zeros_like(buf)}

    def step(buf, grads, state):
        if impl == "pallas":
            from repro.kernels import use_interpret
            from repro.kernels.fused_momentum import fused_momentum
            new, mu = _raveled(
                lambda p, g, m: fused_momentum(
                    p, g, m, lr=lr, beta=beta, interpret=use_interpret()),
                buf, grads, state["mu"])
        else:
            mu = beta * state["mu"] + grads
            new = buf - lr * mu
        return new, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, step, "momentum", packed=True, impl=impl,
                     moment_keys=("mu",))


def packed_adamw(lr: float, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8, weight_decay: float = 0.0, *,
                 impl: str = "auto") -> Optimizer:
    impl = _resolve_impl(impl)

    def init(buf):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jnp.zeros_like(buf),
                "v": jnp.zeros_like(buf)}

    def step(buf, grads, state):
        c = state["count"] + 1
        if impl == "pallas":
            from repro.kernels import use_interpret
            from repro.kernels.fused_adamw import fused_adamw
            new, m, v = _raveled(
                lambda p, g, m, v: fused_adamw(
                    p, g, m, v, count=c, lr=lr, b1=b1, b2=b2, eps=eps,
                    wd=weight_decay, interpret=use_interpret()),
                buf, grads, state["m"], state["v"])
        else:
            # Same math as the per-leaf adamw (bias correction unfolded)
            # so the packed path is bit-compatible up to fma reassociation.
            bc1 = 1.0 - b1 ** c.astype(jnp.float32)
            bc2 = 1.0 - b2 ** c.astype(jnp.float32)
            m = b1 * state["m"] + (1 - b1) * grads
            v = b2 * state["v"] + (1 - b2) * jnp.square(grads)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new = buf - lr * (upd + weight_decay * buf)
        return new, {"count": c, "m": m, "v": v}

    return Optimizer(init, step, "adamw", packed=True, impl=impl,
                     count_dependent=True, moment_keys=("m", "v"),
                     moment_nonneg=("v",))


_PACKED = {"sgd": packed_sgd, "momentum": packed_momentum,
           "adamw": packed_adamw}


def packed(name: str, lr: float, *, impl: str = "auto", **kw) -> Optimizer:
    """Packed (flat-buffer, fused-kernel) variant of a base optimizer."""
    return _PACKED[name](lr, impl=impl, **kw)


# ---------------------------------------------------------------------------
# Composable transforms: global-norm clipping + lr schedules
# ---------------------------------------------------------------------------


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer so grads are clipped to a global L2 norm first.

    Works for packed optimizers too: their grad buffer may carry leading
    group axes, so the norm is taken over the model (last) axis only —
    one norm per group, matching the pytree round's per-group clipping.
    ``dataclasses.replace`` keeps the packed/impl routing flags."""

    if opt.packed:
        def step(buf, grads, state):
            gn = jnp.sqrt(jnp.sum(jnp.square(grads.astype(jnp.float32)),
                                  axis=-1, keepdims=True))
            scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
            return opt.step(buf, grads * scale.astype(grads.dtype), state)
    else:
        def step(params, grads, state):
            leaves = jax.tree.leaves(grads)
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in leaves))
            scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
            clipped = jax.tree.map(lambda g: g * scale.astype(g.dtype),
                                   grads)
            return opt.step(params, clipped, state)

    return dataclasses.replace(opt, step=step, name=opt.name + "+clip")


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    """lr(count): linear warmup then cosine decay to min_frac*base_lr."""

    def lr_fn(count):
        c = jnp.asarray(count, jnp.float32)
        warm = base_lr * (c + 1.0) / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)

    return lr_fn


def with_schedule(make_opt: Callable[[float], Optimizer], lr_fn) -> Optimizer:
    """Optimizer whose lr follows lr_fn(state['count']).

    Implemented by scaling the unit-lr update: requires the base update to
    be linear in lr (true for sgd/momentum; adamw's bias-corrected update
    direction is lr-independent, so scaling is exact there too)."""
    unit = make_opt(1.0)

    def step(params, grads, state):
        lr = lr_fn(state["count"])
        new_p, new_s = unit.step(params, grads, state)
        scaled = jax.tree.map(
            lambda n, p: p + lr.astype(p.dtype) * (n - p), new_p, params)
        return scaled, new_s

    # replace() keeps the packed/impl routing flags of packed optimizers;
    # a schedule makes the update count-dependent by definition
    return dataclasses.replace(unit, step=step, name=unit.name + "+sched",
                               count_dependent=True)


def get(name: str, lr: float, *, packed: bool = False, **kw) -> Optimizer:
    table = _PACKED if packed else {"sgd": sgd, "momentum": momentum,
                                    "adamw": adamw}
    if name not in table:
        raise ValueError(f"unknown optimizer {name!r} (have {sorted(table)}"
                         f", packed={packed})")
    if not packed and "impl" in kw:
        # a clear refusal, not a TypeError (and never a silent fallback):
        # the fused Pallas kernels exist only on the flat-buffer path
        raise ValueError(
            f"impl={kw['impl']!r} selects the fused-kernel path, which "
            "only exists for packed optimizers — pass packed=True (the "
            "pytree optimizers have no Pallas implementation)")
    return table[name](lr, **kw)
