"""Optimizers (built here — no external dependency).

API: ``opt = sgd(lr)``; ``state = opt.init(params)``;
``new_params, new_state = opt.step(params, grads, state)``.
All tree-structured state mirrors the param tree so the same PartitionSpecs
apply (plus replicated scalars).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    step: Callable[[Any, Any, Any], tuple]
    name: str = "opt"


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
        return new, {"count": state["count"] + 1}

    return Optimizer(init, step, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(jnp.zeros_like, params)}

    def step(params, grads, state):
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype),
                          state["mu"], grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, step, "momentum")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params)}

    def step(params, grads, state):
        c = state["count"] + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(p.dtype)
            m_ = b1 * m + (1 - b1) * g
            v_ = b2 * v + (1 - b2) * jnp.square(g)
            upd_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return p - lr * (upd_ + weight_decay * p), m_, v_

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        outs = [upd(p, g, m, v) for p, g, m, v in
                zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(td, [o[0] for o in outs])
        new_m = jax.tree.unflatten(td, [o[1] for o in outs])
        new_v = jax.tree.unflatten(td, [o[2] for o in outs])
        return new_p, {"count": c, "m": new_m, "v": new_v}

    return Optimizer(init, step, "adamw")


# ---------------------------------------------------------------------------
# Composable transforms: global-norm clipping + lr schedules
# ---------------------------------------------------------------------------


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer so grads are clipped to a global L2 norm first."""

    def step(params, grads, state):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
        clipped = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.step(params, clipped, state)

    return Optimizer(opt.init, step, opt.name + "+clip")


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    """lr(count): linear warmup then cosine decay to min_frac*base_lr."""

    def lr_fn(count):
        c = jnp.asarray(count, jnp.float32)
        warm = base_lr * (c + 1.0) / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)

    return lr_fn


def with_schedule(make_opt: Callable[[float], Optimizer], lr_fn) -> Optimizer:
    """Optimizer whose lr follows lr_fn(state['count']).

    Implemented by scaling the unit-lr update: requires the base update to
    be linear in lr (true for sgd/momentum; adamw's bias-corrected update
    direction is lr-independent, so scaling is exact there too)."""
    unit = make_opt(1.0)

    def step(params, grads, state):
        lr = lr_fn(state["count"])
        new_p, new_s = unit.step(params, grads, state)
        scaled = jax.tree.map(
            lambda n, p: p + lr.astype(p.dtype) * (n - p), new_p, params)
        return scaled, new_s

    return Optimizer(unit.init, step, unit.name + "+sched")


def get(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](lr, **kw)
