"""Fixed-shape jit decode/prefill programs over the paged pool.

One compiled ``step`` per (config, batch geometry) serves EVERY decode
step of the engine's life: admissions and retirements never change a
shape. The scheduler ships plain arrays each call — tokens (B,),
per-slot positions (B,), page tables (B, layers_kv, max_blocks), state
rows (B, state_rows), and an ``active`` mask (B,) — and inactive slots
run the same program against the trash page (pos 0, length 1, rows 0):
finite garbage, never read by an active slot (every op in every family's
decode path is batch-elementwise over slots, which is what makes the
continuous-vs-isolated parity tests exact).

Per family:
  dense/moe  per-layer paged KV; attention through the decode kernel
             (``kernels/decode_attention``, impl-resolved pallas/jnp);
             batched prefill (one ``attention_forward`` pass per layer,
             right-padded to the prompt bucket — causal-safe) scatters
             whole pages.
  hybrid     mamba state rows + the zamba2 SHARED attention block's
             n_attn paged KV tables; prefill is a masked scan of the
             same per-token core (recurrence is inherently stepwise), so
             prefill-vs-stepwise parity is exact by construction.
  ssm        pure state rows (no KV pages); the model's own decode_fn is
             the token core; masked-scan prefill likewise.
  vlm/audio  REFUSED: their decode needs modality extras (patches /
             encoder frames) outside the token-slot contract.

Recurrent state lives in the pool as packed flat buffers (one
``optim/packing`` Layout per config, slot-major) — freed pages are
recycled dirty, so prefill starts from a zeros buffer, never from the
pool.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as da
from repro.kernels import resolve_impl, use_interpret
from repro.models import api as mapi
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import xlstm as xl
from repro.models.layers import rms_norm
from repro.optim.packing import Layout, layout_of, pack, unpack
from repro.serve.paging import (PageGeom, make_geom, read_state,
                                write_prefill_kv, write_state,
                                write_token_kv)

SERVE_FAMILIES = ("dense", "moe", "hybrid", "ssm")


def _refuse(fam: str):
    raise NotImplementedError(
        f"serve does not support family {fam!r}: its decode path needs "
        "per-request modality inputs (vlm patches / audio encoder frames) "
        "outside the engine's token-slot contract — serve a "
        "dense/moe/hybrid/ssm config instead, or drive this family's "
        "generation directly through Model.decode_step (static batch, "
        "no scheduler)")


def _greedy(logits, vocab: int):
    """(B, padded_vocab) f32 -> (B,) int32 greedy tokens (pad vocab
    entries excluded)."""
    return jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)


def _logits(params, x, cfg):
    """Final norm + LM head on the single decode position: x (B,1,D) ->
    (B, padded_vocab) f32."""
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return out.astype(jnp.float32)[:, 0]


# ---------------------------------------------------------------------------
# State layouts (recurrent families)
# ---------------------------------------------------------------------------


def state_layout_for(model) -> Optional[Layout]:
    """packing.Layout of ONE slot's recurrent-state pytree (no batch
    axis; packs with a leading B axis to (B, size)). None for pure-KV
    families."""
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return None
    if fam == "hybrid":
        mc = mam.mamba_cache_shapes(cfg, 1, dtype)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape[1:],
                                           s.dtype), mc)
        return layout_of(spec)
    if fam == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        ms = xl.mlstm_cache_shapes(cfg, 1, dtype)
        ss = xl.slstm_cache_shapes(cfg, 1, dtype)
        spec = {
            "mlstm": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (n_groups, n_m) + s.shape[1:], s.dtype), ms),
            "slstm": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (n_groups,) + s.shape[1:], s.dtype), ss),
        }
        return layout_of(spec)
    _refuse(fam)


def _to_slot_major(fam, tree):
    """Device-cache axis order -> slot-major (B leading on every leaf),
    so each slot's state is one contiguous packed buffer."""
    if fam == "hybrid":                       # (L, B, ...) -> (B, L, ...)
        return jax.tree.map(lambda l: jnp.moveaxis(l, 1, 0), tree)
    return {"mlstm": jax.tree.map(lambda l: jnp.moveaxis(l, 2, 0),
                                  tree["mlstm"]),
            "slstm": jax.tree.map(lambda l: jnp.moveaxis(l, 1, 0),
                                  tree["slstm"])}


def _from_slot_major(fam, tree):
    if fam == "hybrid":                       # (B, L, ...) -> (L, B, ...)
        return jax.tree.map(lambda l: jnp.moveaxis(l, 0, 1), tree)
    return {"mlstm": jax.tree.map(lambda l: jnp.moveaxis(l, 0, 2),
                                  tree["mlstm"]),
            "slstm": jax.tree.map(lambda l: jnp.moveaxis(l, 0, 1),
                                  tree["slstm"])}


def _zero_state(fam, layout, batch: int):
    """Fresh per-slot state in device-cache axis order — from a zeros
    buffer, NEVER from the pool (freed rows are recycled dirty)."""
    return _from_slot_major(
        fam, unpack(jnp.zeros((batch, layout.size), jnp.float32), layout))


# ---------------------------------------------------------------------------
# Geometry / attention-impl resolution
# ---------------------------------------------------------------------------


def geom_for(model, *, n_slots: int, page_size: int, max_len: int,
             slack_slots: int = 0, n_pages: Optional[int] = None) -> PageGeom:
    cfg = model.cfg
    fam = cfg.family
    if fam not in SERVE_FAMILIES:
        _refuse(fam)
    layout = state_layout_for(model)
    if fam in ("dense", "moe"):
        n_layers_kv = cfg.n_layers
    elif fam == "hybrid":
        n_layers_kv = max(cfg.n_layers // cfg.attn_every, 1)
    else:
        n_layers_kv = 0
    return make_geom(
        page_size=page_size,
        n_kv=cfg.n_kv_heads if n_layers_kv else 0,
        head_dim=cfg.resolved_head_dim if n_layers_kv else 0,
        n_layers_kv=n_layers_kv, max_len=max_len,
        state_size=layout.size if layout is not None else 0,
        n_slots=n_slots, slack_slots=slack_slots, n_pages=n_pages)


def _make_attn(impl: str, geom: PageGeom):
    """Decode-attention callable, impl-resolved the same way as every
    other kernel front (kernels.resolve_impl): jnp reference off-TPU
    under "auto"; an explicit "pallas" on an unsupported backend raises."""
    impl = resolve_impl(impl)
    ps, n_kv = geom.page_size, geom.n_kv
    if impl == "pallas":
        interp = use_interpret()

        def f(q, pool, rk, rv, lengths):
            return da.paged_decode_attention(
                q, pool, rk, rv, lengths, page_size=ps, n_kv=n_kv,
                interpret=interp)
        return f

    def f(q, pool, rk, rv, lengths):
        return da.paged_decode_attention_ref(
            q, pool, rk, rv, lengths, page_size=ps, n_kv=n_kv)
    return f


# ---------------------------------------------------------------------------
# Per-family program builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Programs:
    """The two jit'd entry points the engine drives (pool donated):

    step(params, pool, tokens (B,), pos (B,), rows_k, rows_v
         (B, layers_kv, max_blocks), srows (B, state_rows), active (B,))
      -> (greedy tokens (B,) int32, pool)
    prefill(params, pool, tokens (1, P), length, rows_k, rows_v
            (layers_kv, max_blocks), srows (state_rows,))
      -> (first generated token (1,) int32, pool)

    Unused arguments per family (srows for dense, page tables for ssm)
    are accepted and ignored so the engine stays family-agnostic.
    """
    family: str
    geom: PageGeom
    state_layout: Optional[Layout]
    step: Callable
    prefill: Callable


def _build_decoder_programs(model, geom, attn_fn):
    cfg = model.cfg
    eps = cfg.norm_eps
    dtype = jnp.dtype(cfg.dtype)
    ps = geom.page_size

    def step(params, pool, tokens, pos, rows_k, rows_v, srows, active):
        B = tokens.shape[0]
        x = mapi._embed_lookup(params["embed"], tokens[:, None], dtype,
                               cfg.embed_impl)
        positions = pos[:, None]
        blk, off, lengths = pos // ps, pos % ps, pos + 1

        def layer(carry, inp):
            x, pool = carry
            p, rk, rv = inp
            h = rms_norm(x, p["norm1"], eps)
            q, k, v = attn.project_qkv(p["attn"], h, h, cfg, positions,
                                       positions, True)
            pool = write_token_kv(pool, rk, blk, off,
                                  k[:, 0].reshape(B, -1), active)
            pool = write_token_kv(pool, rv, blk, off,
                                  v[:, 0].reshape(B, -1), active)
            a = attn_fn(q[:, 0], pool, rk, rv, lengths)
            x = x + attn.output_proj(p["attn"], a[:, None].astype(x.dtype))
            h2 = rms_norm(x, p["norm2"], eps)
            if cfg.is_moe:
                y, _ = moem.moe_decode(p["moe"], h2, cfg)
            else:
                y = mlpm.mlp_forward(p["mlp"], h2, cfg)
            return (x + y, pool), None

        (x, pool), _ = jax.lax.scan(
            layer, (x, pool),
            (params["blocks"], jnp.moveaxis(rows_k, 1, 0),
             jnp.moveaxis(rows_v, 1, 0)))
        return _greedy(_logits(params, x, cfg), cfg.vocab_size), pool

    def prefill(params, pool, tokens, length, rows_k, rows_v, srows):
        # Batched prefill: prompt right-padded to the static bucket P
        # (a page multiple). Padding positions are causal-safe (real
        # token t only attends to indices <= t < length) and their page
        # garbage is hidden by length masking at decode time.
        _, P = tokens.shape
        nblk_p = P // ps
        x = mapi._embed_lookup(params["embed"], tokens, dtype,
                               cfg.embed_impl)

        def layer(carry, inp):
            x, pool = carry
            p, rk, rv = inp
            h, (k, v) = attn.attention_forward(
                p["attn"], rms_norm(x, p["norm1"], eps), cfg,
                schedule="tri", return_kv=True)
            x = x + h
            h2 = rms_norm(x, p["norm2"], eps)
            if cfg.is_moe:
                y, _ = moem.moe_forward(p["moe"], h2, cfg)
            else:
                y = mlpm.mlp_forward(p["mlp"], h2, cfg)
            pool = write_prefill_kv(pool, rk[:nblk_p],
                                    k.reshape(nblk_p, -1))
            pool = write_prefill_kv(pool, rv[:nblk_p],
                                    v.reshape(nblk_p, -1))
            return (x + y, pool), None

        (x, pool), _ = jax.lax.scan(layer, (x, pool),
                                    (params["blocks"], rows_k, rows_v))
        last = jax.lax.dynamic_index_in_dim(x, length - 1, axis=1)
        return _greedy(_logits(params, last, cfg), cfg.vocab_size), pool

    return step, prefill


def _build_hybrid_programs(model, geom, attn_fn, layout):
    cfg = model.cfg
    eps = cfg.norm_eps
    dtype = jnp.dtype(cfg.dtype)
    ps = geom.page_size
    every = cfg.attn_every
    n_attn = max(cfg.n_layers // every, 1)
    fam = "hybrid"

    def token(params, pool, state, tokens, pos, rows_k, rows_v, active):
        """One token for the whole batch: state leaves (L, B, ...)."""
        B = tokens.shape[0]
        x = mapi._embed_lookup(params["embed"], tokens[:, None], dtype,
                               cfg.embed_impl)
        sh = params["shared_attn"]
        positions = pos[:, None]
        blk, off, lengths = pos // ps, pos % ps, pos + 1

        def layer(carry, inp):
            x, pool = carry
            p, mc, idx = inp
            x, new_mc = mapi._mamba_block_decode(p, x, cfg, mc)
            use_attn = (idx % every) == (every - 1)
            slot = jnp.minimum(idx // every, n_attn - 1)
            rk = jnp.take(rows_k, slot, axis=1)
            rv = jnp.take(rows_v, slot, axis=1)

            def with_attn(args):
                x, pool = args
                h = rms_norm(x, sh["norm"], eps)
                q, k, v = attn.project_qkv(sh["attn"], h, h, cfg,
                                           positions, positions, True)
                pool = write_token_kv(pool, rk, blk, off,
                                      k[:, 0].reshape(B, -1), active)
                pool = write_token_kv(pool, rv, blk, off,
                                      v[:, 0].reshape(B, -1), active)
                a = attn_fn(q[:, 0], pool, rk, rv, lengths)
                y = attn.output_proj(sh["attn"], a[:, None].astype(x.dtype))
                return x + y, pool

            x, pool = jax.lax.cond(use_attn, with_attn, lambda a: a,
                                   (x, pool))
            return (x, pool), new_mc

        idxs = jnp.arange(cfg.n_layers)
        (x, pool), new_state = jax.lax.scan(
            layer, (x, pool), (params["blocks"], state, idxs))
        return (_greedy(_logits(params, x, cfg), cfg.vocab_size), pool,
                new_state)

    def step(params, pool, tokens, pos, rows_k, rows_v, srows, active):
        buf = read_state(pool, srows, layout.size)
        state = _from_slot_major(fam, unpack(buf, layout))
        tok, pool, new_state = token(params, pool, state, tokens, pos,
                                     rows_k, rows_v, active)
        buf = pack(_to_slot_major(fam, new_state), layout)
        pool = write_state(pool, srows, buf, active)
        return tok, pool

    def prefill(params, pool, tokens, length, rows_k, rows_v, srows):
        # Masked scan of the SAME per-token core as step: pad steps
        # route kv to trash and leave state untouched, so prefill is
        # bit-equal to feeding the prompt token-by-token.
        _, P = tokens.shape
        state0 = _zero_state(fam, layout, 1)
        rk, rv = rows_k[None], rows_v[None]

        def pstep(carry, t):
            pool, state, tok_hold = carry
            valid = t < length
            tok_t = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)[:, 0]
            pos = jnp.full((1,), t, jnp.int32)
            tok, pool, new_state = token(params, pool, state, tok_t, pos,
                                         rk, rv, valid[None])
            state = jax.tree.map(lambda n, o: jnp.where(valid, n, o),
                                 new_state, state)
            tok_hold = jnp.where(t == length - 1, tok[0], tok_hold)
            return (pool, state, tok_hold), None

        (pool, state, tok_hold), _ = jax.lax.scan(
            pstep, (pool, state0, jnp.zeros((), jnp.int32)),
            jnp.arange(P, dtype=jnp.int32))
        buf = pack(_to_slot_major(fam, state), layout)
        pool = write_state(pool, srows[None], buf)
        return tok_hold[None], pool

    return step, prefill


def _build_ssm_programs(model, geom, layout):
    cfg = model.cfg
    fam = "ssm"

    def core(params, cache, tokens):
        logits, new_cache = model.decode_fn(params, cache, tokens, 0, None)
        return logits[:, 0], new_cache

    def step(params, pool, tokens, pos, rows_k, rows_v, srows, active):
        buf = read_state(pool, srows, layout.size)
        cache = _from_slot_major(fam, unpack(buf, layout))
        logits, new_cache = core(params, cache, tokens[:, None])
        buf = pack(_to_slot_major(fam, new_cache), layout)
        pool = write_state(pool, srows, buf, active)
        return _greedy(logits, cfg.vocab_size), pool

    def prefill(params, pool, tokens, length, rows_k, rows_v, srows):
        _, P = tokens.shape
        cache0 = _zero_state(fam, layout, 1)

        def pstep(carry, t):
            cache, tok_hold = carry
            tok_t = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
            logits, new_cache = core(params, cache, tok_t)
            valid = t < length
            cache = jax.tree.map(lambda n, o: jnp.where(valid, n, o),
                                 new_cache, cache)
            tok_hold = jnp.where(t == length - 1,
                                 _greedy(logits, cfg.vocab_size)[0],
                                 tok_hold)
            return (cache, tok_hold), None

        (cache, tok_hold), _ = jax.lax.scan(
            pstep, (cache0, jnp.zeros((), jnp.int32)),
            jnp.arange(P, dtype=jnp.int32))
        buf = pack(_to_slot_major(fam, cache), layout)
        pool = write_state(pool, srows[None], buf)
        return tok_hold[None], pool

    return step, prefill


def build_programs(model, geom: PageGeom, impl: str = "auto") -> Programs:
    fam = model.cfg.family
    if fam not in SERVE_FAMILIES:
        _refuse(fam)
    resolve_impl(impl)    # surface bad impl strings / unsupported pallas
    layout = state_layout_for(model)
    if fam in ("dense", "moe"):
        step, prefill = _build_decoder_programs(model, geom,
                                                _make_attn(impl, geom))
    elif fam == "hybrid":
        step, prefill = _build_hybrid_programs(model, geom,
                                               _make_attn(impl, geom),
                                               layout)
    else:
        step, prefill = _build_ssm_programs(model, geom, layout)
    return Programs(family=fam, geom=geom, state_layout=layout,
                    step=jax.jit(step, donate_argnums=(1,)),
                    prefill=jax.jit(prefill, donate_argnums=(1,)))
