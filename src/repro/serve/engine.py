"""Slot-based continuous-batching scheduler over the paged pool.

The host side of the serve subsystem: N batch slots drive ONE compiled
decode program for the engine's whole life. Each step the scheduler
(1) admits queued requests into free slots — one fenced prefill per
admission claims the slot's full page budget from the FreeList and
scatters the prompt's KV/state into the pool; (2) runs one batched
decode step over all slots (inactive slots ride along against the trash
page); (3) commits tokens and retires finished requests, freeing their
rows — all without changing a single jit shape.

``policy="static"`` is the baseline the benchmark compares against: the
SAME engine and programs, but admission waits until every slot is idle
(classic static batching — the batch drains fully before the next batch
starts). Any throughput/latency win of ``"continuous"`` is therefore
pure scheduling, not implementation difference.

Backpressure: admission defers (request stays queued) when the FreeList
cannot cover a full slot allocation; if the pool cannot fit even one
request with every slot idle, the engine raises instead of spinning.

Timing is phase-fenced (obs.Trace): ``prefill`` / ``decode_step``
phases block_until_ready before reading the clock, and each step emits
a ``kind="step"`` trace record. ``drive_workload`` runs a discrete-event
virtual clock over those fenced durations, so Poisson arrival/latency
statistics are honest on an async backend.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Tuple

import numpy as np

from repro.obs.trace import Trace
from repro.serve import decode as sdecode
from repro.serve.paging import FreeList


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32 token ids
    max_new: int                  # generated tokens incl. the prefill token
    arrival: float = 0.0          # virtual-clock arrival time (seconds)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: List[int]             # all generated tokens, prefill's first
    arrival: float
    finish_clock: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_clock - self.arrival


@dataclasses.dataclass
class StepReport:
    prefill_s: float
    decode_s: float
    admitted: int
    committed: int                # tokens committed this step (all slots)
    completions: List[Completion]

    @property
    def elapsed_s(self) -> float:
        return self.prefill_s + self.decode_s


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    page_size: int = 8
    max_prompt: int = 16          # rounded up to a page multiple (bucket P)
    max_new: int = 16             # hard per-request cap
    impl: str = "auto"            # decode-attention impl (resolve_impl)
    policy: str = "continuous"    # "continuous" | "static"
    n_pages: Optional[int] = None  # pool-size override (backpressure tests)


@dataclasses.dataclass
class _Slot:
    req: Request
    target: int                   # clamped max_new
    rows: np.ndarray              # full allocation (for free())
    rows_k: np.ndarray            # (layers_kv, max_blocks) or dummy
    rows_v: np.ndarray
    srows: np.ndarray             # (state_rows,) or dummy
    pos: int                      # tokens resident in the cache
    tokens: List[int]


class Engine:
    def __init__(self, model, params, cfg: EngineConfig,
                 trace: Optional[Trace] = None):
        assert cfg.policy in ("continuous", "static"), cfg.policy
        self.model, self.params, self.cfg = model, params, cfg
        self.bucket = cfg.page_size * (-(-cfg.max_prompt // cfg.page_size))
        self.geom = sdecode.geom_for(
            model, n_slots=cfg.n_slots, page_size=cfg.page_size,
            max_len=self.bucket + cfg.max_new, n_pages=cfg.n_pages)
        self.progs = sdecode.build_programs(model, self.geom, cfg.impl)
        self.pool = self.geom.pool()
        self.free = FreeList(self.geom.n_pages)
        self.slots: List[Optional[_Slot]] = [None] * cfg.n_slots
        self.queue: deque = deque()
        self.trace = trace if trace is not None else Trace(None)
        self.step_idx = 0
        self.deferred_total = 0   # cumulative admissions deferred by the
        #                           FreeList (backpressure, DESIGN.md §15)
        g = self.geom
        self._tshape = (max(g.n_layers_kv, 1), max(g.max_blocks, 1))
        self._sshape = (max(g.state_rows, 1),)

    # -- scheduling --------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert 1 <= len(req.prompt) <= self.bucket, \
            (len(req.prompt), self.bucket)
        assert req.max_new >= 1, req.max_new
        self.queue.append(req)

    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _admit(self, slot_id: int, req: Request) -> bool:
        g = self.geom
        rows = self.free.alloc(g.rows_per_slot)
        if rows is None:
            if self.n_active() == 0:
                raise RuntimeError(
                    f"pool too small for a single request: need "
                    f"{g.rows_per_slot} rows, have {self.free.available()}")
            return False                     # backpressure: stay queued
        nk = g.n_layers_kv * g.max_blocks
        if nk:
            rows_k = rows[:nk].reshape(g.n_layers_kv, g.max_blocks)
            rows_v = rows[nk:2 * nk].reshape(g.n_layers_kv, g.max_blocks)
        else:
            rows_k = np.zeros(self._tshape, np.int32)
            rows_v = np.zeros(self._tshape, np.int32)
        srows = (rows[2 * nk:] if g.state_rows
                 else np.zeros(self._sshape, np.int32))
        prompt = np.asarray(req.prompt, np.int32)
        toks = np.zeros((1, self.bucket), np.int32)
        toks[: , :len(prompt)] = prompt[None]
        with self.trace.phase("prefill") as t:
            tok0, self.pool = t(self.progs.prefill(
                self.params, self.pool, toks, np.int32(len(prompt)),
                rows_k, rows_v, srows))
        self.slots[slot_id] = _Slot(
            req=req, target=min(req.max_new, self.cfg.max_new), rows=rows,
            rows_k=rows_k, rows_v=rows_v, srows=srows, pos=len(prompt),
            tokens=[int(np.asarray(tok0)[0])])
        return True

    def _retire(self, slot_id: int) -> Completion:
        s = self.slots[slot_id]
        self.free.free(s.rows)
        self.slots[slot_id] = None
        return Completion(rid=s.req.rid, prompt_len=len(s.req.prompt),
                          tokens=s.tokens, arrival=s.req.arrival)

    def _batch_args(self) -> Tuple[np.ndarray, ...]:
        B = self.cfg.n_slots
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        rows_k = np.zeros((B,) + self._tshape, np.int32)
        rows_v = np.zeros((B,) + self._tshape, np.int32)
        srows = np.zeros((B,) + self._sshape, np.int32)
        active = np.zeros((B,), bool)
        for i, s in enumerate(self.slots):
            if s is None:
                continue          # trash tables: rows 0, pos 0, token 0
            tokens[i] = s.tokens[-1]
            pos[i] = s.pos
            rows_k[i], rows_v[i], srows[i] = s.rows_k, s.rows_v, s.srows
            active[i] = True
        return tokens, pos, rows_k, rows_v, srows, active

    def step(self) -> StepReport:
        """One scheduler tick: admit -> batched decode -> commit/retire.
        Emits one kind="step" trace record with fenced phase durations."""
        admitted, deferred = 0, 0
        can_admit = (self.cfg.policy == "continuous"
                     or self.n_active() == 0)
        while can_admit and self.queue and None in self.slots:
            if not self._admit(self.slots.index(None), self.queue[0]):
                deferred += 1        # free slot, but the pool said no
                break
            self.queue.popleft()
            admitted += 1
        self.deferred_total += deferred
        completions: List[Completion] = []
        committed = admitted      # each prefill committed one token
        for i, s in enumerate(self.slots):
            if s is not None and len(s.tokens) >= s.target:
                completions.append(self._retire(i))   # max_new == 1
        if self.n_active():
            args = self._batch_args()
            with self.trace.phase("decode_step") as t:
                toks, self.pool = t(self.progs.step(
                    self.params, self.pool, *args))
            toks = np.asarray(toks)
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                s.pos += 1        # the token just fed is now in the cache
                s.tokens.append(int(toks[i]))
                committed += 1
                if len(s.tokens) >= s.target:
                    completions.append(self._retire(i))
        prefill_s = self.trace.phase_seconds("prefill")
        decode_s = self.trace.phase_seconds("decode_step")
        self.trace.emit_round(self.step_idx, metrics={
            "active": self.n_active(), "queued": len(self.queue),
            "admitted": admitted, "committed": committed,
            "completed": len(completions), "deferred": deferred,
            "deferred_total": self.deferred_total,
            "free_rows": self.free.available()}, kind="step")
        self.step_idx += 1
        return StepReport(prefill_s, decode_s, admitted, committed,
                          completions)

    def run(self, requests, max_steps: int = 100_000) -> List[Completion]:
        """Submit everything, step until drained (no arrival process)."""
        for r in requests:
            self.submit(r)
        done: List[Completion] = []
        while (self.queue or self.n_active()) and max_steps:
            done.extend(self.step().completions)
            max_steps -= 1
        assert not self.queue and not self.n_active(), "max_steps exceeded"
        return done

    def warmup(self) -> None:
        """Compile both programs before anything is timed for real."""
        self.run([Request(rid=-1, prompt=np.zeros(1, np.int32),
                          max_new=2)])


# ---------------------------------------------------------------------------
# Workloads (benchmarks / smoke)
# ---------------------------------------------------------------------------


def poisson_workload(rate: float, n: int, seed: int = 0,
                     prompt_len=(4, 16), max_new=(4, 16),
                     vocab: int = 256) -> List[Request]:
    """n requests with exponential inter-arrivals at ``rate`` req/s and
    uniform prompt/max_new draws (inclusive ranges)."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        pl = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mn = int(rng.integers(max_new[0], max_new[1] + 1))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, size=pl).astype(np.int32),
            max_new=mn, arrival=t))
    return reqs


def drive_workload(engine: Engine, requests: List[Request]):
    """Discrete-event drive: the virtual clock advances by each step's
    MEASURED fenced duration, arrivals are released at their timestamps,
    and request latency = completion clock - arrival. Returns
    (completions, makespan_seconds)."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    clock, i = 0.0, 0
    done: List[Completion] = []
    while i < len(reqs) or engine.queue or engine.n_active():
        while i < len(reqs) and reqs[i].arrival <= clock:
            engine.submit(reqs[i])
            i += 1
        if not engine.queue and not engine.n_active():
            clock = reqs[i].arrival      # idle: jump to the next arrival
            continue
        rep = engine.step()
        clock += rep.elapsed_s
        for c in rep.completions:
            c.finish_clock = clock
            done.append(c)
    return done, clock
