"""Checkpoint -> serve handoff: restore trained params into the serve
model (DESIGN.md §15).

Two accepted checkpoint formats, both written by ``checkpoint/io.py``:

  pytree  the structure ``launch/train.py --checkpoint`` saves — the
          averaged server params (``localsgd.server_params``), keys
          matching ``model.abstract()``.
  packed  a single flat f32 buffer under the key ``"buf"`` — either
          ``(size,)`` (server buffer) or ``(G, size)`` (per-group
          buffers; groups are averaged, the same reduction
          ``server_params`` applies). Unpacked through the model's own
          ``optim/packing`` Layout, so trailing shard/chunk padding is
          sliced off and per-leaf dtypes are restored.

The checkpoint's ``arch`` metadata must match the serve config when
present — serving qwen3 weights through a granite graph would "work"
(same pytree shapes are not even required to differ) and be silently
wrong.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.optim.packing import layout_of, unpack


def restore_params(path: str, model, check_arch: bool = True):
    """Load ``path`` (npz+json, no extension) into ``model``'s param
    structure. Returns a params pytree of device arrays."""
    try:
        meta = ckpt_io.load_metadata(path)
    except FileNotFoundError:
        meta = {}
    if check_arch and meta.get("arch") and meta["arch"] != model.cfg.name:
        raise ValueError(
            f"checkpoint {path!r} was trained for arch {meta['arch']!r}, "
            f"serve config is {model.cfg.name!r} — pass the matching "
            "--arch, or check_arch=False to force")
    like = model.abstract()
    try:
        tree = ckpt_io.load(path, like)
    except KeyError:
        tree = _restore_packed(path, like)
    return jax.tree.map(jnp.asarray, tree)


def _restore_packed(path: str, like):
    try:
        buf = np.asarray(ckpt_io.load(path, {"buf": 0})["buf"], np.float32)
    except KeyError:
        raise ValueError(
            f"checkpoint {path!r} matches neither the params pytree nor "
            "the packed {'buf': ...} format") from None
    if buf.ndim > 1:                  # (G, size): average the groups
        buf = buf.mean(axis=0)
    layout = layout_of(like)
    if buf.shape[-1] < layout.size:
        raise ValueError(
            f"packed checkpoint buffer has {buf.shape[-1]:,} elements, "
            f"arch needs {layout.size:,} — wrong config?")
    return unpack(jnp.asarray(buf[:layout.size]), layout)
