"""Paged flat-buffer caches for the serve engine (DESIGN.md §15).

ONE f32 pool ``(n_pages, page_elems)`` holds every per-request cache:

  - KV pages: page row j of request b stores ``page_size`` tokens x
    ``n_kv`` heads x ``head_dim`` floats for one layer's K (or V), laid
    out token-major — exactly what the decode kernel
    (``kernels/decode_attention.py``) streams per grid step.
  - Recurrent-state rows: a slot's packed xLSTM/Mamba state (one flat
    buffer via ``optim/packing``) is split into ``page_elems``-wide rows
    (``packing.pad_rows``) and scattered to its own pool rows.

``page_elems`` is rounded up to a multiple of 256 — the same chunk
quantum the int8 codec and ``shard_layout`` use — so pool rows stay
whole-chunk-aligned and a future sharded pool splits on the same
boundaries as the train-side wire buffers (ISSUE 9 tentpole).

Row 0 is RESERVED as the trash page: inactive batch slots route their
(masked) KV writes and reads there, so the fixed-shape decode program
never branches on activity. Real allocations start at row 1.

Allocation is whole-request and host-side (``FreeList``): a request's
full page budget (every layer's K+V tables for ``max_blocks`` blocks,
plus its state rows) is claimed at admission and freed at retirement —
admission backpressure (defer until rows free up) replaces any
mid-flight OOM path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 256        # chunk quantum shared with the int8 codec / shard_layout
TRASH_ROW = 0      # reserved pool row for masked/inactive traffic


def _round_up(n: int, q: int) -> int:
    return q * ((n + q - 1) // q)


@dataclasses.dataclass(frozen=True)
class PageGeom:
    """Static pool geometry for one (model config, engine config) pair."""
    page_size: int          # tokens per KV page
    n_kv: int               # KV heads (0 for pure-ssm: no KV pages)
    head_dim: int
    n_layers_kv: int        # layers that own KV tables (0 for pure-ssm)
    max_blocks: int         # KV page-table length per layer per slot
    state_size: int         # packed recurrent-state f32 elements per slot
    page_elems: int         # pool row width (chunk-aligned)
    state_rows: int         # pool rows per slot of recurrent state
    n_pages: int            # total pool rows incl. the trash row

    @property
    def kv_rows_per_slot(self) -> int:
        return 2 * self.n_layers_kv * self.max_blocks

    @property
    def rows_per_slot(self) -> int:
        return self.kv_rows_per_slot + self.state_rows

    def pool(self) -> jax.Array:
        return jnp.zeros((self.n_pages, self.page_elems), jnp.float32)


def make_geom(*, page_size: int, n_kv: int, head_dim: int,
              n_layers_kv: int, max_len: int, state_size: int,
              n_slots: int, slack_slots: int = 0,
              n_pages: Optional[int] = None) -> PageGeom:
    """Build the pool geometry: rows wide enough for both a KV page and
    the state-row split, and enough rows for ``n_slots + slack_slots``
    concurrent requests (or an explicit ``n_pages`` override, used by the
    backpressure test to force a tight pool)."""
    kv_elems = page_size * n_kv * head_dim
    page_elems = _round_up(max(kv_elems, 1), ALIGN)
    max_blocks = -(-max_len // page_size) if n_layers_kv else 0
    state_rows = -(-state_size // page_elems) if state_size else 0
    geom = PageGeom(page_size=page_size, n_kv=n_kv, head_dim=head_dim,
                    n_layers_kv=n_layers_kv, max_blocks=max_blocks,
                    state_size=state_size, page_elems=page_elems,
                    state_rows=state_rows, n_pages=0)
    need = 1 + (n_slots + slack_slots) * geom.rows_per_slot
    return dataclasses.replace(geom, n_pages=n_pages if n_pages else need)


class FreeList:
    """Host-side pool-row allocator. Row 0 (trash) is never handed out."""

    def __init__(self, n_pages: int):
        self._free = list(range(n_pages - 1, 0, -1))

    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[np.ndarray]:
        """n rows as int32, or None if the pool is short (backpressure:
        the engine defers admission rather than partially allocating)."""
        if n > len(self._free):
            return None
        rows = [self._free.pop() for _ in range(n)]
        return np.asarray(rows, np.int32)

    def free(self, rows: np.ndarray) -> None:
        for r in rows.reshape(-1).tolist():
            assert r != TRASH_ROW, "trash row can never be freed"
            self._free.append(r)


# -- device-side pool access (all shapes static; everything below is
#    called inside the jit'd decode/prefill programs) -------------------


def write_token_kv(pool, rows, blk, off, vec, valid=None):
    """Scatter one decode step's per-slot K (or V) vectors into the pool.

    pool (n_pages, E); rows (B, nblk) page table for ONE layer's K or V;
    blk/off (B,) int32 block index / in-page offset; vec (B, n_kv*hd)
    f32; valid (B,) bool or None. Invalid slots write to the trash row
    at offset 0 — garbage that nothing reads (their table rows also point
    at trash, and length masking hides position 0 overwrites).
    """
    row = jnp.take_along_axis(rows, blk[:, None], axis=1)[:, 0]
    if valid is not None:
        row = jnp.where(valid, row, TRASH_ROW)
        off = jnp.where(valid, off, 0)
    width = vec.shape[-1]
    cols = off[:, None] * width + jnp.arange(width, dtype=jnp.int32)[None]
    return pool.at[row[:, None], cols].set(vec.astype(pool.dtype))


def write_prefill_kv(pool, rows, mat):
    """Scatter a whole prefill's pages for one layer's K (or V).

    rows (nblk,) page table of the single prefilling slot; mat
    (nblk, page_size * n_kv * hd) f32, token-major per page. Rows past
    the prompt length still land on real (allocated) pages — their
    garbage is hidden by length masking in the kernel."""
    return pool.at[rows, :mat.shape[-1]].set(mat.astype(pool.dtype))


def read_state(pool, rows, size: int):
    """Gather per-slot packed recurrent state: rows (B, state_rows) ->
    (B, size) f32 flat buffers (padding sliced off)."""
    b = rows.shape[0]
    return pool[rows].reshape(b, -1)[:, :size]


def write_state(pool, rows, buf, valid=None):
    """Scatter per-slot packed state buffers back: buf (B, size).

    Uses ``packing.pad_rows`` to split each slot's buffer into pool-row
    width; invalid slots are redirected to the trash row."""
    from repro.optim.packing import pad_rows
    tiles = pad_rows(buf.astype(pool.dtype), pool.shape[-1])  # (B, R, E)
    if valid is not None:
        rows = jnp.where(valid[:, None], rows, TRASH_ROW)
    return pool.at[rows].set(tiles)
