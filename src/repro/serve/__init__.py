"""Continuous-batching serve engine (DESIGN.md §15).

The inference half of the north star: slot-scheduled continuous
batching over paged flat-buffer caches, fed by checkpoint→serve handoff
from localsgd training runs.

  paging   one f32 pool (n_pages, page_elems): KV pages + recurrent-state
           rows, chunk-aligned like the §9 codec chunks; host FreeList
  decode   fixed-shape jit programs per family (dense/moe paged decode
           through the Pallas kernel, hybrid shared-attn + state rows,
           ssm state rows); explicit refusals for vlm/audio
  engine   the host scheduler: admit into freed slots every step, retire
           without recompiling; static-batch policy for baselines
  handoff  restore trained params (pytree or packed flat buffer) from
           checkpoint/io.py
"""
from repro.serve.engine import (Engine, EngineConfig, Request,
                                drive_workload, poisson_workload)
from repro.serve.handoff import restore_params
from repro.serve.paging import PageGeom

__all__ = ["Engine", "EngineConfig", "Request", "PageGeom",
           "drive_workload", "poisson_workload", "restore_params"]
