"""Paper-validation tests at test scale (Sec 2.3 / Sec 3 experiments).

Full-size reproductions live in benchmarks/; these assert the paper's
claims hold qualitatively in seconds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theory
from repro.core.reference import run_alg1 as _run_alg1
from repro.data import convex


def run_alg1(losses, w0, lr, T, rounds, threshold=None):
    """Thin wrapper around the library's reference driver returning
    (final-iterate 'trajectory' sentinel, global grad-sq residuals)."""
    out = _run_alg1(losses, w0, lr=lr, T=T, rounds=rounds,
                    threshold=threshold)
    return [jnp.asarray(w0), out["w"]], out["gsq"]


# ---------------------------------------------------------------------------
# Sec 2.3.1: Beck-Teboulle feasibility (separation fails -> only 1/n)
# ---------------------------------------------------------------------------


def test_beck_teboulle_converges_to_origin():
    losses = convex.beck_teboulle_losses()
    w0 = jnp.array([1.5, 0.8])
    traj, gsq = run_alg1(losses, w0, lr=0.4, T=10, rounds=300)
    assert gsq[-1] < 1e-6
    # iterates approach (0,0), the single intersection point
    assert float(jnp.linalg.norm(traj[-1])) < 0.3
    # residuals vanish but slowly (sublinear): late-phase ratio close to 1
    assert gsq[-1] / gsq[-100] > 0.05


def test_beck_teboulle_gsq_about_1_over_n():
    losses = convex.beck_teboulle_losses()
    _, gsq = run_alg1(losses, jnp.array([1.5, 0.8]), lr=0.4, T=10,
                      rounds=400)
    # fit log gsq ~ -p log n on the tail; paper reports p ~ 1
    n = np.arange(1, len(gsq) + 1)
    tail = slice(50, None)
    p = np.polyfit(np.log(n[tail]), np.log(np.asarray(gsq)[tail]), 1)[0]
    # Thm 2's O(1/n) is an upper bound — residuals may (and here do)
    # decay faster than the paper's 1/n reference line
    assert -4.0 < p < -0.5, p


# ---------------------------------------------------------------------------
# Sec 2.3.2: over-parameterized least squares -> linear rate, all T
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def regression():
    return convex.make_overparam_regression(n=20, d=200, m=2, seed=0)


def test_linear_convergence_all_T(regression):
    losses = regression.local_losses()
    w0 = jnp.zeros(200)
    for T in (1, 10, 100):
        _, gsq = run_alg1(losses, w0, lr=2.0, T=T, rounds=40)
        # linear rate: log-residual drops roughly linearly; final tiny
        assert gsq[-1] < gsq[0] * 1e-4, (T, gsq[0], gsq[-1])


def test_threshold_mode_converges(regression):
    losses = regression.local_losses()
    _, gsq = run_alg1(losses, jnp.zeros(200), lr=2.0, T=None,
                      rounds=8, threshold=1e-10)
    # the averaged global residual plateaus near the local threshold
    assert gsq[-1] < 1e-4, gsq[-1]


def test_larger_T_fewer_rounds(regression):
    losses = regression.local_losses()
    w0 = jnp.zeros(200)

    def rounds_to(T, tol=1e-8, cap=200):
        _, gsq = run_alg1(losses, w0, lr=2.0, T=T, rounds=cap)
        for i, g in enumerate(gsq):
            if g < tol:
                return i + 1
        return cap

    r1, r10, r100 = rounds_to(1), rounds_to(10), rounds_to(100)
    assert r10 < r1 and r100 <= r10, (r1, r10, r100)


# ---------------------------------------------------------------------------
# Sec 4: quartic loss -> sub-linear local decay, detected by fit_decay
# ---------------------------------------------------------------------------


def test_quartic_local_decay_is_sublinear():
    prob = convex.make_overparam_regression(n=10, d=50, m=1, power=2,
                                            seed=1)
    f = prob.local_losses()[0]
    g = jax.jit(jax.grad(f))
    w = jnp.ones(50) * 0.3
    traj = []
    for _ in range(60):
        gi = g(w)
        traj.append(float(jnp.sum(gi ** 2)))
        w = w - 0.5 * gi
    fit = theory.fit_decay(traj)
    assert fit is not None and fit.kind == "sublinear", fit


def test_quadratic_local_decay_is_linear():
    prob = convex.make_overparam_regression(n=10, d=50, m=1, power=1,
                                            seed=1)
    f = prob.local_losses()[0]
    g = jax.jit(jax.grad(f))
    w = jnp.ones(50) * 0.3
    traj = []
    for _ in range(40):
        gi = g(w)
        traj.append(float(jnp.sum(gi ** 2)))
        w = w - 1.0 * gi
    fit = theory.fit_decay(traj)
    assert fit is not None and fit.kind == "linear", fit


# ---------------------------------------------------------------------------
# Lemma 6: separation constant for affine subspaces
# ---------------------------------------------------------------------------


def test_lemma6_separation_bound(key):
    d, m, rank = 10, 3, 2
    ks = jax.random.split(key, m + 2)
    # random subspaces S_i = ker(A_i) (through 0 so S = ∩ ker A_i)
    As = [jax.random.normal(ks[i], (rank, d)) for i in range(m)]
    # orthonormalize rows
    As = [jnp.linalg.qr(A.T)[0].T for A in As]
    Q = sum(A.T @ A for A in As) / m
    svals = jnp.linalg.svd(Q, compute_uv=False)
    pos = svals[svals > 1e-9]
    c = 1.0 / float(pos[-1])
    # check d(x,S) <= c * mean_i d(x,S_i) on random points
    stacked = jnp.concatenate(As, 0)
    u, s, vt = jnp.linalg.svd(stacked, full_matrices=False)
    V = vt[s > 1e-8]
    for j in range(10):
        x = jax.random.normal(jax.random.PRNGKey(100 + j), (d,))
        dS = float(jnp.linalg.norm(V.T @ (V @ x)))
        mean_di = float(np.mean([jnp.linalg.norm(A @ x) for A in As]))
        assert dS <= c * mean_di + 1e-5, (dS, c, mean_di)
        # lower bound of Lemma 6
        assert mean_di <= dS + 1e-5
