"""Serve-engine tests (ISSUE 9): paged pool + FreeList unit behavior,
Pallas decode-attention bit-parity vs the jnp reference, prefill-vs-
stepwise token parity at the program level, continuous-vs-isolated
token parity across every servable family (dense/GQA, moe, ssm,
hybrid), static policy, backpressure, refusals, checkpoint->serve
handoff, and the kind="step" trace schema."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_config
from repro.kernels import decode_attention as da
from repro.models import build_model
from repro.obs import report
from repro.obs.trace import Trace
from repro.optim.packing import layout_of, pack
from repro.serve import (Engine, EngineConfig, Request, paging,
                         restore_params)
from repro.serve import decode as sdecode

SERVE_ARCHS = ("qwen3-32b", "granite-moe-1b-a400m", "xlstm-1.3b",
               "zamba2-7b")


def _requests(cfg, n=6, seed=0, prompt=(2, 10), gen=(2, 7)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(*prompt))).astype(np.int32),
                    max_new=int(rng.integers(*gen)))
            for i in range(n)]


def _run_isolated(model, params, reqs, **ecfg):
    eng = Engine(model, params, EngineConfig(n_slots=1, **ecfg))
    out = {}
    for r in reqs:
        done = eng.run([Request(r.rid, r.prompt.copy(), r.max_new)])
        out[r.rid] = done[0].tokens
    return out


# -- paging / FreeList --------------------------------------------------


def test_freelist_never_hands_out_trash_and_backpressures():
    fl = paging.FreeList(6)
    a = fl.alloc(3)
    assert paging.TRASH_ROW not in a.tolist()
    assert fl.alloc(3) is None          # only 2 rows left: defer, not split
    assert fl.available() == 2
    fl.free(a)
    assert fl.available() == 5
    b = fl.alloc(5)
    assert sorted(b.tolist()) == [1, 2, 3, 4, 5]


def test_geom_rows_and_pool_alignment():
    g = paging.make_geom(page_size=4, n_kv=2, head_dim=16, n_layers_kv=3,
                         max_len=10, state_size=1000, n_slots=2)
    assert g.page_elems % paging.ALIGN == 0
    assert g.max_blocks == 3            # ceil(10 / 4)
    assert g.kv_rows_per_slot == 2 * 3 * 3
    assert g.state_rows == -(-1000 // g.page_elems)
    assert g.n_pages == 1 + 2 * g.rows_per_slot
    assert g.pool().shape == (g.n_pages, g.page_elems)


def test_token_kv_write_masks_to_trash():
    g = paging.make_geom(page_size=2, n_kv=1, head_dim=4, n_layers_kv=1,
                         max_len=4, state_size=0, n_slots=2)
    pool = g.pool()
    rows = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    vec = jnp.ones((2, 4), jnp.float32)       # n_kv * head_dim = 4
    blk = jnp.asarray([0, 1], jnp.int32)
    off = jnp.asarray([1, 0], jnp.int32)
    out = paging.write_token_kv(pool, rows, blk, off, vec,
                                valid=jnp.asarray([True, False]))
    assert float(out[1, 4:8].sum()) == 4.0    # slot 0: row 1, offset 1
    assert float(out[4].sum()) == 0.0         # slot 1 masked -> trash
    assert float(out[0, :4].sum()) == 4.0     # garbage parked on trash row


def test_state_roundtrip_and_trash_masking():
    g = paging.make_geom(page_size=2, n_kv=1, head_dim=4, n_layers_kv=0,
                         max_len=4, state_size=300, n_slots=2)
    pool = g.pool()
    rows = jnp.arange(1, 1 + 2 * g.state_rows, dtype=jnp.int32
                      ).reshape(2, g.state_rows)
    buf = jnp.arange(2 * 300, dtype=jnp.float32).reshape(2, 300)
    pool = paging.write_state(pool, rows, buf)
    got = paging.read_state(pool, rows, 300)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(buf))
    masked = paging.write_state(g.pool(), rows, buf,
                                valid=jnp.asarray([False, True]))
    assert float(masked[rows[0, 0]].sum()) == 0.0
    assert float(masked[rows[1, 0]].sum()) > 0.0


# -- Pallas decode kernel vs jnp reference ------------------------------


@pytest.mark.parametrize("B,n_kv,g,hd,ps,nblk", [
    (4, 2, 2, 8, 4, 5),      # GQA
    (3, 4, 1, 16, 8, 3),     # MHA
    (1, 1, 8, 32, 4, 2),     # MQA-ish, single row
])
def test_paged_decode_kernel_bit_identical_to_ref(B, n_kv, g, hd, ps,
                                                  nblk):
    rng = np.random.default_rng(42)
    H = n_kv * g
    used = ps * n_kv * hd
    n_pages = 1 + 2 * B * nblk
    pool = jnp.asarray(rng.standard_normal(
        (n_pages, ((used + 255) // 256) * 256)).astype(np.float32))
    rows = rng.permutation(np.arange(1, n_pages)).astype(np.int32)
    rows_k = jnp.asarray(rows[:B * nblk].reshape(B, nblk))
    rows_v = jnp.asarray(rows[B * nblk:].reshape(B, nblk))
    q = jnp.asarray(rng.standard_normal((B, H, hd)).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, ps * nblk + 1, size=B), jnp.int32)
    out_k = da.paged_decode_attention(q, pool, rows_k, rows_v, lengths,
                                      page_size=ps, n_kv=n_kv,
                                      interpret=True)
    out_r = da.paged_decode_attention_ref(q, pool, rows_k, rows_v, lengths,
                                          page_size=ps, n_kv=n_kv)
    assert np.array_equal(np.asarray(out_k), np.asarray(out_r)), (
        np.abs(np.asarray(out_k) - np.asarray(out_r)).max())


def test_decode_ref_ignores_pages_past_length():
    """Length masking means garbage beyond ``lengths`` never leaks."""
    rng = np.random.default_rng(0)
    used = 4 * 2 * 8
    pool = jnp.asarray(rng.standard_normal((9, 256)).astype(np.float32))
    rows_k = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    rows_v = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    q = jnp.asarray(rng.standard_normal((1, 4, 8)).astype(np.float32))
    a = da.paged_decode_attention_ref(q, pool, rows_k, rows_v,
                                      jnp.asarray([6], jnp.int32),
                                      page_size=4, n_kv=2)
    # length 6 / page 4: only blocks 0,1 are live — trash K blocks 2,3
    # (rows 3,4) and V blocks 2,3 (rows 7,8) with huge finite garbage
    trashed = pool.at[3:5].set(1e6).at[7:9].set(1e6)
    b = da.paged_decode_attention_ref(q, trashed, rows_k, rows_v,
                                      jnp.asarray([6], jnp.int32),
                                      page_size=4, n_kv=2)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# -- engine parity across families --------------------------------------


@pytest.fixture(scope="module", params=SERVE_ARCHS)
def served(request):
    """Continuous engine (3 slots over 6 requests: slot reuse + queueing)
    vs per-request isolated decode, plus the step-trace records."""
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg)
    ecfg = dict(page_size=4, max_prompt=12, max_new=8)
    trace = Trace(None, meta={"launcher": "test"})
    eng = Engine(model, params, EngineConfig(n_slots=3, **ecfg),
                 trace=trace)
    done = eng.run([Request(r.rid, r.prompt.copy(), r.max_new)
                    for r in reqs])
    cont = {c.rid: c.tokens for c in done}
    iso = _run_isolated(model, params, reqs, **ecfg)
    return cfg, model, params, reqs, ecfg, cont, iso, done


def test_continuous_matches_isolated(served):
    cfg, _, _, reqs, _, cont, iso, _ = served
    assert set(cont) == {r.rid for r in reqs}
    for rid in cont:
        assert cont[rid] == iso[rid], (cfg.name, rid)


def test_completions_respect_caps(served):
    cfg, _, _, reqs, ecfg, _, _, done = served
    by_rid = {r.rid: r for r in reqs}
    for c in done:
        assert len(c.tokens) == min(by_rid[c.rid].max_new, ecfg["max_new"])
        assert c.prompt_len == len(by_rid[c.rid].prompt)
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)


def test_static_policy_same_tokens_worse_schedule():
    """Static admission is the same compiled programs — identical tokens,
    batches drain fully before readmission."""
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, n=5)
    ecfg = dict(page_size=4, max_prompt=12, max_new=8)
    stat = Engine(model, params,
                  EngineConfig(n_slots=2, policy="static", **ecfg))
    for r in reqs:
        stat.submit(Request(r.rid, r.prompt.copy(), r.max_new))
    tokens, admitted_nonidle = {}, 0
    while stat.queue or stat.n_active():
        pre_active = stat.n_active()
        rep = stat.step()
        if rep.admitted and pre_active:
            admitted_nonidle += 1
        for c in rep.completions:
            tokens[c.rid] = c.tokens
    iso = _run_isolated(model, params, reqs, **ecfg)
    assert tokens == iso
    # static: admission only ever happens on fully-idle ticks
    assert admitted_nonidle == 0


def test_backpressure_defers_then_completes():
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    probe = sdecode.geom_for(model, n_slots=2, page_size=4, max_len=16)
    tight = 1 + probe.rows_per_slot     # pool fits exactly ONE request
    eng = Engine(model, params, EngineConfig(
        n_slots=2, page_size=4, max_prompt=8, max_new=8, n_pages=tight))
    reqs = _requests(cfg, n=3, prompt=(2, 8), gen=(2, 5))
    done = eng.run([Request(r.rid, r.prompt.copy(), r.max_new)
                    for r in reqs])
    assert {c.rid for c in done} == {r.rid for r in reqs}

    starved = Engine(model, params, EngineConfig(
        n_slots=1, page_size=4, max_prompt=8, max_new=8, n_pages=2))
    starved.submit(Request(0, np.zeros(1, np.int32), 2))
    with pytest.raises(RuntimeError, match="pool too small"):
        starved.step()


def test_sustained_overload_counts_deferrals(tmp_path):
    """Backpressure telemetry (DESIGN.md §15): under a pool sized for one
    request and a deep queue, every blocked admission is counted — the
    kind="step" records carry deferred/deferred_total/free_rows, the
    report surfaces them, and the engine still drains to completion."""
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    probe = sdecode.geom_for(model, n_slots=2, page_size=4, max_len=16)
    tight = 1 + probe.rows_per_slot     # pool fits exactly ONE request
    path = tmp_path / "overload.jsonl"
    trace = Trace(str(path), meta={"launcher": "serve", "arch": cfg.name})
    eng = Engine(model, params, EngineConfig(
        n_slots=2, page_size=4, max_prompt=8, max_new=8, n_pages=tight),
        trace=trace)
    reqs = _requests(cfg, n=6, prompt=(2, 8), gen=(3, 6))
    done = eng.run([Request(r.rid, r.prompt.copy(), r.max_new)
                    for r in reqs])
    trace.close()
    # sustained overload: with 2 slots and a 1-request pool, the second
    # slot's admissions must have been deferred repeatedly
    assert eng.deferred_total > 0
    assert {c.rid for c in done} == {r.rid for r in reqs}  # nothing lost
    meta, records = report.load(path)
    assert report.check(meta, records) == []
    steps = report.steps_of(records)
    assert all({"deferred", "deferred_total", "free_rows"}
               <= set(s["metrics"]) for s in steps)
    # the cumulative counter is monotone and matches the engine's
    totals = [s["metrics"]["deferred_total"] for s in steps]
    assert totals == sorted(totals)
    assert totals[-1] == eng.deferred_total == sum(
        s["metrics"]["deferred"] for s in steps)
    # the pool was actually exhausted at some point, and recovered
    frees = [s["metrics"]["free_rows"] for s in steps]
    assert min(frees) < probe.rows_per_slot
    assert frees[-1] == eng.free.available()
    s = report.summarize(meta, records)
    assert s["serve"]["deferred_total"] == eng.deferred_total
    assert s["serve"]["free_rows_min"] == min(frees)
    assert s["serve"]["queued_max"] >= 1


def test_serve_trace_schema(tmp_path):
    """kind="step" records pass the obs.report --check gate."""
    cfg = get_config("xlstm-1.3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = tmp_path / "serve.jsonl"
    trace = Trace(str(path), meta={"launcher": "serve", "arch": cfg.name})
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, page_size=4, max_prompt=8,
                              max_new=4), trace=trace)
    eng.run([Request(r.rid, r.prompt.copy(), r.max_new)
             for r in _requests(cfg, n=3, prompt=(2, 8), gen=(2, 5))])
    trace.close()
    meta, records = report.load(path)
    assert report.check(meta, records) == []
    steps = report.steps_of(records)
    assert steps and all("decode_step" in s["phase_s"]
                         or s["metrics"]["admitted"] for s in steps)
    s = report.summarize(meta, records)
    assert s["n_steps"] == len(steps)
    assert "prefill" in s["phase_s"] and "decode_step" in s["phase_s"]


# -- prefill vs stepwise (program level) --------------------------------


@pytest.mark.parametrize("arch", ["qwen3-32b", "zamba2-7b"])
def test_prefill_matches_stepwise_teacher_forcing(arch):
    """prefill(prompt) must emit the same next token as prefilling one
    token and teacher-forcing the rest through the decode step program —
    the whole-prompt path and the incremental path agree."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # two slot budgets: one for the whole-prompt path, one for stepwise
    geom = sdecode.geom_for(model, n_slots=2, page_size=4, max_len=12)
    progs = sdecode.build_programs(model, geom)
    fl = paging.FreeList(geom.n_pages)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)

    def slot_tables():
        rows = fl.alloc(geom.rows_per_slot)
        nk = geom.n_layers_kv * geom.max_blocks
        rk = (rows[:nk].reshape(geom.n_layers_kv, geom.max_blocks)
              if nk else np.zeros((1, 1), np.int32))
        rv = (rows[nk:2 * nk].reshape(geom.n_layers_kv, geom.max_blocks)
              if nk else np.zeros((1, 1), np.int32))
        sr = (rows[2 * nk:] if geom.state_rows
              else np.zeros((1,), np.int32))
        return rows, rk, rv, sr

    pool = geom.pool()
    _, rk, rv, sr = slot_tables()
    padded = np.zeros((1, 8), np.int32)
    padded[0, :7] = prompt
    tok_full, pool = progs.prefill(params, pool, padded, np.int32(7),
                                   rk, rv, sr)

    _, rk2, rv2, sr2 = slot_tables()
    first = np.zeros((1, 8), np.int32)
    first[0, 0] = prompt[0]
    _, pool = progs.prefill(params, pool, first, np.int32(1),
                            rk2, rv2, sr2)
    tok = None
    for t in range(1, 7):
        tok, pool = progs.step(
            params, pool, np.asarray([prompt[t]], np.int32),
            np.asarray([t], np.int32), rk2[None], rv2[None], sr2[None],
            np.asarray([True]))
    assert int(np.asarray(tok_full)[0]) == int(np.asarray(tok)[0])


# -- refusals ------------------------------------------------------------


@pytest.mark.parametrize("arch", ["internvl2-1b", "whisper-base"])
def test_unservable_families_refuse(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    with pytest.raises(NotImplementedError, match="serve"):
        sdecode.geom_for(model, n_slots=1, page_size=4, max_len=8)


def test_bad_impl_rejected():
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    geom = sdecode.geom_for(model, n_slots=1, page_size=4, max_len=8)
    with pytest.raises(ValueError):
        sdecode.build_programs(model, geom, impl="cuda")


# -- checkpoint -> serve handoff ----------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("xlstm-1.3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def test_handoff_pytree_roundtrip(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    path = str(tmp_path / "ck")
    ckpt_io.save(path, params, metadata={"arch": cfg.name, "rounds": 3})
    got = restore_params(path, model)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_handoff_packed_roundtrip(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    layout = layout_of(params)
    buf = np.asarray(pack(params, layout))
    path = str(tmp_path / "ck_packed")
    # (G, size): per-group buffers are averaged like server_params
    ckpt_io.save(path, {"buf": np.stack([buf, buf])},
                 metadata={"arch": cfg.name})
    got = restore_params(path, model)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_handoff_arch_mismatch_and_short_buffer(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    path = str(tmp_path / "ck_wrong")
    ckpt_io.save(path, params, metadata={"arch": "qwen3-32b"})
    with pytest.raises(ValueError, match="qwen3-32b"):
        restore_params(path, model)
    restore_params(path, model, check_arch=False)   # explicit override
    short = str(tmp_path / "ck_short")
    ckpt_io.save(short, {"buf": np.zeros(8, np.float32)},
                 metadata={"arch": cfg.name})
    with pytest.raises(ValueError, match="packed checkpoint"):
        restore_params(short, model)
