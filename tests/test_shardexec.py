"""Sharded execution layer (sharding/shardexec.py + packing.ShardedLayout).

Acceptance-critical invariants (ISSUE 3 / DESIGN.md §9):
  * ShardedLayout pads to a shard*chunk multiple; pack/unpack round-trip
    through the padded buffer; the pad region stays zero,
  * on a forced 8-device host mesh the sharded packed round (Pallas
    kernels inside shard_map on shard-local buffers) matches the
    replicated path <= 1e-5 rel for sgd/momentum/adamw x {server, ring}
    x {fp32, int8},
  * int8 per-chunk scales are shard-local: the sharded exchange is
    BIT-identical to the replicated one (same noise, same chunk geometry),
  * the packed train-step builder unpins impl on sharded meshes, donates
    the sharded state (memory analysis shows the aliasing), and refuses
    the combos that cannot shard (topk, pallas-on-replicated-GSPMD).

Most tests need 8 devices. Under the plain 1-device tier-1 run,
``test_suite_under_forced_8_devices`` re-runs this module in a child
process with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
device count is locked at jax init, so it cannot be flipped in-process);
under CI's forced-8-device job the tests simply run directly.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, optim
from repro.core import localsgd as lsgd
from repro.optim import packing
from repro.sharding import shardexec as shx

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2) + 0.1 * jnp.sum(params["u"] ** 2)


def make_problem(key, g=G, r=4, d=6):
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,)),
              "u": jax.random.normal(ks[3], (2, 3))}
    return params, batch


def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


# ---------------------------------------------------------------------------
# ShardedLayout: padding, round-trip, alignment (no devices needed)
# ---------------------------------------------------------------------------


def test_shard_layout_roundtrip_with_padding(key):
    params, _ = make_problem(key)
    base = packing.layout_of(params)
    layout = packing.shard_layout(base, n_shards=2, align=256)
    assert layout.padded % (2 * 256) == 0
    assert layout.shard_size % 256 == 0
    assert layout.padded >= base.size and layout.size == base.size
    buf = packing.pack(params, layout)
    assert buf.shape == (layout.padded,)
    # the pad region is exactly zero and unpack ignores it
    np.testing.assert_array_equal(np.asarray(buf[base.size:]), 0.0)
    back = packing.unpack(buf, layout)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(a, b)
    # grouped packing pads every group's row
    tree_G = lsgd.replicate(params, 3)
    buf_G = packing.pack(tree_G, layout)
    assert buf_G.shape == (3, layout.padded)
    assert layout.abstract((3,)).shape == (3, layout.padded)


def test_shard_layout_pad_stays_zero_through_updates(key):
    """The pad region is a fixed point of every packed optimizer: zero
    params + zero grads + zero moments stay exactly zero, so padding
    never bleeds into real elements over a round."""
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params), 2, align=64)
    buf = packing.pack(params, layout)
    g = packing.pack(jax.tree.map(jnp.ones_like, params), layout)
    for name in ("sgd", "momentum", "adamw"):
        opt = optim.packed(name, 0.1, impl="jnp")
        state = opt.init(buf)
        b = buf
        for _ in range(3):
            b, state = opt.step(b, g, state)
        np.testing.assert_array_equal(np.asarray(b[layout.size:]), 0.0)


def test_plan_and_layout_guards(key):
    params, _ = make_problem(key)
    base = packing.layout_of(params)
    # plan_for on a 1-device mesh: nothing to shard over
    from jax.sharding import Mesh
    m1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
              ("data", "model"))
    assert shx.plan_for(m1) is None
    with pytest.raises(ValueError):
        shx.plan_for(m1, require=True)
    # a plain Layout is refused by sharded execution
    fake = shx.ShardExec(mesh=m1, group_axes=("data",),
                         shard_axes=("model",))
    with pytest.raises(ValueError):
        fake.check_layout(base)
    # shard-count mismatch is refused
    with pytest.raises(ValueError):
        fake.check_layout(packing.shard_layout(base, 4))
    # chunk misalignment is refused (scales must stay shard-local)
    bad = packing.shard_layout(base, 1, align=8)
    with pytest.raises(ValueError):
        fake.check_layout(bad, chunk=256)


def test_sharded_path_refusals(key):
    """The combos that stay replicated-only: a downlink codec (its
    broadcast-reference state is not threaded through shard_map) and
    async+topk (mirrors the replicated refusal). topk itself is NO
    LONGER refused — it runs sharded via the distributed threshold
    selection (DESIGN.md §11, tests/test_exchange_engine.py)."""
    params, _ = make_problem(key)
    from jax.sharding import Mesh
    m1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
              ("data", "model"))
    fake = shx.ShardExec(mesh=m1, group_axes=("data",),
                         shard_axes=("model",))
    layout = packing.shard_layout(packing.layout_of(params), 1)
    ex = comm.get_exchange("server", "topk", G)
    fake.exchange(ex, layout)   # builds — topk shards now
    ex_d = comm.get_exchange("server", "fp32", G, downlink_codec="bf16")
    with pytest.raises(NotImplementedError):
        fake.exchange(ex_d, layout)
    import dataclasses as _dc
    ex_async = _dc.replace(comm.get_exchange("async_stale", "fp32", G,
                                             staleness=1),
                           codec=comm.get_codec("topk"))
    with pytest.raises(NotImplementedError):
        fake.exchange(ex_async, layout)
    with pytest.raises(ValueError):
        _dc.replace(fake, hop_impl="bogus")._hop_fn(
            np.eye(G, dtype=np.float32), "data")


def test_shardexec_needs_packed_path(key):
    params, _ = make_problem(key)
    from jax.sharding import Mesh
    m1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
              ("data", "model"))
    fake = shx.ShardExec(mesh=m1, group_axes=("data",),
                         shard_axes=("model",))
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    with pytest.raises(ValueError):
        lsgd.make_local_round(quad_loss, optim.sgd(0.1), cfg,
                              shardexec=fake)


def test_impl_errors_are_clear():
    """No silent fallbacks / bare asserts: unknown impl names raise
    ValueError; a pytree optimizer refuses impl= outright (the fused
    kernels only exist packed); pallas on an unsupported backend raises
    NotImplementedError (cpu/tpu are supported, so only the message path
    is checkable here)."""
    from repro.kernels import pallas_supported, resolve_impl

    with pytest.raises(ValueError):
        resolve_impl("cuda")
    with pytest.raises(ValueError):
        optim.get("sgd", 0.1, impl="pallas")          # pytree + impl
    assert pallas_supported()                          # cpu container
    assert resolve_impl("pallas") == "pallas"          # interpret mode ok
    assert resolve_impl("auto") == "jnp"               # cpu default


def test_packed_sync_refuses_fsdp_mesh_and_pytree_refuses_impl():
    """Two more no-silent-path guards: packed sync on an fsdp mesh must
    refuse (its buffer stays replicated — recording that profile on a
    mesh built for sharding would mislead), and the pytree (non-packed)
    builder refuses impl= outright."""
    from jax.sharding import Mesh
    from repro.configs.base import InputShape, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    mesh_f = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                  ("data", "fsdp", "model"))
    with pytest.raises(NotImplementedError):
        build_train_step(cfg, shape, mesh_f, mode="sync", packed=True)
    with pytest.raises(ValueError):
        build_train_step(cfg, shape, make_local_mesh(1, 1),
                         packed=False, impl="pallas")


def test_pallas_impl_refused_on_replicated_multidevice_mesh():
    """No silent jnp fallback: an explicit impl='pallas' on a
    multi-device mesh with no in-group shard axis must raise (a
    pallas_call there is not GSPMD-partitionable)."""
    from repro.launch.steps import _packed_impl

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 1}

        class devices:
            size = 4

    with pytest.raises(NotImplementedError):
        _packed_impl("pallas", FakeMesh(), None)
    assert _packed_impl("auto", FakeMesh(), None) == "jnp"


# ---------------------------------------------------------------------------
# 8-device mesh: parity, exactness, builder, donation
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
@pytest.mark.parametrize("topo", ["server", "ring"])
@pytest.mark.parametrize("codec", ["fp32", "int8"])
def test_sharded_round_parity(opt_name, topo, codec, key):
    """THE acceptance gate: multi-round sharded packed rounds (Pallas
    kernels in shard_map on shard-local buffers) match the replicated
    path on the same padded layout to <= 1e-5 rel."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    assert sexec.n_shards == 2 and sexec.group_axes == ("data",)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange(topo, codec, G, mix_rounds=2, impl="jnp")
    opt_s = optim.get(opt_name, 0.05, packed=True, impl="pallas")
    opt_r = optim.get(opt_name, 0.05, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3, metrics="traj")
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=G, layout=layout,
                         exchange=ex)
    sr = lsgd.init_state(params, opt_r, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(3):
        ss, ms = rnd_s(ss, batch)
        sr, mr = rnd_r(sr, batch)
    scale = float(jnp.max(jnp.abs(sr["params"]))) + 1e-12
    err = float(jnp.max(jnp.abs(ss["params"] - sr["params"]))) / scale
    assert err <= 1e-5, (opt_name, topo, codec, err)
    # opt-state moments agree too (they follow the topology sharded)
    for k in ss["opt"]:
        if k == "count":
            continue
        m_scale = float(jnp.max(jnp.abs(sr["opt"][k]))) + 1e-12
        m_err = float(jnp.max(jnp.abs(ss["opt"][k] - sr["opt"][k])))
        assert m_err / m_scale <= 1e-5, (opt_name, topo, codec, k)
    # traj metrics: the sq_norm psum path matches the flat reduction
    np.testing.assert_allclose(np.asarray(ms["grad_sq_traj"]),
                               np.asarray(mr["grad_sq_traj"]),
                               rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(np.asarray(ms["loss"]),
                               np.asarray(mr["loss"]), rtol=1e-4)


@needs8
def test_sharded_int8_codec_bit_identical(key):
    """Shard-local chunk scales: each shard's rows are whole chunks of
    the full buffer and the noise is sliced from the SAME full-shape
    draw, so the decoded payload is bit-for-bit the replicated one —
    slicing rows before or after compress_rows commutes exactly."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    codec = comm.get_codec("int8", impl="jnp")
    delta = jax.random.normal(key, (G, layout.padded)) * 0.1
    rows = packing.chunk_rows(delta, codec.chunk)
    u = codec.noise(jnp.zeros((), jnp.int32), rows.shape)
    full = np.asarray(codec.compress_rows(rows, u)
                      .reshape(G, layout.padded))
    # shard-local: group g, shard s sees its own contiguous row block
    rs = layout.shard_size // codec.chunk          # rows per shard
    u_g = np.asarray(u).reshape(G, -1, codec.chunk)
    for g in range(G):
        for s in range(sexec.n_shards):
            loc = delta[g, s * layout.shard_size:
                        (s + 1) * layout.shard_size]
            got = codec.compress_rows(
                loc.reshape(-1, codec.chunk),
                jnp.asarray(u_g[g, s * rs:(s + 1) * rs]))
            np.testing.assert_array_equal(
                np.asarray(got).reshape(-1),
                full[g, s * layout.shard_size:(s + 1) * layout.shard_size])


@needs8
def test_sharded_int8_exchange_matches_replicated(key):
    """The full sharded exchange (quantize kernels in shard_map + psum
    mean) against the replicated exchange: identical codec bits, mixing
    differs only by collective reduction order (~1 ulp)."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange("server", "int8", G, impl="jnp")
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    x = x0 + jax.random.normal(key, x0.shape) * 0.1
    state = ex.init(x0)
    fn = sexec.exchange(ex, layout)
    out_s, st_s = jax.jit(fn)(x, x0, state)
    out_r, st_r = jax.jit(ex.params)(x, x0, state)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_r),
                               rtol=1e-6, atol=1e-7)
    assert int(st_s["codec"]["params"]["count"]) \
        == int(st_r["codec"]["params"]["count"]) == 1


@needs8
def test_sharded_async_stale_parity(key):
    """async_stale on the sharded path: the staleness buffer shards like
    the params; the masked refresh + psum-mean matches the replicated
    path."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=1)
    opt_s = optim.get("sgd", 0.05, packed=True, impl="pallas")
    opt_r = optim.get("sgd", 0.05, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2,
                              average_opt_state=False)
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=G, layout=layout,
                         exchange=ex)
    sr = lsgd.init_state(params, opt_r, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(4):
        ss, _ = rnd_s(ss, batch)
        sr, _ = rnd_r(sr, batch)
    scale = float(jnp.max(jnp.abs(sr["params"]))) + 1e-12
    assert float(jnp.max(jnp.abs(ss["params"] - sr["params"]))) / scale \
        <= 1e-5
    assert int(ss["comm"]["round"]) == 4
    np.testing.assert_allclose(np.asarray(ss["comm"]["pushed"]),
                               np.asarray(sr["comm"]["pushed"]),
                               rtol=1e-5, atol=1e-7)


@needs8
def test_sharded_parity_fsdp_mesh(key):
    """A (data=2, fsdp=2, model=2) mesh: the buffer shards 4-way over
    BOTH in-group axes; parity holds."""
    mesh = mesh8((2, 2, 2), ("data", "fsdp", "model"))
    sexec = shx.plan_for(mesh)
    assert sexec.shard_axes == ("fsdp", "model") and sexec.n_shards == 4
    params, batch = make_problem(key, g=2)
    layout = packing.shard_layout(packing.layout_of(params), 4)
    opt_s = optim.get("momentum", 0.05, packed=True, impl="pallas")
    opt_r = optim.get("momentum", 0.05, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=2, inner_steps=2)
    ex = comm.get_exchange("server", "fp32", 2)
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=2, layout=layout)
    sr = lsgd.init_state(params, opt_r, n_groups=2, layout=layout)
    ss, _ = rnd_s(ss, batch)
    sr, _ = rnd_r(sr, batch)
    scale = float(jnp.max(jnp.abs(sr["params"]))) + 1e-12
    assert float(jnp.max(jnp.abs(ss["params"] - sr["params"]))) / scale \
        <= 1e-5


@needs8
def test_sync_packed_impl_gate_on_mesh():
    """sync never enters shard_map, so even on a sharded-capable mesh a
    packed sync step refuses impl='pallas' (auto resolves to jnp) — the
    gate considers mode, not just mesh shape."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = mesh8()
    shape = InputShape(name="tiny", kind="train", global_batch=8,
                       seq_len=8)
    with pytest.raises(NotImplementedError):
        build_train_step(cfg, shape, mesh, mode="sync", packed=True,
                         impl="pallas")
    built = build_train_step(cfg, shape, mesh, mode="sync", packed=True)
    assert built.meta["impl"] == "jnp"


@needs8
def test_build_packed_train_step_sharded(key):
    """The mesh builder takes the sharded path (impl unpinned): Pallas
    fused update + int8 quantize kernels inside shard_map, sharded
    shardings on state, donation aliasing in the memory analysis, and
    per-device state bytes cut by n_shards."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = mesh8()
    shape = InputShape(name="tiny", kind="train", global_batch=8,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2,
                             opt_name="adamw", packed=True,
                             codec="int8", impl="pallas")
    meta = built.meta
    assert meta["sharded"] is True and meta["n_shards"] == 2
    assert meta["impl"] == "pallas"
    assert meta["n_flat_padded"] % (2 * 256) == 0
    assert meta["wire_bytes_per_round"] == (meta["wire_bytes_up_per_round"]
                                            + meta["wire_bytes_down_per_"
                                                   "round"])
    state_abs, _ = built.args
    assert state_abs["params"].shape == (4, meta["n_flat_padded"])
    # params shard over BOTH the group and the model axes
    psh = built.in_shardings[0]["params"]
    shard_shape = psh.shard_shape(tuple(state_abs["params"].shape))
    assert shard_shape == (1, meta["n_flat_padded"] // 2)
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        compiled = jitted.lower(*built.args).compile()
    ma = compiled.memory_analysis()
    if ma is not None and hasattr(ma, "alias_size_in_bytes"):
        # params + m + v donated in place: at least 3 G-sharded buffers
        state_bytes = 3 * 4 * state_abs["params"].size
        assert ma.alias_size_in_bytes >= state_bytes // mesh.devices.size


@needs8
def test_sharded_matches_replicated_builder_end_to_end(key):
    """Same config, same mesh: the sharded builder's round and a
    replicated-fallback round (jnp, data-axis-only mesh) produce the same
    server params after a round, <= 1e-5 rel — the builder-level version
    of the parity gate."""
    from jax.sharding import Mesh
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    mesh_s = mesh8()
    mesh_r = Mesh(np.array(jax.devices()[:4]).reshape(4, 1),
                  ("data", "model"))
    outs = {}
    for tag, mesh, impl in (("sharded", mesh_s, "pallas"),
                            ("replicated", mesh_r, "jnp")):
        built = build_train_step(cfg, shape, mesh, t_inner=2,
                                 opt_name="sgd", packed=True, impl=impl)
        assert built.meta["sharded"] == (tag == "sharded")
        state_abs, batch_abs = built.args
        rng = np.random.RandomState(0)
        from repro.models import build_model
        model = build_model(cfg, schedule="rect")
        params = model.init(jax.random.PRNGKey(0))
        layout = packing.layout_of(params)
        if built.meta["sharded"]:
            layout = packing.shard_layout(layout, built.meta["n_shards"])
        opt = optim.get("sgd", 1e-3, packed=True, impl=impl)
        state = lsgd.init_state(params, opt, n_groups=4, layout=layout)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (4, 1, 8)), jnp.int32)}
        with mesh:
            jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings,
                             donate_argnums=built.donate_argnums)
            new_state, _ = jitted(state, batch)
        outs[tag] = np.asarray(
            jax.tree.leaves(lsgd.server_params(new_state,
                                               layout=layout))[0])
    np.testing.assert_allclose(outs["sharded"], outs["replicated"],
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# multi-stream payloads on the sharded path (DESIGN.md §10)
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("opt_name", ["momentum", "adamw"])
@pytest.mark.parametrize("topo", ["server", "ring"])
def test_sharded_stream_parity_moment_codec(opt_name, topo, key):
    """The §10 sharded parity gate: moments ride their own int8 codec
    inside the shard_map exchange — multi-round sharded packed rounds
    (Pallas kernels) match the replicated path <= 1e-5 rel on params AND
    every moment stream (the int8 noise is per-stream, generated outside
    at full rows shape, so the codec bits are identical)."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange(topo, "int8", G, mix_rounds=2, impl="jnp",
                           moment_codec="int8")
    opt_s = optim.get(opt_name, 0.03, packed=True, impl="pallas")
    opt_r = optim.get(opt_name, 0.03, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3, metrics="traj")
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=G, layout=layout,
                         exchange=ex)
    sr = lsgd.init_state(params, opt_r, n_groups=G, layout=layout,
                         exchange=ex)
    assert set(ss["comm"]["codec"]) == {"params"} | set(opt_s.moment_keys)
    for _ in range(3):
        ss, ms = rnd_s(ss, batch)
        sr, mr = rnd_r(sr, batch)
    scale = float(jnp.max(jnp.abs(sr["params"]))) + 1e-12
    err = float(jnp.max(jnp.abs(ss["params"] - sr["params"]))) / scale
    assert err <= 1e-5, (opt_name, topo, err)
    for k in opt_s.moment_keys:
        m_scale = float(jnp.max(jnp.abs(sr["opt"][k]))) + 1e-12
        m_err = float(jnp.max(jnp.abs(ss["opt"][k] - sr["opt"][k])))
        assert m_err / m_scale <= 1e-5, (opt_name, topo, k)
        # per-stream rng counters advanced identically on both paths
        np.testing.assert_array_equal(
            np.asarray(ss["comm"]["codec"][k]["count"]),
            np.asarray(sr["comm"]["codec"][k]["count"]))
    np.testing.assert_allclose(np.asarray(ms["grad_sq_traj"]),
                               np.asarray(mr["grad_sq_traj"]),
                               rtol=1e-4, atol=1e-8)


@needs8
def test_sharded_fp32_moments_bit_exact_vs_mix(key):
    """§10 bit-exactness on the sharded path: with moment_codec=fp32 the
    stream exchange's moment mixing is the SAME psum-mean ops as the old
    shardexec.mix — compare the round's moments against mixing the
    no-comm locals by hand, bit for bit."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    opt = optim.get("momentum", 0.05, packed=True, impl="pallas")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "fp32", G)
    ex_none = comm.get_exchange("none", "fp32", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex,
                                        shardexec=sexec))
    rnd_none = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                             layout=layout,
                                             exchange=ex_none,
                                             shardexec=sexec))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd(st, batch)
    mix = sexec.mix(ex)
    np.testing.assert_array_equal(np.asarray(got["opt"]["mu"]),
                                  np.asarray(jax.jit(mix)(
                                      locals_["opt"]["mu"])))
    np.testing.assert_array_equal(np.asarray(got["params"]),
                                  np.asarray(jax.jit(mix)(
                                      locals_["params"])))


@needs8
def test_sharded_async_avg_opt_parity(key):
    """async_stale + average_opt_state=True on the sharded path (§10):
    per-stream staleness buffers shard like the params; masked refresh +
    psum-mean of params AND moments match the replicated path."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=1)
    opt_s = optim.get("momentum", 0.05, packed=True, impl="pallas")
    opt_r = optim.get("momentum", 0.05, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)  # avg_opt on
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=G, layout=layout,
                         exchange=ex)
    sr = lsgd.init_state(params, opt_r, n_groups=G, layout=layout,
                         exchange=ex)
    assert set(ss["comm"]["pushed_opt"]) == {"mu"}
    for _ in range(4):
        ss, _ = rnd_s(ss, batch)
        sr, _ = rnd_r(sr, batch)
    for name, a, b in (("params", ss["params"], sr["params"]),
                       ("mu", ss["opt"]["mu"], sr["opt"]["mu"]),
                       ("pushed", ss["comm"]["pushed"],
                        sr["comm"]["pushed"]),
                       ("pushed_mu", ss["comm"]["pushed_opt"]["mu"],
                        sr["comm"]["pushed_opt"]["mu"])):
        scale = float(jnp.max(jnp.abs(b))) + 1e-12
        assert float(jnp.max(jnp.abs(a - b))) / scale <= 1e-5, name
    assert int(ss["comm"]["round"]) == 4


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------


def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module with 8
    forced host devices in a subprocess (jax locks the device count at
    first init). CI's forced-8-device job runs the tests directly and
    skips this driver."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device shardexec suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
