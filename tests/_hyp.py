"""Hypothesis import shim with a deterministic fallback.

The property tests use a small strategy subset (integers, floats,
sampled_from, lists). When the real ``hypothesis`` package is installed it
is used unchanged; when it is missing (this container has no network), each
``@given`` test runs a fixed set of boundary/midpoint examples instead of
aborting the whole suite at collection time.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Fixed example list standing in for a hypothesis strategy."""

        def __init__(self, examples):
            self.examples = list(examples)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            vals = [min_value, mid, max_value]
            # dedupe, preserving order (tiny ranges collapse)
            return _Strategy(dict.fromkeys(vals))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy([min_value, 0.5 * (min_value + max_value),
                              max_value])

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            if len(elements) <= 3:
                return _Strategy(elements)
            return _Strategy([elements[0], elements[len(elements) // 2],
                              elements[-1]])

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            ex = elem.examples
            cap = max_size if max_size is not None else min_size + 2
            out = [[ex[i % len(ex)] for i in range(min_size)]]
            if cap > min_size:
                out.append([ex[i % len(ex)] for i in range(cap)])
            return _Strategy(out)

    st = _St()

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)
        pools = [strategies[n].examples for n in names]

        def deco(fn):
            def wrapper(*args, **kwargs):
                # zip-cycled cases (not the full product) keep the
                # deterministic sweep cheap while still hitting every
                # boundary example of every strategy at least once.
                n_cases = max(len(p) for p in pools)
                for i in range(n_cases):
                    case = {n: pools[j][i % len(pools[j])]
                            for j, n in enumerate(names)}
                    fn(*args, **case, **kwargs)

            # Hide the strategy-filled params from pytest's fixture
            # resolution; any remaining params stay visible as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for n, p in sig.parameters.items() if n not in strategies])
            return wrapper
        return deco
