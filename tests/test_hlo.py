"""HLO collective parser + trip-count-aware cost analyzer."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo, hlocost


def test_shape_bytes():
    assert hlo.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert hlo.shape_bytes("bf16[8]{0}") == 16
    assert hlo.shape_bytes("(f32[4]{0}, s32[2]{0})") == 16 + 8
    assert hlo.shape_bytes("pred[]") == 1  # scalar: one element
    assert hlo.shape_bytes("u8[10]{0}") == 10


def test_collective_summary_crafted():
    txt = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag.1 = bf16[2048]{0} all-gather(%y), replica_groups=[2,8]<=[16]
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[4,4]<=[16]
"""
    s = hlo.collective_summary(txt)
    assert s["n_collectives"] == 3
    assert s["bytes_by_kind"]["all-reduce"] == 4096
    assert s["bytes_by_kind"]["all-gather"] == 4096
    # reduce-scatter: result x group size
    assert s["bytes_by_kind"]["reduce-scatter"] == 64 * 4 * 4


def test_hlocost_scan_trip_multiplication():
    """A scan of N matmuls must report ~N x the flops of one matmul."""

    def scanned(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=10)
        return x

    def single(x, w):
        return jnp.tanh(x @ w)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t_scan = jax.jit(scanned).lower(x, w).compile().as_text()
    t_one = jax.jit(single).lower(x, w).compile().as_text()
    f_scan = hlocost.analyze(t_scan)["flops"]
    f_one = hlocost.analyze(t_one)["flops"]
    assert f_one == pytest.approx(2 * 128 ** 3, rel=0.01)
    assert f_scan == pytest.approx(10 * f_one, rel=0.05)


def test_hlocost_nested_scan():
    """Nested scans multiply trip counts."""

    def nested(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, None, length=4)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(nested).lower(x, w).compile().as_text()
    res = hlocost.analyze(txt)
    assert res["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=0.05)
    assert res["max_trip_product"] == 12


def test_hlocost_dot_flops_rectangular():
    def f(a, b):
        return a @ b  # (17,33) @ (33,9)

    a = jax.ShapeDtypeStruct((17, 33), jnp.float32)
    b = jax.ShapeDtypeStruct((33, 9), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    res = hlocost.analyze(txt)
    assert res["flops"] == pytest.approx(2 * 17 * 33 * 9, rel=0.01)


def test_hlocost_hbm_bytes_positive():
    def f(x):
        return jnp.sum(x * 2.0)

    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    res = hlocost.analyze(txt)
    assert res["hbm_bytes"] >= 4096  # at least reads the input


def test_parse_module_symbol_table():
    txt = """
HloModule m

ENTRY %main (p0: f32[4,8], p1: f32[8,2]) -> f32[4,2] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,2]{1,0} parameter(1)
  ROOT %dot.1 = f32[4,2]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps, entry = hlocost.parse_module(txt)
    assert entry == "main"
    res = hlocost.analyze(txt)
    assert res["flops"] == 2 * 4 * 8 * 2


# ---------------------------------------------------------------------------
# Property sweep: parser robustness on synthesized HLO fragments
# ---------------------------------------------------------------------------

from _hyp import given, settings, st  # noqa: E402

_DTYPES = ["f32", "bf16", "s32", "u8", "pred", "f16"]
_BYTES = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1, "pred": 1, "f16": 2}


@settings(max_examples=40, deadline=None)
@given(dtype=st.sampled_from(_DTYPES),
       dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_shape_bytes_property(dtype, dims):
    n = 1
    for d in dims:
        n *= d
    s = f"{dtype}[{','.join(map(str, dims))}]{{{','.join('0' * 0)}}}"
    assert hlo.shape_bytes(s) == n * _BYTES[dtype]


@settings(max_examples=25, deadline=None)
@given(g=st.integers(1, 8), s=st.integers(1, 8))
def test_replica_group_iota_identity(g, s):
    groups = hlo.replica_group_members(
        f"x, replica_groups=[{g},{s}]<=[{g * s}]")
    assert len(groups) == g
    flat = [d for grp in groups for d in grp]
    assert flat == list(range(g * s))


@settings(max_examples=25, deadline=None)
@given(a=st.integers(2, 6), b=st.integers(2, 6))
def test_groups_cross_slow_transpose(a, b):
    """Transposed iota groups stride by b -> cross any block < a*b."""
    line = f"x, replica_groups=[{b},{a}]<=[{a},{b}]T(1,0)"
    groups = hlo.replica_group_members(line)
    assert groups[0] == [i * b for i in range(a)]
    assert hlo.groups_cross_slow(line, b)      # strides cross b-blocks
    assert not hlo.groups_cross_slow(line, a * b)  # one big block
