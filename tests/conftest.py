import os
import sys

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, forces 512 placeholder devices inside its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # for the _hyp shim

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
