"""Checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io


def test_roundtrip(tmp_path, key):
    tree = {
        "params": {"w": jax.random.normal(key, (4, 3)),
                   "b": jnp.zeros((3,), jnp.bfloat16)},
        "opt": {"count": jnp.asarray(7, jnp.int32),
                "mu": {"w": jnp.ones((4, 3))}},
    }
    path = str(tmp_path / "ckpt")
    io.save(path, tree, metadata={"round": 12, "arch": "qwen3-32b"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    loaded = io.load(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    md = io.load_metadata(path)
    assert md["round"] == 12 and md["arch"] == "qwen3-32b"


def test_roundtrip_list_pytree(tmp_path, key):
    tree = [jnp.arange(5), {"x": jnp.ones((2, 2))}]
    path = str(tmp_path / "ckpt2")
    io.save(path, tree)
    loaded = io.load(path, tree)
    np.testing.assert_allclose(loaded[0], tree[0])
    np.testing.assert_allclose(loaded[1]["x"], tree[1]["x"])
