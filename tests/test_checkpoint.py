"""Checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io


def test_roundtrip(tmp_path, key):
    tree = {
        "params": {"w": jax.random.normal(key, (4, 3)),
                   "b": jnp.zeros((3,), jnp.bfloat16)},
        "opt": {"count": jnp.asarray(7, jnp.int32),
                "mu": {"w": jnp.ones((4, 3))}},
    }
    path = str(tmp_path / "ckpt")
    io.save(path, tree, metadata={"round": 12, "arch": "qwen3-32b"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    loaded = io.load(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    md = io.load_metadata(path)
    assert md["round"] == 12 and md["arch"] == "qwen3-32b"


def test_roundtrip_list_pytree(tmp_path, key):
    tree = [jnp.arange(5), {"x": jnp.ones((2, 2))}]
    path = str(tmp_path / "ckpt2")
    io.save(path, tree)
    loaded = io.load(path, tree)
    np.testing.assert_allclose(loaded[0], tree[0])
    np.testing.assert_allclose(loaded[1]["x"], tree[1]["x"])


def test_roundtrip_packed_state_with_comm_streams(tmp_path, key):
    """The full packed train state survives: params/moment stream buffers,
    per-stream codec state (rng counters, nested under comm/codec/<stream>),
    per-stream async staleness buffers (pushed + pushed_opt/<stream>), and
    the round counter — then training RESUMES bit-exactly (DESIGN.md §10:
    the comm state is part of the algorithm, not a cache)."""
    import jax.numpy as jnp

    from repro import comm, optim
    from repro.core import localsgd as lsgd
    from repro.optim import packing

    G = 4
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (G, 4, 6))
    batch = {"A": A, "b": jax.random.normal(ks[1], (G, 4))}
    params = {"w": jax.random.normal(ks[2], (6,))}
    layout = packing.layout_of(params)

    def loss(p, b):
        r = b["A"] @ p["w"] - b["b"]
        return 0.5 * jnp.sum(r ** 2)

    opt = optim.packed("adamw", 0.05, impl="jnp")
    ex = comm.get_exchange("async_stale", "int8", G, staleness=1,
                           moment_codec="int8")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    rnd = jax.jit(lsgd.make_local_round(loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(3):
        st, _ = rnd(st, batch)
    # nested per-stream comm state is present and non-trivial
    assert set(st["comm"]) == {"codec", "pushed", "pushed_opt", "round"}
    assert set(st["comm"]["codec"]) == {"params", "m", "v"}
    assert set(st["comm"]["pushed_opt"]) == {"m", "v"}

    path = str(tmp_path / "ckpt3")
    io.save(path, st, metadata={"round": 3, "comm": ex.name})
    like = jax.tree.map(jnp.zeros_like, st)
    loaded = io.load(path, like)
    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(st)[0],
            jax.tree_util.tree_flatten_with_path(loaded)[0]):
        assert ka == kb
        assert np.asarray(a).dtype == np.asarray(b).dtype, ka
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ka))
    assert io.load_metadata(path)["comm"] == ex.name
    # resume parity: one more round from the loaded state must be
    # BIT-identical to continuing from the live state (the rng counters
    # and staleness buffers are what make this true)
    cont, mc = rnd(st, batch)
    res, mr = rnd(jax.tree.map(jnp.asarray, loaded), batch)
    for a, b in zip(jax.tree.leaves(cont), jax.tree.leaves(res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(mc["wire_bytes"]) == int(mr["wire_bytes"])
