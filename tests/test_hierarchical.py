"""Tiered fault domains: hierarchical two-tier exchange (ISSUE 10 /
DESIGN.md §16).

Acceptance-critical invariants:
  * the lossless two-tier round is the composition of the pod-local
    circulant (or pod mean) and one pod-graph consensus hop — verified
    against a straight numpy reference;
  * cross-tier push_sum stays ratio consensus: sum(mass) +
    sum(backlog_w) == G EXACTLY under DCN loss, the group mean is
    unbiased where flat gossip under the same loss rate drifts;
  * pod-leader dropout re-elects deterministically; a fully-partitioned
    pod degrades to pod-local rounds and rejoins by draining queued
    mass, conserving it exactly;
  * the seed-lane registry (faults.HASH_LANES / CODEC_SEED_OFFSETS /
    FAULT_SEED_OFFSETS) is collision-free and bit-stable with the
    historical seed derivations;
  * a mid-fault checkpoint with live tiered backlogs resumes bit-exact;
  * the sharded (shard_map) hierarchical path matches the replicated
    one under identical per-tier fault schedules;
  * every §13 round record carries the per-tier keys and the wire total
    decomposes as intra + inter.

8-device tests ride the same forced-host child-process pattern as
tests/test_shardexec.py / tests/test_faults.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, obs, optim
from repro.comm import faults as faults_mod
from repro.comm import topology as topo
from repro.comm.exchange import elect_leaders
from repro.core import localsgd as lsgd
from repro.optim import packing
from repro.sharding import shardexec as shx

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_problem(key, g=G, r=8, d=40):
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,))}
    return params, batch


def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


def mass_total(st):
    return float(jnp.sum(st["mass"]) + jnp.sum(st["backlog_w"]))


def run_rounds(ex, x, n_rounds, every=None):
    """Iterate the exchange as a pure consensus map on one (G, d)
    params stream; ``every(st)`` checks per-round invariants."""
    st = ex.init(x)
    fn = jax.jit(ex.streams)
    xs = {"params": jnp.asarray(x)}
    xs0 = {"params": jnp.asarray(x)} if ex.lossy_stream("params") else {}
    for _ in range(n_rounds):
        xs, st = fn(xs, dict(xs0), st)
        if every is not None:
            every(st)
    return np.asarray(xs["params"]), st


# ---------------------------------------------------------------------------
# lossless round: numpy reference
# ---------------------------------------------------------------------------


def _ref_hier_round(x, n_pods, mix_rounds=1):
    """One lossless fp32 hierarchical round (ring intra, push_sum
    inter): pod-local circulant hops then one pod-graph consensus hop.
    All offset sets are symmetric (±1 patterns), so the stencil is
    direction-free."""
    g = x.shape[0]
    s = g // n_pods
    y = x.astype(np.float64).copy()

    def pod_take(v, d):
        r = v.reshape((n_pods, s) + v.shape[1:])
        return np.roll(r, -d, axis=1).reshape(v.shape)

    if s > 1:
        w_self, offs, w_edge = topo.ring_circulant(s)
        for _ in range(mix_rounds):
            out = w_self * y
            for d in offs:
                out = out + w_edge * pod_take(y, d)
            y = out
    offs_p = topo.push_sum_offsets(n_pods)
    if offs_p:
        a = 1.0 / (len(offs_p) + 1)
        z = a * y.copy()
        for dp in offs_p:
            z = z + a * np.roll(y, dp * s, axis=0)
        y = z
    return y


@pytest.mark.parametrize("g,n_pods,mix_rounds", [
    (4, 2, 1), (8, 2, 2), (8, 4, 1), (6, 3, 1),
])
def test_lossless_round_matches_numpy_reference(g, n_pods, mix_rounds,
                                                key):
    x = jax.random.normal(key, (g, 24))
    ex = comm.get_exchange("hierarchical", "fp32", g, n_pods=n_pods,
                           mix_rounds=mix_rounds)
    out, st = run_rounds(ex, x, 1)
    ref = _ref_hier_round(np.asarray(x), n_pods, mix_rounds)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    # lossless: the global mean is preserved exactly-to-rounding and the
    # weight channel stays uniform (no mass ever queues)
    np.testing.assert_allclose(out.mean(0), np.asarray(x).mean(0),
                               rtol=1e-5, atol=1e-6)
    if "mass" in st:
        np.testing.assert_allclose(np.asarray(st["mass"]), 1.0,
                                   rtol=1e-6)
        assert float(jnp.sum(st["backlog_w"])) == 0.0


def test_lossless_server_server_is_exact_global_mean(key):
    """intra=server takes pod means, inter=server averages the leaders:
    with equal pods one round lands every lane on the global mean."""
    x = jax.random.normal(key, (8, 16))
    ex = comm.get_exchange("hierarchical", "fp32", 8, n_pods=4,
                           intra_topology="server",
                           inter_topology="server")
    out, _ = run_rounds(ex, x, 1)
    np.testing.assert_allclose(
        out, np.broadcast_to(np.asarray(x).mean(0), out.shape),
        rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# cross-tier push_sum: mass conservation + unbiasedness under DCN loss
# ---------------------------------------------------------------------------


def test_mass_conserved_and_unbiased_under_dcn_loss(key):
    g, n_pods = 8, 4
    x = jax.random.normal(key, (g, 40))
    true_mean = np.asarray(x).mean(0)
    ex = comm.get_exchange("hierarchical", "fp32", g, n_pods=n_pods,
                           drop_rate=0.2, stall_rate=0.1, fault_seed=5)
    checks = []
    out, st = run_rounds(ex, x, 60,
                         every=lambda s: checks.append(mass_total(s)))
    # THE §12/§16 invariant, every single round: no mass is ever lost to
    # a dropped DCN packet — it queues in the per-edge backlog
    assert all(c == pytest.approx(g, abs=1e-3) for c in checks)
    # ratio consensus: every lane converges to the TRUE group mean
    err = np.abs(out - true_mean[None]).max()
    assert err < 1e-3, err
    bias = np.abs(out.mean(0) - true_mean).max()
    assert bias < 1e-4, bias


def test_tiered_push_sum_unbiased_where_flat_gossip_drifts(key):
    """The §16 bias regression at the ISSUE's 5-10%% DCN loss: under the
    same loss rate, flat gossip's self-substituted rows stay stochastic
    but not doubly — the group mean drifts — while the tiered push_sum
    estimate stays unbiased."""
    g, loss = 8, 0.075
    x = jax.random.normal(key, (g, 40))
    true_mean = np.asarray(x).mean(0)
    hier = comm.get_exchange("hierarchical", "fp32", g, n_pods=4,
                             drop_rate=loss, fault_seed=2)
    goss = comm.get_exchange("gossip", "fp32", g, drop_rate=loss,
                             fault_seed=2)
    out_h, st_h = run_rounds(hier, x, 40)
    out_g, _ = run_rounds(goss, x, 40)
    err_h = np.linalg.norm(out_h.mean(0) - true_mean)
    err_g = np.linalg.norm(out_g.mean(0) - true_mean)
    assert err_h < 1e-3, err_h
    assert err_g > 10 * err_h, (err_g, err_h)
    assert mass_total(st_h) == pytest.approx(g, abs=1e-3)


# ---------------------------------------------------------------------------
# leader election + partitioned-pod degradation
# ---------------------------------------------------------------------------


def test_leader_election_deterministic_and_survives_dropout():
    full = jnp.ones((6,), jnp.float32)
    w, live = elect_leaders(full, 3)
    np.testing.assert_array_equal(np.asarray(w), [1, 0, 1, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(live), [1, 1, 1])
    # leader dropout -> the next live member takes over, pod stays live
    w2, live2 = elect_leaders(full.at[0].set(0.0), 3)
    np.testing.assert_array_equal(np.asarray(w2), [0, 1, 1, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(live2), [1, 1, 1])
    # fully-dead pod: zero weight, pod_live 0 — no phantom leader
    w3, live3 = elect_leaders(full.at[2].set(0.0).at[3].set(0.0), 3)
    np.testing.assert_array_equal(np.asarray(w3), [1, 0, 0, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(live3), [1, 0, 1])
    # pure in the mask: repeated calls agree bit-for-bit
    wa, la = elect_leaders(w3, 3)
    wb, lb = elect_leaders(w3, 3)
    np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_partitioned_pod_degrades_then_rejoins_exactly(key):
    """Pod 1 (lanes 2-3) loses its DCN uplink for rounds [2, 5): during
    the outage it runs pod-local rounds only — its pod mean is frozen —
    the queued cross-pod mass is conserved EXACTLY, and after rejoin the
    drained backlog pulls everyone to the true global mean."""
    x = jax.random.normal(key, (G, 32))
    true_mean = np.asarray(x).mean(0)
    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                           dropouts=((2, 2, 5), (3, 2, 5)),
                           fault_seed=1)
    st = ex.init(x)
    fn = jax.jit(ex.streams)
    xs = {"params": jnp.asarray(x)}
    pod1_mean = None
    for rnd in range(24):
        xs, st = fn(xs, {}, st)
        assert mass_total(st) == pytest.approx(G, abs=1e-3), rnd
        cur = np.asarray(xs["params"])[2:4].mean(0)
        if rnd == 2:
            pod1_mean = cur
        elif rnd in (3, 4):
            # degraded to local-only: intra mixing preserves the pod
            # mean, the dead inter tier injects nothing
            np.testing.assert_allclose(cur, pod1_mean, rtol=1e-5,
                                       atol=1e-6)
    out = np.asarray(xs["params"])
    np.testing.assert_allclose(out, np.broadcast_to(true_mean, out.shape),
                               atol=1e-3)
    assert np.abs(out.mean(0) - true_mean).max() < 1e-4


# ---------------------------------------------------------------------------
# seed-lane registry (satellite: centralized splitmix32 lanes)
# ---------------------------------------------------------------------------


def test_seed_registry_collision_free_and_bit_stable():
    """The registries in repro.comm.faults are the ONE home for every
    derived seed/lane: no two entries of a registry may collide (a
    collision silently correlates independent randomness), and the
    derivations must stay bit-stable with the historical constants
    (seed, seed+1, seed+2) that shipped before the registry existed."""
    for reg in (faults_mod.HASH_LANES, faults_mod.CODEC_SEED_OFFSETS,
                faults_mod.FAULT_SEED_OFFSETS):
        assert len(set(reg.values())) == len(reg), reg
    for seed in (0, 7, 12345):
        cs = [faults_mod.codec_seed(seed, lane)
              for lane in faults_mod.CODEC_SEED_OFFSETS]
        assert len(set(cs)) == len(cs)
        fs = [faults_mod.fault_seed_for(seed, tier)
              for tier in faults_mod.FAULT_SEED_OFFSETS]
        assert len(set(fs)) == len(fs)
        # historical bit-exactness: params/moments/downlink were seeded
        # seed/seed+1/seed+2 before the registry centralized them
        assert faults_mod.codec_seed(seed, "params") == seed
        assert faults_mod.codec_seed(seed, "moments") == seed + 1
        assert faults_mod.codec_seed(seed, "downlink") == seed + 2
        assert faults_mod.fault_seed_for(seed, "flat") == seed
    with pytest.raises(ValueError):
        faults_mod.codec_seed(0, "no_such_lane")
    with pytest.raises(ValueError):
        faults_mod.fault_seed_for(0, "no_such_tier")
    # the two tiers of one fault_seed draw decorrelated mask streams
    pi = faults_mod.FaultPlan(
        seed=faults_mod.fault_seed_for(3, "intra"), drop_rate=0.3)
    px = faults_mod.FaultPlan(
        seed=faults_mod.fault_seed_for(3, "inter"), drop_rate=0.3)
    diff = sum(not np.array_equal(np.asarray(pi.push_mask(r, 64)),
                                  np.asarray(px.push_mask(r, 64)))
               for r in range(8))
    assert diff >= 6


# ---------------------------------------------------------------------------
# refusal matrix
# ---------------------------------------------------------------------------


def _assert_lists_alternatives(err, *names):
    msg = str(err.value)
    assert "valid" in msg, msg
    listed = [n for n in names if f"'{n}'" in msg]
    assert len(listed) >= 2, (msg, names)


def test_hierarchical_refusals_name_alternatives():
    gx = dict(n_groups=G, n_pods=2)
    with pytest.raises(ValueError) as e:      # non-divisor pod count
        comm.get_exchange("hierarchical", "fp32", G, n_pods=3)
    assert "divide" in str(e.value)
    with pytest.raises(ValueError) as e:      # tier knobs on flat topo
        comm.get_exchange("ring", "fp32", G, n_pods=2)
    assert "hierarchical" in str(e.value)
    with pytest.raises(ValueError):
        comm.get_exchange("ring", "fp32", G, inter_codec="int8")
    with pytest.raises(ValueError):
        comm.get_exchange("ring", "fp32", G, intra_drop_rate=0.1)
    with pytest.raises(ValueError) as e:      # unknown tier topologies
        comm.get_exchange("hierarchical", "fp32", **gx,
                          intra_topology="mesh")
    _assert_lists_alternatives(e, *comm.exchange.INTRA_TOPOLOGIES)
    with pytest.raises(ValueError) as e:
        comm.get_exchange("hierarchical", "fp32", **gx,
                          inter_topology="mesh")
    _assert_lists_alternatives(e, *comm.exchange.INTER_TOPOLOGIES)
    with pytest.raises(NotImplementedError) as e:   # delta intra codec
        comm.get_exchange("hierarchical", "int8", **gx)
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16")
    with pytest.raises(NotImplementedError) as e:   # push_sum + int8
        comm.get_exchange("hierarchical", "fp32", **gx,
                          inter_codec="int8")
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16")
    with pytest.raises(NotImplementedError) as e:   # topk cross-tier
        comm.get_exchange("hierarchical", "fp32", **gx,
                          inter_codec="topk")
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16", "int8")
    with pytest.raises(NotImplementedError) as e:   # lossy inter-server
        comm.get_exchange("hierarchical", "fp32", **gx,
                          inter_topology="server", drop_rate=0.1)
    assert "push_sum" in str(e.value)
    with pytest.raises(NotImplementedError) as e:
        comm.get_exchange("hierarchical", "fp32", **gx, overlap=True)
    _assert_lists_alternatives(e, "server", "ring", "gossip")
    with pytest.raises(NotImplementedError) as e:
        comm.get_exchange("hierarchical", "fp32", **gx,
                          downlink_codec="int8")
    assert "inter_codec" in str(e.value)


def test_flat_fault_plan_on_hierarchical_refused(key):
    """A flat FaultPlan does not say which tier it masks — the exchange
    refuses it instead of guessing."""
    import dataclasses
    x = jax.random.normal(key, (G, 8))
    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2)
    bad = dataclasses.replace(
        ex, fault_plan=faults_mod.FaultPlan(seed=0, drop_rate=0.2))
    with pytest.raises(NotImplementedError) as e:
        bad.streams({"params": x}, {}, bad.init(x))
    assert "TieredFaultPlan" in str(e.value)


# ---------------------------------------------------------------------------
# checkpoint: mid-fault resume with tiered backlogs is bit-exact
# ---------------------------------------------------------------------------


def test_checkpoint_resume_mid_fault_tiered_backlogs(key, tmp_path):
    """Save at round 3 with live per-tier fault schedules and queued
    cross-pod backlog mass, resume, and the continuation is bit-exact
    with the uninterrupted run — both tier's masks are pure in
    (round, tier seed lane), so the schedule replays."""
    from repro.checkpoint import io as ckpt_io

    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                           drop_rate=0.4, stall_rate=0.1,
                           intra_drop_rate=0.1, fault_seed=4, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(3):
        st, _ = rnd(st, batch)
    assert int(st["comm"]["round"]) == 3
    # mid-fault for real: queued cross-pod mass is in flight (the fault
    # schedule is pure in (round, seed), so this is deterministic)
    assert float(jnp.sum(st["comm"]["backlog_w"])) > 0.0
    assert mass_total(st["comm"]) == pytest.approx(G, abs=1e-3)
    path = str(tmp_path / "mid_fault_tiered")
    ckpt_io.save(path, st, metadata={"round": 3, "comm": ex.name})
    back = ckpt_io.load(path, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for _ in range(3):
        st, _ = rnd(st, batch)            # uninterrupted
        back, _ = rnd(back, batch)        # resumed
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# §13 round metrics: per-tier keys + wire identity
# ---------------------------------------------------------------------------


def test_round_metrics_carry_tier_keys_and_wire_identity(key):
    params, batch = make_problem(key)
    opt = optim.get("sgd", 0.05)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                           drop_rate=0.2, intra_drop_rate=0.05,
                           fault_seed=3)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, exchange=ex)
    st, m = rnd(st, batch)
    assert set(obs.round_metric_keys(("params",))) <= set(m)
    assert int(m["wire_bytes"]) \
        == int(m["wire_bytes_intra"]) + int(m["wire_bytes_inter"])
    assert int(m["wire_bytes_intra"]) > 0
    assert int(m["wire_bytes_inter"]) > 0
    for k in ("participation", "participation_intra",
              "participation_inter", "delivery_rate",
              "delivery_rate_intra", "delivery_rate_inter"):
        assert 0.0 <= float(m[k]) <= 1.0, (k, float(m[k]))
    assert float(m["delivery_rate_intra"]) \
        == pytest.approx(ex.delivery_rate_intra)
    assert float(m["delivery_rate_inter"]) \
        == pytest.approx(ex.delivery_rate_inter)
    # flat rounds carry the same keys with the single-tier conventions
    ex_flat = comm.get_exchange("ring", "fp32", G)
    rnd_f = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                          exchange=ex_flat))
    st_f = lsgd.init_state(params, opt, n_groups=G, exchange=ex_flat)
    _, mf = rnd_f(st_f, batch)
    assert set(obs.round_metric_keys(("params",))) <= set(mf)
    assert int(mf["wire_bytes_intra"]) == int(mf["wire_bytes"])
    assert int(mf["wire_bytes_inter"]) == 0
    assert float(mf["participation_inter"]) == 1.0
    assert float(mf["delivery_rate_inter"]) == 1.0


def test_adaptive_t_prices_tiers_on_their_own_links():
    """AdaptiveT.from_exchange prices the intra bytes on the fast link
    and the inter bytes on the DCN at the inter tier's delivery rate —
    slowing or losing the DCN makes comm pricier (smaller r, T* up)."""
    from repro.core.controller import AdaptiveT

    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                           inter_codec="bf16", drop_rate=0.1)
    fast = AdaptiveT.from_exchange(1e-3, ex, 1_000_000)
    slow = AdaptiveT.from_exchange(1e-3, ex, 1_000_000,
                                   inter_bandwidth_bytes_per_s=5e9)
    assert slow.r < fast.r
    # intra ring prices ATTEMPTS, so an intra loss rate raises the priced
    # cost (inter push_sum prices delivered edges — loss there cancels)
    lossless = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                                 inter_codec="bf16")
    lossy_ici = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                                  inter_codec="bf16", intra_drop_rate=0.2)
    assert (AdaptiveT.from_exchange(1e-3, lossy_ici, 1_000_000).r
            < AdaptiveT.from_exchange(1e-3, lossless, 1_000_000).r)


# ---------------------------------------------------------------------------
# 8-device mesh: sharded hierarchical parity + builder threading
# ---------------------------------------------------------------------------


def _packed_setup(key, sexec):
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1),
                               x0.shape) * 0.1 * mask
    return layout, x0, x


@needs8
@pytest.mark.parametrize("codec,kw", [
    ("fp32", dict(drop_rate=0.3, stall_rate=0.1, intra_drop_rate=0.05)),
    ("bf16", dict(drop_rate=0.08, stall_rate=0.05, inter_codec="bf16")),
    ("fp32", dict(intra_topology="server", inter_topology="server",
                  inter_codec="int8", intra_stall_rate=0.1)),
])
def test_sharded_hierarchical_matches_replicated(codec, kw, key):
    """THE §16 shard_map gate: per-tier masks, leader election inputs
    and int8 noise are generated OUTSIDE the shard_map block at full
    (G,) shape, so the sharded two-tier round consumes IDENTICAL fault
    schedules — outputs match the replicated path to reduction order
    and the mass/participation channels agree exactly."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    layout, x0, x = _packed_setup(key, sexec)
    ex = comm.get_exchange("hierarchical", codec, G, n_pods=2,
                           impl="jnp", fault_seed=6, **kw)
    st = ex.init(x0)
    fs = jax.jit(sexec.exchange_streams(ex, layout))
    fr = jax.jit(ex.streams)
    xs = {"params": x}
    xs0 = {"params": x0} if ex.lossy_stream("params") else {}
    os_, ss = fs(dict(xs), dict(xs0), st)
    or_, sr = fr(dict(xs), dict(xs0), st)
    np.testing.assert_allclose(np.asarray(os_["params"]),
                               np.asarray(or_["params"]),
                               rtol=1e-4, atol=1e-4)
    for k in ("participation", "participation_intra",
              "participation_inter"):
        assert float(ss[k]) == pytest.approx(float(sr[k]))
    assert int(ss["round"]) == int(sr["round"]) == 1
    if ex.inter_topology == "push_sum":
        np.testing.assert_allclose(np.asarray(ss["mass"]),
                                   np.asarray(sr["mass"]),
                                   rtol=1e-6, atol=1e-7)
        assert mass_total(ss) == pytest.approx(G, abs=1e-3)
        assert mass_total(sr) == pytest.approx(G, abs=1e-3)


@needs8
def test_sharded_hierarchical_multi_round_conserves_mass(key):
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    layout, x0, x = _packed_setup(key, sexec)
    ex = comm.get_exchange("hierarchical", "fp32", G, n_pods=2,
                           drop_rate=0.2, stall_rate=0.1, fault_seed=3)
    fs = jax.jit(sexec.exchange_streams(ex, layout))
    fr = jax.jit(ex.streams)
    ss = sr = ex.init(x0)
    xs_s = xs_r = x
    for _ in range(6):
        o_s, ss = fs({"params": xs_s}, {}, ss)
        o_r, sr = fr({"params": xs_r}, {}, sr)
        xs_s, xs_r = o_s["params"], o_r["params"]
        np.testing.assert_allclose(np.asarray(xs_s), np.asarray(xs_r),
                                   rtol=1e-4, atol=1e-4)
        assert mass_total(ss) == pytest.approx(G, abs=1e-3)
        assert mass_total(sr) == pytest.approx(G, abs=1e-3)


@needs8
def test_builder_threads_hierarchical_flags_sharded():
    """build_train_step threads --n-pods/--intra-*/--inter-* through to
    the exchange, allocates the cross-tier mass/backlog state with
    buffer-aligned shardings, reports the per-tier wire split in its
    meta, and the tiered faulty step compiles on the mesh."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = mesh8()
    shape = InputShape(name="tiny", kind="train", global_batch=8,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2, packed=True,
                             comm="hierarchical", codec="fp32",
                             n_pods=2, drop_rate=0.1,
                             intra_drop_rate=0.05, fault_seed=3)
    assert built.meta["comm"].startswith("hier[")
    by_tier = built.meta["wire_bytes_per_round_by_tier"]
    assert set(by_tier) == {"intra", "inter"}
    assert by_tier["intra"] > 0 and by_tier["inter"] > 0
    state_abs, _ = built.args
    assert {"mass", "backlog", "backlog_w", "round", "participation",
            "participation_intra", "participation_inter"} \
        <= set(state_abs["comm"])
    bl = state_abs["comm"]["backlog"]["params"]
    psh = built.in_shardings[0]["params"]
    bsh = built.in_shardings[0]["comm"]["backlog"]["params"]
    assert bsh.shard_shape(tuple(bl.shape))[1:] \
        == psh.shard_shape(tuple(state_abs["params"].shape))
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        jitted.lower(*built.args).compile()


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------


def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module with 8
    forced host devices in a subprocess (jax locks the device count at
    first init). CI's forced-8-device job runs the tests directly and
    skips this driver (REPRO_SHARDEXEC_CHILD, shared with
    test_shardexec.py)."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device hierarchical suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
