"""Sharding policies (§Perf machinery): dp / fsdp specs, the fsdp mesh,
embed gather-vs-onehot equivalence, and the slow-link collective
classifier."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch import hlo
from repro.models import build_model
from repro.models.api import _embed_lookup
from repro.models.layers import pdef
from repro.sharding import specs as sh


class FakeMesh:
    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_dp_policy_replicates():
    m = FakeMesh(data=16, model=16)
    d = pdef((1024, 6400), ("embed", "ff"))
    assert sh.spec_for(d, m, policy="dp") == P()
    assert sh.spec_for(d, m, leading=("data",), policy="dp") == P("data")


def test_fsdp_rule_shards_embed_axis():
    m = FakeMesh(data=2, fsdp=8, model=16)
    d = pdef((16384, 128, 128), ("embed", "heads", None))
    # heads=128 -> model; embed=16384 -> fsdp
    assert sh.spec_for(d, m) == P("fsdp", "model")
    # without an fsdp axis the rule is inert
    m2 = FakeMesh(data=16, model=16)
    assert sh.spec_for(d, m2) == P(None, "model")


def test_fsdp_rule_one_axis_each():
    m = FakeMesh(data=2, fsdp=8, model=16)
    # both dims map to fsdp? no - embed->fsdp only once
    d = pdef((1024, 512), ("embed", "ff"))
    assert sh.spec_for(d, m) == P("fsdp", "model")


def test_embed_gather_matches_onehot(key):
    V, D = 64, 16
    table = jax.random.normal(key, (V, D))
    toks = jax.random.randint(key, (2, 8), 0, V)
    a = _embed_lookup(table, toks, jnp.float32, "onehot")
    b = _embed_lookup(table, toks, jnp.float32, "gather")
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_model_with_gather_embed_runs(key):
    cfg = dataclasses.replace(get_config("qwen3-32b").reduced(),
                              embed_impl="gather")
    model = build_model(cfg, schedule="rect")
    p = model.init(key)
    loss = model.loss(p, {"tokens": jnp.zeros((2, 16), jnp.int32)})
    assert bool(jnp.isfinite(loss))


def test_layer_hooks_are_applied(key):
    """Hooks must not change values (identity semantics here) and must be
    called once per layer."""
    cfg = get_config("qwen3-32b").reduced()
    calls = {"p": 0, "a": 0}

    def ph(p):
        calls["p"] += 1
        return p

    def ah(x):
        calls["a"] += 1
        return x

    base = build_model(cfg, schedule="rect")
    hooked = build_model(cfg, schedule="rect", layer_param_hook=ph,
                         layer_act_hook=ah)
    params = base.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    l0 = base.loss(params, batch)
    l1 = hooked.loss(params, batch)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)
    assert calls["p"] >= 1 and calls["a"] >= 1


def test_production_mesh_fsdp_shape():
    # shape math only (needs no devices beyond validation in dryrun)
    from repro.launch import mesh as meshmod
    try:
        m = meshmod.make_production_mesh(fsdp=8)
    except RuntimeError:
        pytest.skip("needs 256 host devices (dry-run only)")
    assert m.axis_names == ("data", "fsdp", "model")


def test_groups_cross_slow():
    line_model = "all-reduce(%x), replica_groups=[16,16]<=[256]"
    line_data = "all-reduce(%x), replica_groups=[16,16]<=[16,16]T(1,0)"
    assert not hlo.groups_cross_slow(line_model, 16)
    assert hlo.groups_cross_slow(line_data, 16)
    # explicit form
    expl = "all-reduce(%x), replica_groups={{0,16,32},{1,17,33}}"
    assert hlo.groups_cross_slow(expl, 16)
    assert not hlo.groups_cross_slow(
        "all-reduce(%x), replica_groups={{0,1,2,3}}", 16)


def test_replica_group_members_iota():
    g = hlo.replica_group_members(
        "x, replica_groups=[4,4]<=[4,4]T(1,0)")
    assert g[0] == [0, 4, 8, 12]
    g2 = hlo.replica_group_members("x, replica_groups=[2,8]<=[16]")
    assert g2[0] == list(range(8))


def test_pallas_attention_path_matches_blocked(key):
    """cfg.attn_impl='pallas' routes causal self-attention through the
    Pallas flash kernel (interpret mode) and must agree with the blocked
    pure-JAX path."""
    import dataclasses

    from repro.models import attention as attn

    cfg = get_config("qwen3-32b").reduced()
    cfgp = dataclasses.replace(cfg, attn_impl="pallas")
    p_defs = attn.attention_defs(cfg)
    from repro.models.layers import init_params
    params = init_params(p_defs, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model))
    y_blocked = attn.attention_forward(params, x, cfg, schedule="tri",
                                       block=128)
    y_pallas = attn.attention_forward(params, x, cfgp, schedule="tri",
                                      block=128)
    np.testing.assert_allclose(y_pallas, y_blocked, atol=2e-4, rtol=2e-3)


def test_pallas_model_end_to_end(key):
    cfg = dataclasses.replace(get_config("qwen3-32b").reduced(),
                              attn_impl="pallas")
    model = build_model(cfg, attn_block=128)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 128), 0, cfg.vocab_size)
    loss = model.loss(params, {"tokens": toks})
    assert bool(jnp.isfinite(loss))
