"""Property tests for model numerics (hypothesis where randomized)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import get_config
from repro.models import attention as attn
from repro.models import moe as moem
from repro.models.api import _chunked_ce, _embed_lookup
from repro.models.layers import apply_rope, rms_norm, softmax_cross_entropy


# ---------------------------------------------------------------------------
# Blocked causal attention == full attention (both schedules)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["tri", "rect"])
@pytest.mark.parametrize("B,S,H,KV,hd,block", [
    (1, 8, 2, 2, 4, 2), (2, 16, 4, 2, 8, 4), (1, 32, 2, 1, 16, 8),
])
def test_blocked_equals_full(schedule, B, S, H, KV, hd, block, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    mask = jnp.tril(jnp.ones((S, S), bool))
    want = attn.full_attention(q, k, v, mask)
    got = attn.blocked_causal_attention(q, k, v, block, schedule)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_blocked_causality(key):
    B, S, H, hd, block = 1, 16, 2, 8, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    o1 = attn.blocked_causal_attention(q, k, v, block, "tri")
    k2 = k.at[:, -1].add(50.0)
    o2 = attn.blocked_causal_attention(q, k2, v, block, "tri")
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], atol=1e-6)


# ---------------------------------------------------------------------------
# Chunked CE == full CE; chunked embed == table lookup
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), b=st.integers(1, 3),
       s=st.sampled_from([4, 8, 16]), v=st.sampled_from([11, 32]))
def test_chunked_ce_matches_full(seed, b, s, v):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    d = 12
    x = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    mask = (jax.random.uniform(ks[2], (b, s)) > 0.3)
    got = _chunked_ce(x, w, labels, mask, chunk=4)
    want = softmax_cross_entropy(x @ w, labels, mask)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_embed_lookup_matches_take(key):
    V, D, B, S = 50, 16, 2, 12
    table = jax.random.normal(key, (V, D))
    toks = jax.random.randint(key, (B, S), 0, V)
    got = _embed_lookup(table, toks, jnp.float32)
    want = table[toks]
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RoPE / RMSNorm
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_rope_preserves_norm(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 6, 2, 8))
    pos = jnp.arange(6)[None]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-5)


def test_rope_relative_property(key):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def score(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10_000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))

    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(5, 5) - score(0, 0)) < 1e-4


def test_rms_norm_unit_variance(key):
    x = jax.random.normal(key, (4, 64)) * 7.0
    w = jnp.ones((64,))
    y = rms_norm(x, w)
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# MoE: dispatch == densemask when capacity is unbounded
# ---------------------------------------------------------------------------


def test_moe_dispatch_matches_densemask(key):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    defs = moem.moe_defs(cfg)
    from repro.models.layers import init_params
    p = init_params(defs, key)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    y_dense, aux_d = moem.moe_densemask(p, x, cfg)
    # capacity_factor big enough that no token is dropped
    y_disp, aux_s = moem.moe_dispatch(p, x, cfg,
                                      capacity_factor=float(cfg.n_experts))
    np.testing.assert_allclose(y_disp, y_dense, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(aux_d, aux_s, rtol=1e-5)


def test_moe_decode_matches_forward(key):
    """Single-token decode path == full forward at S=1."""
    cfg = get_config("granite-moe-1b-a400m").reduced()
    defs = moem.moe_defs(cfg)
    from repro.models.layers import init_params
    p = init_params(defs, key)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 1, cfg.d_model))
    y_fwd, _ = moem.moe_densemask(p, x, cfg)
    y_dec, _ = moem.moe_decode(p, x, cfg)
    np.testing.assert_allclose(y_dec, y_fwd, atol=1e-4, rtol=1e-4)


def test_moe_router_gates_normalized(key):
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    defs = moem.moe_defs(cfg)
    from repro.models.layers import init_params
    p = init_params(defs, key)
    x = jax.random.normal(key, (2, 4, cfg.d_model))
    gates, idx, aux = moem.router(p, x, cfg)
    np.testing.assert_allclose(jnp.sum(gates, -1), 1.0, atol=1e-5)
    assert gates.shape[-1] == cfg.top_k
    assert bool(jnp.all(idx >= 0)) and bool(jnp.all(idx < cfg.n_experts))
    assert float(aux) >= 1.0 - 1e-3  # Switch aux >= 1 at balance
