"""The paper's algorithm (core.localsgd): unit + property tests.

Key invariants tested:
  * one local-SGD round with T=1 equals one synchronous-DP step,
  * the round is EXACTLY Alg 1 (manual numpy re-implementation agrees),
  * threshold mode (T_i = inf) stops at ||grad||^2 <= eps,
  * Lemma 1: d(x_n, S) is non-increasing for any T (hypothesis sweep),
  * averaging is the mean; groups end identical after a round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import optim
from repro.core import localsgd as lsgd
from repro.data.convex import (distance_to_intersection,
                               random_intersecting_quadratics)


def quadratic_loss_fn(w_dim=6):
    """loss(params, batch) with batch = {"A": (r,d), "b": (r,)} giving
    f(w) = 0.5 ||A w - b||^2 — convex, smooth."""

    def loss(params, batch):
        r = batch["A"] @ params["w"] - batch["b"]
        return 0.5 * jnp.sum(r ** 2)

    return loss


def make_group_batch(key, G, r, d):
    ks = jax.random.split(key, 2)
    A = jax.random.normal(ks[0], (G, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    b = jnp.einsum("grd,d->gr", A, w_star)  # consistent -> S nonempty
    return {"A": A, "b": b}, w_star


def test_average_groups_is_mean(key):
    x = jax.random.normal(key, (4, 3, 2))
    out = lsgd.average_groups({"p": x})["p"]
    want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_replicate_shapes(key):
    tree = {"a": jnp.ones((2, 3)), "b": jnp.zeros(())}
    rep = lsgd.replicate(tree, 5)
    assert rep["a"].shape == (5, 2, 3)
    assert rep["b"].shape == (5,)


def test_round_matches_manual_alg1(key):
    """Exact agreement with a numpy re-implementation of the paper Alg 1."""
    G, r, d, T, lr = 3, 4, 6, 5, 0.05
    loss = quadratic_loss_fn(d)
    batch, _ = make_group_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(7), (d,))
    opt = optim.sgd(lr)
    state = lsgd.init_state({"w": w0}, opt, n_groups=G)
    rnd = lsgd.make_local_round(
        loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=T))
    new_state, metrics = rnd(state, batch)

    # manual: each worker does T GD steps from w0 on its own (A_i, b_i)
    A = np.asarray(batch["A"]); b = np.asarray(batch["b"])
    ws = []
    for i in range(G):
        w = np.asarray(w0, np.float64)
        for _ in range(T):
            g = A[i].T @ (A[i] @ w - b[i])
            w = w - lr * g
        ws.append(w)
    want = np.mean(ws, axis=0)
    got = np.asarray(new_state["params"]["w"][0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # all groups identical after averaging
    for i in range(G):
        np.testing.assert_allclose(
            new_state["params"]["w"][i], got, rtol=1e-6)
    assert int(metrics["inner_steps"][0]) == T


def test_t1_round_equals_sync_step(key):
    """Local round with T=1 == conventional sync-DP step (same lr, data)."""
    G, r, d, lr = 4, 3, 5, 0.1
    loss = quadratic_loss_fn(d)
    batch, _ = make_group_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(3), (d,))
    opt = optim.sgd(lr)

    state_l = lsgd.init_state({"w": w0}, opt, n_groups=G)
    rnd = lsgd.make_local_round(
        loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=1))
    out_l, _ = rnd(state_l, batch)

    # sync: mean over group losses == (1/G) sum f_i
    def global_loss(params, batch):
        return jnp.mean(jax.vmap(lambda A, b: loss(params, {"A": A, "b": b})
                                 )(batch["A"], batch["b"]))

    step = lsgd.make_sync_step(global_loss, opt)
    out_s, _ = step(lsgd.init_state({"w": w0}, opt), batch)
    np.testing.assert_allclose(
        out_l["params"]["w"][0], out_s["params"]["w"], rtol=1e-5, atol=1e-6)


def test_threshold_mode_stops_at_eps(key):
    """T_i = infinity: local GD runs until ||grad_i||^2 <= eps."""
    G, r, d, lr, eps = 2, 3, 8, 0.2, 1e-8
    loss = quadratic_loss_fn(d)
    batch, _ = make_group_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (d,))
    opt = optim.sgd(lr)
    state = lsgd.init_state({"w": w0}, opt, n_groups=G)
    rnd = lsgd.make_local_round(
        loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=1,
                                       threshold=eps, max_inner=10_000))
    new_state, metrics = rnd(state, batch)
    assert bool(jnp.all(metrics["grad_sq"] <= eps))
    assert bool(jnp.all(metrics["inner_steps"] < 10_000))
    assert bool(jnp.all(metrics["inner_steps"] > 1))


def test_threshold_mode_respects_cap(key):
    G, r, d = 2, 3, 8
    loss = quadratic_loss_fn(d)
    batch, _ = make_group_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (d,))
    opt = optim.sgd(1e-4)  # tiny lr: cannot reach eps in 5 steps
    state = lsgd.init_state({"w": w0}, opt, n_groups=G)
    rnd = lsgd.make_local_round(
        loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=1,
                                       threshold=1e-20, max_inner=5))
    _, metrics = rnd(state, batch)
    assert bool(jnp.all(metrics["inner_steps"] == 5))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 5), t=st.integers(1, 20),
       seed=st.integers(0, 10_000))
def test_lemma1_distance_nonincreasing(m, t, seed):
    """Lemma 1: d(x_n, S)^2 non-increasing for intersecting quadratics,
    any T_i, constant lr < 2/L."""
    from repro.core.reference import make_local_T

    d, rank = 12, 3
    key = jax.random.PRNGKey(seed)
    losses, w_star, mats = random_intersecting_quadratics(key, m, d, rank)
    L = max(float(jnp.linalg.norm(A, 2) ** 2) for A in mats)
    lr = 1.0 / L  # < 2/L -> alpha > 0
    runners = [make_local_T(f, lr, t) for f in losses]

    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,)) * 3.0
    d_prev = float(distance_to_intersection(w, mats, w_star))
    for _ in range(4):  # 4 communication rounds
        w = jnp.mean(jnp.stack([run(w)[0] for run in runners]), axis=0)
        d_new = float(distance_to_intersection(w, mats, w_star))
        assert d_new <= d_prev + 1e-6, (d_prev, d_new)
        d_prev = d_new


def test_more_local_steps_fewer_rounds(key):
    """Paper Question 2: larger T reaches a target in fewer rounds."""
    from repro.core.reference import make_local_T

    m, d, rank = 2, 8, 3
    losses, w_star, mats = random_intersecting_quadratics(key, m, d, rank)
    L = max(float(jnp.linalg.norm(A, 2) ** 2) for A in mats)
    lr = 1.0 / L
    w0 = jax.random.normal(jax.random.PRNGKey(5), (d,)) * 3.0

    def rounds_to(target, T, max_rounds=400):
        runners = [make_local_T(f, lr, T) for f in losses]
        w = w0
        for n in range(max_rounds):
            if float(distance_to_intersection(w, mats, w_star)) < target:
                return n
            w = jnp.mean(jnp.stack([r(w)[0] for r in runners]), axis=0)
        return max_rounds

    r1 = rounds_to(1e-2, 1)
    r10 = rounds_to(1e-2, 10)
    r100 = rounds_to(1e-2, 100)
    assert r10 < r1, (r1, r10, r100)
    # T=100 can saturate at the same round count as T=10 once the local
    # problems are solved to optimality each round (T_i -> inf regime)
    assert r100 <= r10 + 1, (r1, r10, r100)


def test_server_params(key):
    state = lsgd.init_state({"w": jnp.ones((3,))}, optim.sgd(0.1),
                            n_groups=4)
    state["params"]["w"] = state["params"]["w"] * jnp.arange(
        4.0)[:, None]
    got = lsgd.server_params(state)["w"]
    np.testing.assert_allclose(got, jnp.full((3,), 1.5), rtol=1e-6)
