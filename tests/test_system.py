"""End-to-end system tests: the full production path (model + local-SGD
rounds + optimizer + data pipeline) actually learns, and threshold /
adaptive-T modes work through the jitted round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import get_config
from repro.core import localsgd as lsgd
from repro.core.controller import AdaptiveT
from repro.data.synthetic import fixed_group_batches
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-mlp").reduced()
    model = build_model(cfg, schedule="rect")
    params = model.init(jax.random.PRNGKey(0))
    G, b, S = 2, 2, 32
    batch = {"tokens": jnp.asarray(
        fixed_group_batches(cfg.vocab_size, S, G, b)["tokens"])}
    return cfg, model, params, G, batch


def test_localsgd_training_descends(setup):
    cfg, model, params, G, batch = setup
    opt = optim.sgd(0.05)
    rnd = jax.jit(lsgd.make_local_round(
        model.loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=5)))
    state = lsgd.init_state(params, opt, n_groups=G)
    losses = []
    for _ in range(8):
        state, m = rnd(state, batch)
        losses.append(float(jnp.mean(m["loss"])))
    assert losses[-1] < 0.8 * losses[0], losses
    # all groups hold the identical averaged model after a round
    for leaf in jax.tree.leaves(state["params"]):
        np.testing.assert_allclose(leaf[0], leaf[-1], rtol=1e-6)


def test_localsgd_beats_sync_per_round(setup):
    """Paper's claim on the real model: at equal communication rounds,
    T=5 local steps reach lower loss than T=1 (sync-equivalent)."""
    cfg, model, params, G, batch = setup
    opt = optim.sgd(0.05)

    def run(T, rounds=6):
        rnd = jax.jit(lsgd.make_local_round(
            model.loss, opt,
            lsgd.LocalSGDConfig(n_groups=G, inner_steps=T)))
        state = lsgd.init_state(params, opt, n_groups=G)
        for _ in range(rounds):
            state, m = rnd(state, batch)
        return float(jnp.mean(m["loss"]))

    assert run(5) < run(1)


def test_threshold_mode_on_real_model(setup):
    cfg, model, params, G, batch = setup
    opt = optim.sgd(0.05)
    rnd = jax.jit(lsgd.make_local_round(
        model.loss, opt,
        lsgd.LocalSGDConfig(n_groups=G, inner_steps=1, threshold=1e-1,
                            max_inner=50)))
    state = lsgd.init_state(params, opt, n_groups=G)
    state, m = rnd(state, batch)
    assert bool(jnp.all(m["inner_steps"] >= 1))
    assert bool(jnp.all(jnp.isfinite(m["loss"])))


def test_adaptive_t_on_real_trajectory(setup):
    cfg, model, params, G, batch = setup
    opt = optim.sgd(0.05)
    rnd = jax.jit(lsgd.make_local_round(
        model.loss, opt, lsgd.LocalSGDConfig(n_groups=G, inner_steps=20)))
    state = lsgd.init_state(params, opt, n_groups=G)
    state, m = rnd(state, batch)
    ctl = AdaptiveT(r=0.01, ema=0.0)
    t = ctl.update(np.asarray(m["grad_sq_traj"])[0])
    assert 1 <= t <= ctl.t_max
    assert ctl.history, "controller must record the fit"
