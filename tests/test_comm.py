"""Communication subsystem (repro.comm): topologies, codecs, exchanges.

Acceptance-critical invariants (ISSUE 2 / DESIGN.md §8):
  * mixing matrices are doubly stochastic with positive spectral gap, and
    repeated mixing contracts to the G-mean (consensus),
  * the server backend with the fp32 codec is BIT-EXACT with the
    pre-refactor ``average_groups`` on both pytree and packed rounds,
  * int8/topk codecs round-trip within their scale tolerance; the Pallas
    quantize kernels agree with the jnp reference on the same rounding
    bits,
  * error-feedback residuals account exactly: what top-k drops this round
    is re-offered next round (zero drift),
  * every backend preserves the G-mean, wire bytes are exact, and the
    unsupported combinations refuse instead of silently degrading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, optim
from repro.core import localsgd as lsgd
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.optim import packing

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2) + 0.1 * jnp.sum(params["u"] ** 2)


def make_problem(key, g=G, r=4, d=6):
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,)),
              "u": jax.random.normal(ks[3], (2, 3))}
    return params, batch


# ---------------------------------------------------------------------------
# topologies: doubly stochastic, spectral gap, consensus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["server", "ring", "gossip"])
@pytest.mark.parametrize("m", [1, 2, 3, 4, 7, 16])
def test_mixing_matrix_doubly_stochastic(name, m):
    w = comm.mixing_matrix(name, m, seed=3)
    assert w.shape == (m, m)
    assert comm.is_doubly_stochastic(w)


@pytest.mark.parametrize("name", ["server", "ring", "gossip"])
@pytest.mark.parametrize("m", [3, 5, 8])
def test_mixing_converges_to_consensus(name, m):
    """spectral gap > 0 => W^k x -> mean(x) at rate (1 - gap)^k."""
    w = comm.mixing_matrix(name, m, seed=1)
    gap = comm.spectral_gap(w)
    assert gap > 0.0, (name, m, gap)
    rng = np.random.RandomState(0)
    x = rng.randn(m, 5)
    y = x.copy()
    k = 80
    for _ in range(k):
        y = w @ y
    err = np.abs(y - x.mean(axis=0)).max()
    assert err <= (1.0 - gap) ** k * np.abs(x).max() * m + 1e-9, \
        (name, m, err)


def test_gossip_deterministic_per_seed():
    a = comm.gossip_matrix(8, seed=5)
    b = comm.gossip_matrix(8, seed=5)
    c = comm.gossip_matrix(8, seed=6)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_server_matrix_one_step_consensus():
    w = comm.server_matrix(5)
    x = np.arange(15.0).reshape(5, 3)
    np.testing.assert_allclose(w @ x, np.broadcast_to(x.mean(0), (5, 3)))


# ---------------------------------------------------------------------------
# codecs: round-trips, error feedback, wire bytes
# ---------------------------------------------------------------------------


def test_cast_codec_roundtrip(key):
    x = jax.random.normal(key, (G, 100))
    for name, tol in (("fp16", 1e-3), ("bf16", 1e-2)):
        c = comm.get_codec(name)
        out, state = c.compress(x, {})
        assert state == {}
        np.testing.assert_allclose(out, x, rtol=tol, atol=tol)
        assert c.wire_bytes(100) == 200


def test_int8_roundtrip_within_chunk_scale(key):
    """Stochastic rounding moves each element by at most one quantization
    step (the chunk's scale); padding chunks never leak."""
    chunk = 64
    c = comm.get_codec("int8", chunk=chunk, impl="jnp")
    x = jax.random.normal(key, (G, 150)) * 3.0      # 150: ragged chunks
    out, state = c.compress(x, c.init(x))
    assert int(state["count"]) == 1
    rows = packing.chunk_rows(x, chunk)
    scales = jnp.max(jnp.abs(rows), axis=-1, keepdims=True) / 127.0
    err = jnp.abs(packing.chunk_rows(out, chunk) - rows)
    assert bool(jnp.all(err <= scales + 1e-7))
    # payload: 1 byte/elem + one fp32 scale per chunk
    assert c.wire_bytes(150) == 150 + 4 * 3


def test_int8_deterministic_and_unbiased(key):
    c = comm.get_codec("int8", impl="jnp")
    x = jax.random.normal(key, (2, 4096))
    out1, _ = c.compress(x, c.init(x))
    out2, _ = c.compress(x, c.init(x))
    np.testing.assert_array_equal(out1, out2)     # same counter, same bits
    # different counter -> different bits, but zero-mean error
    out3, _ = c.compress(x, {"count": jnp.asarray(7, jnp.int32)})
    assert not np.array_equal(out1, out3)
    assert abs(float(jnp.mean(out1 - x))) < 1e-3


def test_int8_pallas_matches_jnp(key):
    """Both impls consume the same rounding bits -> identical output."""
    cj = comm.get_codec("int8", impl="jnp")
    cp = comm.get_codec("int8", impl="pallas")
    x = jax.random.normal(key, (G, 300))
    oj, _ = cj.compress(x, cj.init(x))
    op, _ = cp.compress(x, cp.init(x))
    np.testing.assert_allclose(op, oj, atol=1e-7)


@pytest.mark.parametrize("rows,chunk", [(1, 64), (6, 256), (13, 128)])
def test_quantize_kernels_vs_oracle(rows, chunk, key):
    """kernels/quantize.py vs the jnp math on the same noise."""
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (rows, chunk)) * 2.0
    u = jax.random.uniform(ks[1], (rows, chunk))
    q, scales = quantize_int8(x, u, interpret=True)
    assert q.dtype == jnp.int8 and scales.shape == (rows, 1)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    want_s = jnp.where(amax > 0, amax / 127.0, 1.0)
    np.testing.assert_allclose(scales, want_s, rtol=1e-6)
    want_q = jnp.clip(jnp.floor(x / want_s + u), -127, 127)
    np.testing.assert_array_equal(q, want_q.astype(jnp.int8))
    out = dequantize_int8(q, scales, interpret=True)
    np.testing.assert_allclose(out, q.astype(jnp.float32) * scales,
                               rtol=1e-6)


def test_quantize_kernel_zero_chunk():
    """An all-zero chunk must quantize to zeros (scale guard)."""
    x = jnp.zeros((2, 64))
    u = jnp.full((2, 64), 0.5)
    q, s = quantize_int8(x, u, interpret=True)
    np.testing.assert_array_equal(q, jnp.zeros((2, 64), jnp.int8))
    np.testing.assert_array_equal(s, jnp.ones((2, 1)))


def test_topk_error_feedback_zero_drift(key):
    """delta + residual_in == delta_hat + residual_out EXACTLY: what the
    wire drops this round is carried, not lost."""
    c = comm.get_codec("topk", topk_frac=0.25)
    x = jax.random.normal(key, (G, 40))
    state = c.init(x)
    for i in range(4):
        delta = jnp.roll(x, i, axis=-1) * (i + 1)
        e_in = state["residual"]
        out, state = c.compress(delta, state)
        # the per-round accounting identity is EXACT: the residual update
        # is the same subtraction that defines what the wire dropped
        np.testing.assert_array_equal(delta + e_in,
                                      out + state["residual"])
        # at most k entries per row on the wire
        k = max(1, round(0.25 * 40))
        assert int(jnp.max(jnp.sum(out != 0.0, axis=-1))) <= k
    assert c.wire_bytes(40) == 8 * 10


# ---------------------------------------------------------------------------
# exchanges: parity, mean preservation, staleness, wire bytes
# ---------------------------------------------------------------------------


def test_server_fp32_bit_exact_with_average_groups_pytree(key):
    """The acceptance parity: the refactored round (server/fp32 through
    comm.Exchange) is BIT-EXACT with averaging the ungrouped round's
    locals via the pre-refactor average_groups. Eager execution: op-by-op
    identical arithmetic."""
    params, batch = make_problem(key)
    opt = optim.momentum(0.05)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    # "none" topology = the local steps with NO communication
    rnd_none = lsgd.make_local_round(
        quad_loss, opt, cfg, exchange=comm.get_exchange("none", "fp32", G))
    rnd_server = lsgd.make_local_round(
        quad_loss, opt, cfg,
        exchange=comm.get_exchange("server", "fp32", G))
    st = lsgd.init_state(params, opt, n_groups=G)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd_server(st, batch)
    want_p = lsgd.average_groups(locals_["params"])
    want_o = lsgd.average_groups(locals_["opt"])
    for a, b in zip(jax.tree.leaves(got["params"]),
                    jax.tree.leaves(want_p)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(got["opt"]), jax.tree.leaves(want_o)):
        np.testing.assert_array_equal(a, b)


def test_server_fp32_bit_exact_with_average_groups_packed(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    rnd_none = lsgd.make_local_round(
        quad_loss, opt, cfg, layout=layout,
        exchange=comm.get_exchange("none", "fp32", G))
    rnd_server = lsgd.make_local_round(quad_loss, opt, cfg, layout=layout)
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd_server(st, batch)
    np.testing.assert_array_equal(
        got["params"], lsgd.average_groups(locals_["params"]))
    np.testing.assert_array_equal(
        got["opt"]["mu"], lsgd.average_groups(locals_["opt"]["mu"]))
    np.testing.assert_array_equal(got["opt"]["count"],
                                  locals_["opt"]["count"])


@pytest.mark.parametrize("topology", ["ring", "gossip"])
def test_decentralized_exchange_preserves_mean(topology, key):
    """Doubly-stochastic mixing keeps the G-mean invariant: decentralized
    rounds optimize the same average objective as the server."""
    ex = comm.get_exchange(topology, "fp32", G, mix_rounds=2)
    x = jax.random.normal(key, (G, 37))
    mixed, state = ex.params(x, None, {})
    assert state == {}
    np.testing.assert_allclose(jnp.mean(mixed, 0), jnp.mean(x, 0),
                               rtol=1e-5, atol=1e-6)
    # groups do NOT reach exact consensus in one ring hop...
    assert float(jnp.abs(mixed - jnp.mean(x, 0)).max()) > 1e-3
    # ...but many hops contract toward it
    ex_k = dataclasses.replace(ex, mix_rounds=60)
    near, _ = ex_k.params(x, None, {})
    assert float(jnp.abs(near - jnp.mean(x, 0)).max()) < 1e-3


def test_decentral_lossy_recompresses_per_hop(key):
    """Multi-hop ring/gossip applies the codec at EVERY mixing hop (each
    hop's payload is a fresh wire transmission — the byte accounting
    always counted per hop; the noise model now matches): the int8 rng
    counter advances once per hop, and error feedback (top-k residual)
    updates per hop while its exact accounting identity still closes over
    the whole round."""
    k = 3
    ex = comm.get_exchange("ring", "int8", 8, mix_rounds=k)
    x0 = jnp.zeros((8, 512))
    x = jax.random.normal(key, (8, 512)) * 0.1
    state = ex.init(x0)
    _, state = ex.params(x, x0, state)
    # per-stream codec state (DESIGN.md §10): the params stream's rng
    # counter advances once per hop
    assert int(state["codec"]["params"]["count"]) == k
    # top-k per-hop error feedback: after the round, delta-minus-residual
    # equals the sum of everything transmitted (nothing lost, only delayed)
    ex_t = comm.get_exchange("ring", "topk", 8, mix_rounds=2,
                             topk_frac=0.1)
    state_t = ex_t.init(x0)
    out_t, state_t = ex_t.params(x, x0, state_t)
    resid = state_t["codec"]["params"]["residual"]
    assert bool(jnp.all(jnp.isfinite(resid)))
    # mean preservation still holds under per-hop top-k: the mixing is
    # doubly stochastic over the DECODED payloads, so the output mean is
    # the input mean minus exactly what still sits in the residual
    want = jnp.mean(x - resid, axis=0)
    np.testing.assert_allclose(jnp.mean(out_t, 0), want,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_per_hop_codec_consensus_contracts(codec, key):
    """The ROADMAP follow-up check: with the codec applied at every hop,
    repeated mixing still CONTRACTS disagreement (the per-hop noise is
    bounded by the per-chunk scale / absorbed by error feedback, so it
    cannot undo the spectral-gap contraction at these magnitudes)."""
    m, k = 8, 4
    ex = comm.get_exchange("ring", codec, m, mix_rounds=k, topk_frac=0.25)
    x0 = jnp.zeros((m, 512))
    x = jax.random.normal(key, (m, 512))
    state = ex.init(x0)
    out, _ = ex.params(x, x0, state)
    dis_in = float(jnp.abs(x - jnp.mean(x, 0)).max())
    dis_out = float(jnp.abs(out - jnp.mean(out, 0)).max())
    # ring(8): |lambda_2| ~ 0.80 -> 4 hops contract to ~0.42; leave head-
    # room for codec noise but require a real contraction
    assert dis_out < 0.7 * dis_in, (codec, dis_in, dis_out)


def test_async_stale_s0_equals_server(key):
    ex0 = comm.get_exchange("async_stale", "fp32", G, staleness=0)
    x = jax.random.normal(key, (G, 11))
    state = ex0.init(x * 0.0)
    out, state = ex0.params(x, None, state)
    want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    np.testing.assert_array_equal(out, want)


def test_async_stale_bounded_staleness(key):
    """s=1: each round only half the groups refresh their push; the
    average mixes fresh models with <= 1-round-old ones, deterministically
    (numpy re-simulation agrees)."""
    s = 1
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=s)
    x0 = jax.random.normal(key, (G, 5))
    state = ex.init(x0)
    pushed_ref = np.asarray(x0).copy()
    for rnd_i in range(4):
        x = x0 + (rnd_i + 1) * jnp.arange(G)[:, None]
        out, state = ex.params(x, None, state)
        fresh = (np.arange(G) + rnd_i) % (s + 1) == 0
        pushed_ref[fresh] = np.asarray(x)[fresh]
        np.testing.assert_allclose(
            np.asarray(out),
            np.broadcast_to(pushed_ref.mean(0), (G, 5)), rtol=1e-6)
    assert int(state["round"]) == 4


def test_wire_bytes_accounting():
    n = 1000
    cases = {
        ("server", "fp32"): G * 4 * n,
        ("server", "fp16"): G * 2 * n,
        ("server", "int8"): G * (n + 4 * 4),          # 4 chunks of 256
        ("server", "topk"): G * 8 * 50,               # k = 5% of 1000
        ("none", "fp32"): 0,
    }
    for (topo, codec), want in cases.items():
        ex = comm.get_exchange(topo, codec, G)
        assert ex.wire_bytes_up(n) == want, (topo, codec)
        # server broadcast: every group also PULLS the new average at the
        # same codec width; none has no wire at all
        assert ex.wire_bytes_down(n) == want, (topo, codec)
        assert ex.wire_bytes_per_round(n) == 2 * want, (topo, codec)
    # ring: one payload per directed edge per hop (G=4 ring: 8 edges);
    # peer-to-peer symmetry — every edge payload is one node's uplink and
    # its neighbor's downlink, i.e. the SAME transmission seen from both
    # endpoints: the total counts it once (no double-counting)
    ex = comm.get_exchange("ring", "fp32", G, mix_rounds=3)
    assert ex.wire_bytes_up(n) == 8 * 3 * 4 * n
    assert ex.wire_bytes_down(n) == ex.wire_bytes_up(n)
    assert ex.wire_bytes_per_round(n) == ex.wire_bytes_up(n)
    # async s=1: half the groups push per round (amortized), and the
    # downlink answers each push with the fresh average (pull-on-push)
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=1)
    assert ex.wire_bytes_up(n) == G // 2 * 4 * n
    assert ex.wire_bytes_down(n) == G // 2 * 4 * n
    # moment buffers ride at fp32 width
    ex = comm.get_exchange("server", "int8", G)
    assert ex.wire_bytes_up(n, moment_elems=2 * n) == \
        G * ((n + 16) + 4 * 2 * n)
    assert ex.wire_bytes_per_round(n, moment_elems=2 * n) == \
        2 * G * ((n + 16) + 4 * 2 * n)


def test_round_metrics_report_wire_bytes(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    n = layout.size
    opt = optim.packed("adamw", 0.01, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    _, m = rnd(st, batch)
    # adamw: m and v buffers averaged at fp32; count not exchanged
    assert int(m["wire_bytes"]) == ex.wire_bytes_per_round(n, 2 * n)
    assert int(m["wire_bytes_up"]) == ex.wire_bytes_up(n, 2 * n)
    assert int(m["wire_bytes_down"]) == ex.wire_bytes_down(n, 2 * n)
    assert int(m["wire_bytes"]) == (int(m["wire_bytes_up"])
                                    + int(m["wire_bytes_down"]))
    # pytree path: the moment leaves count, the counter never does
    # (it is not exchanged on either path); server up == down
    opt_t = optim.momentum(0.05)
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    _, mt = rnd_t(lsgd.init_state(params, opt_t, n_groups=G), batch)
    assert int(mt["wire_bytes_up"]) == 4 * G * (n + n)
    assert int(mt["wire_bytes"]) == 2 * 4 * G * (n + n)


def test_pytree_counts_stay_lockstep_under_mixing(key):
    """The int32 step counter is never exchanged (map_moments convention,
    both paths): mixing it through the f32 gossip matmul used to truncate
    and drift per-group counts, corrupting adamw's bias correction."""
    params, batch = make_problem(key)
    opt = optim.adamw(0.01)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("gossip", "fp32", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G)
    for _ in range(30):
        st, _ = rnd(st, batch)
    c = np.asarray(st["opt"]["count"])
    assert c.dtype == np.int32
    np.testing.assert_array_equal(c, np.full(G, 60, np.int32))


def test_int8_round_converges(key):
    """Delta-coded quantized communication preserves convergence on the
    feasibility problem (the benchmark checks the full frontier)."""
    params, batch = make_problem(key, r=3, d=8)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.2, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    ex = comm.get_exchange("server", "int8", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, m0 = rnd(st, batch)
    for _ in range(60):
        st, m = rnd(st, batch)
    assert float(jnp.mean(m["grad_sq"])) < 1e-3 * float(
        jnp.mean(m0["grad_sq"]))


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_flat_only_codec_needs_layout(key):
    params, _ = make_problem(key)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    for codec in ("int8", "topk"):
        with pytest.raises(NotImplementedError):
            lsgd.make_local_round(
                quad_loss, optim.sgd(0.1), cfg,
                exchange=comm.get_exchange("server", codec, G))


def test_async_stale_averages_opt_state_with_staleness_buffers(key):
    """The lifted restriction (DESIGN.md §10): async_stale keeps one
    staleness buffer PER STREAM (params under "pushed", each moment under
    "pushed_opt"), so rounds may average opt state. The moments follow
    the same deterministic push schedule as the params."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)  # avg_opt default
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=1)
    assert ex.supports_opt_state_averaging
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    assert set(st["comm"]) == {"pushed", "pushed_opt", "round"}
    assert st["comm"]["pushed_opt"]["mu"].shape == st["params"].shape
    # numpy re-simulation of the per-stream staleness schedule
    pushed_ref = {"params": np.asarray(st["params"]).copy(),
                  "mu": np.asarray(st["opt"]["mu"]).copy()}
    for rnd_i in range(4):
        pre = {"params": st["params"], "mu": st["opt"]["mu"]}
        st, _ = rnd(st, batch)
        fresh = (np.arange(G) + rnd_i) % 2 == 0
        # re-run the local steps without comm to get this round's locals
        ex_none = comm.get_exchange("none", "fp32", G)
        rnd_none = jax.jit(lsgd.make_local_round(
            quad_loss, opt, cfg, layout=layout, exchange=ex_none))
        loc, _ = rnd_none({"params": pre["params"],
                           "opt": {"count": st["opt"]["count"] - 2,
                                   "mu": pre["mu"]}}, batch)
        for name, val in (("params", loc["params"]),
                          ("mu", loc["opt"]["mu"])):
            pushed_ref[name][fresh] = np.asarray(val)[fresh]
        np.testing.assert_allclose(
            np.asarray(st["params"]),
            np.broadcast_to(pushed_ref["params"].mean(0),
                            st["params"].shape), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(st["opt"]["mu"]),
            np.broadcast_to(pushed_ref["mu"].mean(0),
                            st["opt"]["mu"].shape), rtol=1e-5, atol=1e-6)
        # the NEXT round mixes from the refreshed buffers, so keep the
        # reference in sync with what the round actually pushed
        pushed_ref = {"params": np.asarray(st["comm"]["pushed"]).copy(),
                      "mu": np.asarray(st["comm"]["pushed_opt"]["mu"])
                      .copy()}


def test_stateful_exchange_needs_init_state(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.1, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "topk", G)
    rnd = lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                exchange=ex)
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)  # no comm
    with pytest.raises(ValueError):
        rnd(st, batch)


def test_exchange_group_mismatch_raises(key):
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    with pytest.raises(ValueError):
        lsgd.make_local_round(
            quad_loss, optim.sgd(0.1), cfg,
            exchange=comm.get_exchange("server", "fp32", G + 1))


def test_async_stale_refuses_topk():
    """Staleness drops non-pushed rounds by design; error feedback would
    absorb their top-k entries as delivered and silently lose them."""
    with pytest.raises(NotImplementedError):
        comm.get_exchange("async_stale", "topk", G, staleness=1)


def test_none_topology_skips_codec(key):
    """A no-comm baseline must not inject quantization noise (and must
    report zero wire bytes)."""
    ex = comm.get_exchange("none", "int8", G)
    x = jax.random.normal(key, (G, 50))
    x0 = jnp.zeros_like(x)
    # no wire -> no codec state either: nothing to allocate or carry
    assert not ex.stateful and ex.init(x0) == {}
    out, _ = ex.params(x, x0, {})
    np.testing.assert_array_equal(out, x)
    assert ex.wire_bytes_per_round(50) == 0
    # and no layout requirement: the flat-only codec never executes
    params = {"w": jnp.zeros(5)}
    lsgd.make_local_round(quad_loss, optim.sgd(0.1),
                          lsgd.LocalSGDConfig(n_groups=G, inner_steps=1),
                          exchange=ex)


def test_builder_meta_wire_bytes_counts_moments():
    """Dry-run meta must agree with the round's own metrics["wire_bytes"]
    (adamw: 2 moment buffers ride at fp32)."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = make_local_mesh(1, 1)
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2,
                             opt_name="adamw", packed=True)
    n = built.meta["n_flat"]
    ex = comm.get_exchange("server", "fp32", built.meta["groups"])
    assert built.meta["wire_bytes_per_round"] == \
        ex.wire_bytes_per_round(n, 2 * n)


def test_unknown_names_raise():
    with pytest.raises(ValueError):
        comm.get_exchange("mesh?", "fp32", G)
    with pytest.raises(ValueError):
        comm.get_codec("fp8")
    with pytest.raises(ValueError):
        comm.mixing_matrix("star", 4)


# ---------------------------------------------------------------------------
# multi-stream payloads: per-stream codec policy (DESIGN.md §10)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt_name", ["momentum", "adamw"])
@pytest.mark.parametrize("topology", ["server", "ring"])
def test_fp32_moment_codec_bit_exact_vs_map_moments(opt_name, topology,
                                                    key):
    """THE §10 parity gate (replicated): with moment_codec=fp32 the
    stream exchange must be BIT-exact with the old map_moments path —
    run the locals with no comm, then mix params and moments by hand
    with exch.params + optim.map_moments(exch.mix) and compare."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed(opt_name, 0.03, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    ex = comm.get_exchange(topology, "fp32", G, mix_rounds=2)
    assert ex.mcodec.identity
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    rnd_none = jax.jit(lsgd.make_local_round(
        quad_loss, opt, cfg, layout=layout,
        exchange=comm.get_exchange("none", "fp32", G)))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd(st, batch)
    want_p, _ = ex.params(locals_["params"], None, {})
    want_o = optim.map_moments(ex.mix, locals_["opt"])
    np.testing.assert_array_equal(np.asarray(got["params"]),
                                  np.asarray(want_p))
    for k in locals_["opt"]:
        np.testing.assert_array_equal(np.asarray(got["opt"][k]),
                                      np.asarray(want_o[k]), err_msg=k)


def test_moment_codec_wire_accounting_per_stream():
    """Per-stream accounting (§10): each moment stream through the
    moment codec (the fp32 surcharge is gone), old totals == sums."""
    n = 1024
    ms = {"m": n, "v": n}
    ex = comm.get_exchange("server", "int8", G, moment_codec="int8")
    pb = n + 4 * 4                      # int8 payload: 4 chunks of 256
    by = ex.wire_bytes_by_stream(n, ms)
    assert by == {"params": 2 * G * pb, "m": 2 * G * pb, "v": 2 * G * pb}
    assert ex.wire_bytes_per_round(n, moment_sizes=ms) \
        == sum(by.values())
    assert ex.wire_bytes_up(n, moment_sizes=ms) == 3 * G * pb
    assert ex.wire_bytes_down(n, moment_sizes=ms) == 3 * G * pb
    # bf16 moments: 2 bytes/elem while params stay int8
    ex2 = comm.get_exchange("server", "int8", G, moment_codec="bf16")
    by2 = ex2.wire_bytes_by_stream(n, ms)
    assert by2["params"] == 2 * G * pb
    assert by2["m"] == by2["v"] == 2 * G * 2 * n
    # legacy single-blob moment_elems stays the old fp32 number
    ex3 = comm.get_exchange("server", "int8", G)
    assert ex3.wire_bytes_up(n, moment_elems=2 * n) == \
        G * (pb + 4 * 2 * n)
    # p2p totals count each edge payload once, per stream too
    ex4 = comm.get_exchange("ring", "fp32", G, moment_codec="bf16")
    by4 = ex4.wire_bytes_by_stream(n, ms)
    assert by4["params"] == 8 * 4 * n           # G=4 ring: 8 edges
    assert by4["m"] == 8 * 2 * n
    assert ex4.wire_bytes_per_round(n, moment_sizes=ms) == \
        ex4.wire_bytes_up(n, moment_sizes=ms)


def test_moment_codec_round_metrics_per_stream(key):
    """Round metrics report wire_bytes/<stream> with the totals as exact
    sums (adamw: params + m + v through their own codecs)."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    n = layout.size
    opt = optim.packed("adamw", 0.01, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G, moment_codec="int8")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    _, m = rnd(st, batch)
    by = ex.wire_bytes_by_stream(n, {"m": n, "v": n})
    for k, v in by.items():
        assert int(m[f"wire_bytes/{k}"]) == v, k
    assert int(m["wire_bytes"]) == sum(by.values())
    assert int(m["wire_bytes"]) == (int(m["wire_bytes_up"])
                                    + int(m["wire_bytes_down"]))
    # vs the old accounting: moments no longer ride at 4 bytes/elem
    old_total = comm.get_exchange("server", "int8", G).wire_bytes_per_round(
        n, moment_elems=2 * n)
    assert int(m["wire_bytes"]) < old_total


def test_moment_codec_per_stream_state(key):
    """Each stream keeps its OWN codec state: adamw + int8 everywhere
    gives three rng counters (params/m/v), all advancing per round."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("adamw", 0.01, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G, moment_codec="int8")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    assert set(st["comm"]["codec"]) == {"params", "m", "v"}
    for _ in range(3):
        st, _ = rnd(st, batch)
    for k in ("params", "m", "v"):
        assert int(st["comm"]["codec"][k]["count"]) == 3, k


@pytest.mark.parametrize("moment_codec", ["bf16", "int8"])
def test_lossy_moment_codec_converges_and_tracks_fp32(moment_codec, key):
    """Lossy moment codecs on the feasibility problem: delta coding makes
    the moment quantization error vanish with convergence — the run
    converges AND tracks the fp32-moments run closely."""
    params, batch = make_problem(key, r=3, d=8)
    layout = packing.layout_of(params)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    outs = {}
    for mc in ("fp32", moment_codec):
        opt = optim.packed("momentum", 0.05, impl="jnp")
        ex = comm.get_exchange("server", "int8", G, moment_codec=mc)
        rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                            layout=layout, exchange=ex))
        st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                             exchange=ex)
        st, m0 = rnd(st, batch)
        for _ in range(80):
            st, m = rnd(st, batch)
        assert float(jnp.mean(m["grad_sq"])) < 1e-4 * float(
            jnp.mean(m0["grad_sq"])), mc
        outs[mc] = np.asarray(st["params"][0])
    scale = np.abs(outs["fp32"]).max() + 1e-12
    rel = np.abs(outs[moment_codec] - outs["fp32"]).max() / scale
    assert rel <= 1e-2, (moment_codec, rel)


def test_nonneg_moment_stream_clamped(key):
    """adamw's v must never go negative through a lossy moment codec
    (sqrt(v) would NaN): the round projects it back onto [0, inf)."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("adamw", 0.05, impl="jnp")
    assert opt.moment_nonneg == ("v",)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G, moment_codec="int8")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(5):
        st, _ = rnd(st, batch)
        assert bool(jnp.all(st["opt"]["v"] >= 0.0))
        assert bool(jnp.all(jnp.isfinite(st["params"])))


def test_topk_moment_codec_refused():
    """topk moments stay excluded (§10): error feedback would re-offer
    rounds-stale moment mass."""
    with pytest.raises(NotImplementedError):
        comm.get_exchange("server", "fp32", G, moment_codec="topk")
    with pytest.raises(NotImplementedError):
        comm.get_exchange("ring", "int8", G, moment_codec="topk")


def test_flat_only_moment_codec_needs_layout(key):
    """int8 moments need the packed flat buffers; cast moment codecs
    (bf16) run on the pytree path too."""
    params, batch = make_problem(key)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    with pytest.raises(NotImplementedError):
        lsgd.make_local_round(
            quad_loss, optim.momentum(0.05), cfg,
            exchange=comm.get_exchange("server", "fp32", G,
                                       moment_codec="int8"))
    # average_opt_state=False: the moment codec never runs -> no refusal
    cfg_off = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2,
                                  average_opt_state=False)
    lsgd.make_local_round(
        quad_loss, optim.momentum(0.05), cfg_off,
        exchange=comm.get_exchange("server", "fp32", G,
                                   moment_codec="int8"))
    # bf16 moments on the pytree path: runs, and the moments move
    ex = comm.get_exchange("server", "fp32", G, moment_codec="bf16")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, optim.momentum(0.05),
                                        cfg, exchange=ex))
    st = lsgd.init_state(params, optim.momentum(0.05), n_groups=G)
    out, m = rnd(st, batch)
    assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(out["opt"]["mu"])[0])))
    # bf16 moments halve the moment wire term in the metrics
    n = sum(l.size for l in jax.tree.leaves(params))
    assert int(m["wire_bytes/mu"]) == 2 * G * 2 * n


def test_adaptive_t_from_exchange_prices_moment_streams():
    """AdaptiveT.from_exchange: r reflects the moment codec (§10) — int8
    moments make comm cheaper, so r rises with the full stream payload
    priced, not the fp32-moments assumption."""
    from repro.core.controller import AdaptiveT

    n = 1_000_000
    ms = {"m": n, "v": n}
    step = 2e-6
    ctl_fp32 = AdaptiveT.from_exchange(
        step, comm.get_exchange("server", "int8", 2), n, ms)
    ctl_int8 = AdaptiveT.from_exchange(
        step, comm.get_exchange("server", "int8", 2, moment_codec="int8"),
        n, ms)
    assert ctl_int8.r > 2.5 * ctl_fp32.r
    ex = comm.get_exchange("server", "int8", 2, moment_codec="int8")
    want = ex.wire_bytes_per_round(n, moment_sizes=ms)
    assert abs(ctl_int8.r - step / (want / 50e9)) < 1e-12


@pytest.mark.parametrize("s_stale", [1, 2])
def test_async_avg_opt_state_converges(s_stale, key):
    """The §10 acceptance run: async_stale with average_opt_state=True
    (per-stream staleness buffers) converges on the convex feasibility
    problem under bounded staleness s — moments riding the stale
    averaging must not destabilize it."""
    params, batch = make_problem(key, r=3, d=8)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)  # avg_opt on
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=s_stale)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, m0 = rnd(st, batch)
    for _ in range(120):
        st, m = rnd(st, batch)
    assert float(jnp.mean(m["grad_sq"])) < 1e-6 * float(
        jnp.mean(m0["grad_sq"])), s_stale
    # the staleness wire amortization prices the moment stream too:
    # amortized senders G/(s+1) times the fp32 moment payload, up+down
    n = layout.size
    want = 2 * int(round(G / (s_stale + 1) * 4 * n))
    assert int(m["wire_bytes/mu"]) == want
    assert ex.wire_bytes_by_stream(n, {"mu": n})["mu"] == want
