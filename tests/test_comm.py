"""Communication subsystem (repro.comm): topologies, codecs, exchanges.

Acceptance-critical invariants (ISSUE 2 / DESIGN.md §8):
  * mixing matrices are doubly stochastic with positive spectral gap, and
    repeated mixing contracts to the G-mean (consensus),
  * the server backend with the fp32 codec is BIT-EXACT with the
    pre-refactor ``average_groups`` on both pytree and packed rounds,
  * int8/topk codecs round-trip within their scale tolerance; the Pallas
    quantize kernels agree with the jnp reference on the same rounding
    bits,
  * error-feedback residuals account exactly: what top-k drops this round
    is re-offered next round (zero drift),
  * every backend preserves the G-mean, wire bytes are exact, and the
    unsupported combinations refuse instead of silently degrading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, optim
from repro.core import localsgd as lsgd
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.optim import packing

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2) + 0.1 * jnp.sum(params["u"] ** 2)


def make_problem(key, g=G, r=4, d=6):
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,)),
              "u": jax.random.normal(ks[3], (2, 3))}
    return params, batch


# ---------------------------------------------------------------------------
# topologies: doubly stochastic, spectral gap, consensus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["server", "ring", "gossip"])
@pytest.mark.parametrize("m", [1, 2, 3, 4, 7, 16])
def test_mixing_matrix_doubly_stochastic(name, m):
    w = comm.mixing_matrix(name, m, seed=3)
    assert w.shape == (m, m)
    assert comm.is_doubly_stochastic(w)


@pytest.mark.parametrize("name", ["server", "ring", "gossip"])
@pytest.mark.parametrize("m", [3, 5, 8])
def test_mixing_converges_to_consensus(name, m):
    """spectral gap > 0 => W^k x -> mean(x) at rate (1 - gap)^k."""
    w = comm.mixing_matrix(name, m, seed=1)
    gap = comm.spectral_gap(w)
    assert gap > 0.0, (name, m, gap)
    rng = np.random.RandomState(0)
    x = rng.randn(m, 5)
    y = x.copy()
    k = 80
    for _ in range(k):
        y = w @ y
    err = np.abs(y - x.mean(axis=0)).max()
    assert err <= (1.0 - gap) ** k * np.abs(x).max() * m + 1e-9, \
        (name, m, err)


def test_gossip_deterministic_per_seed():
    a = comm.gossip_matrix(8, seed=5)
    b = comm.gossip_matrix(8, seed=5)
    c = comm.gossip_matrix(8, seed=6)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_server_matrix_one_step_consensus():
    w = comm.server_matrix(5)
    x = np.arange(15.0).reshape(5, 3)
    np.testing.assert_allclose(w @ x, np.broadcast_to(x.mean(0), (5, 3)))


# ---------------------------------------------------------------------------
# codecs: round-trips, error feedback, wire bytes
# ---------------------------------------------------------------------------


def test_cast_codec_roundtrip(key):
    x = jax.random.normal(key, (G, 100))
    for name, tol in (("fp16", 1e-3), ("bf16", 1e-2)):
        c = comm.get_codec(name)
        out, state = c.compress(x, {})
        assert state == {}
        np.testing.assert_allclose(out, x, rtol=tol, atol=tol)
        assert c.wire_bytes(100) == 200


def test_int8_roundtrip_within_chunk_scale(key):
    """Stochastic rounding moves each element by at most one quantization
    step (the chunk's scale); padding chunks never leak."""
    chunk = 64
    c = comm.get_codec("int8", chunk=chunk, impl="jnp")
    x = jax.random.normal(key, (G, 150)) * 3.0      # 150: ragged chunks
    out, state = c.compress(x, c.init(x))
    assert int(state["count"]) == 1
    rows = packing.chunk_rows(x, chunk)
    scales = jnp.max(jnp.abs(rows), axis=-1, keepdims=True) / 127.0
    err = jnp.abs(packing.chunk_rows(out, chunk) - rows)
    assert bool(jnp.all(err <= scales + 1e-7))
    # payload: 1 byte/elem + one fp32 scale per chunk
    assert c.wire_bytes(150) == 150 + 4 * 3


def test_int8_deterministic_and_unbiased(key):
    c = comm.get_codec("int8", impl="jnp")
    x = jax.random.normal(key, (2, 4096))
    out1, _ = c.compress(x, c.init(x))
    out2, _ = c.compress(x, c.init(x))
    np.testing.assert_array_equal(out1, out2)     # same counter, same bits
    # different counter -> different bits, but zero-mean error
    out3, _ = c.compress(x, {"count": jnp.asarray(7, jnp.int32)})
    assert not np.array_equal(out1, out3)
    assert abs(float(jnp.mean(out1 - x))) < 1e-3


def test_int8_pallas_matches_jnp(key):
    """Both impls consume the same rounding bits -> identical output."""
    cj = comm.get_codec("int8", impl="jnp")
    cp = comm.get_codec("int8", impl="pallas")
    x = jax.random.normal(key, (G, 300))
    oj, _ = cj.compress(x, cj.init(x))
    op, _ = cp.compress(x, cp.init(x))
    np.testing.assert_allclose(op, oj, atol=1e-7)


@pytest.mark.parametrize("rows,chunk", [(1, 64), (6, 256), (13, 128)])
def test_quantize_kernels_vs_oracle(rows, chunk, key):
    """kernels/quantize.py vs the jnp math on the same noise."""
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (rows, chunk)) * 2.0
    u = jax.random.uniform(ks[1], (rows, chunk))
    q, scales = quantize_int8(x, u, interpret=True)
    assert q.dtype == jnp.int8 and scales.shape == (rows, 1)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    want_s = jnp.where(amax > 0, amax / 127.0, 1.0)
    np.testing.assert_allclose(scales, want_s, rtol=1e-6)
    want_q = jnp.clip(jnp.floor(x / want_s + u), -127, 127)
    np.testing.assert_array_equal(q, want_q.astype(jnp.int8))
    out = dequantize_int8(q, scales, interpret=True)
    np.testing.assert_allclose(out, q.astype(jnp.float32) * scales,
                               rtol=1e-6)


def test_quantize_kernel_zero_chunk():
    """An all-zero chunk must quantize to zeros (scale guard)."""
    x = jnp.zeros((2, 64))
    u = jnp.full((2, 64), 0.5)
    q, s = quantize_int8(x, u, interpret=True)
    np.testing.assert_array_equal(q, jnp.zeros((2, 64), jnp.int8))
    np.testing.assert_array_equal(s, jnp.ones((2, 1)))


def test_topk_error_feedback_zero_drift(key):
    """delta + residual_in == delta_hat + residual_out EXACTLY: what the
    wire drops this round is carried, not lost."""
    c = comm.get_codec("topk", topk_frac=0.25)
    x = jax.random.normal(key, (G, 40))
    state = c.init(x)
    for i in range(4):
        delta = jnp.roll(x, i, axis=-1) * (i + 1)
        e_in = state["residual"]
        out, state = c.compress(delta, state)
        # the per-round accounting identity is EXACT: the residual update
        # is the same subtraction that defines what the wire dropped
        np.testing.assert_array_equal(delta + e_in,
                                      out + state["residual"])
        # at most k entries per row on the wire
        k = max(1, round(0.25 * 40))
        assert int(jnp.max(jnp.sum(out != 0.0, axis=-1))) <= k
    assert c.wire_bytes(40) == 8 * 10


# ---------------------------------------------------------------------------
# exchanges: parity, mean preservation, staleness, wire bytes
# ---------------------------------------------------------------------------


def test_server_fp32_bit_exact_with_average_groups_pytree(key):
    """The acceptance parity: the refactored round (server/fp32 through
    comm.Exchange) is BIT-EXACT with averaging the ungrouped round's
    locals via the pre-refactor average_groups. Eager execution: op-by-op
    identical arithmetic."""
    params, batch = make_problem(key)
    opt = optim.momentum(0.05)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    # "none" topology = the local steps with NO communication
    rnd_none = lsgd.make_local_round(
        quad_loss, opt, cfg, exchange=comm.get_exchange("none", "fp32", G))
    rnd_server = lsgd.make_local_round(
        quad_loss, opt, cfg,
        exchange=comm.get_exchange("server", "fp32", G))
    st = lsgd.init_state(params, opt, n_groups=G)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd_server(st, batch)
    want_p = lsgd.average_groups(locals_["params"])
    want_o = lsgd.average_groups(locals_["opt"])
    for a, b in zip(jax.tree.leaves(got["params"]),
                    jax.tree.leaves(want_p)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(got["opt"]), jax.tree.leaves(want_o)):
        np.testing.assert_array_equal(a, b)


def test_server_fp32_bit_exact_with_average_groups_packed(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    rnd_none = lsgd.make_local_round(
        quad_loss, opt, cfg, layout=layout,
        exchange=comm.get_exchange("none", "fp32", G))
    rnd_server = lsgd.make_local_round(quad_loss, opt, cfg, layout=layout)
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)
    locals_, _ = rnd_none(jax.tree.map(jnp.copy, st), batch)
    got, _ = rnd_server(st, batch)
    np.testing.assert_array_equal(
        got["params"], lsgd.average_groups(locals_["params"]))
    np.testing.assert_array_equal(
        got["opt"]["mu"], lsgd.average_groups(locals_["opt"]["mu"]))
    np.testing.assert_array_equal(got["opt"]["count"],
                                  locals_["opt"]["count"])


@pytest.mark.parametrize("topology", ["ring", "gossip"])
def test_decentralized_exchange_preserves_mean(topology, key):
    """Doubly-stochastic mixing keeps the G-mean invariant: decentralized
    rounds optimize the same average objective as the server."""
    ex = comm.get_exchange(topology, "fp32", G, mix_rounds=2)
    x = jax.random.normal(key, (G, 37))
    mixed, state = ex.params(x, None, {})
    assert state == {}
    np.testing.assert_allclose(jnp.mean(mixed, 0), jnp.mean(x, 0),
                               rtol=1e-5, atol=1e-6)
    # groups do NOT reach exact consensus in one ring hop...
    assert float(jnp.abs(mixed - jnp.mean(x, 0)).max()) > 1e-3
    # ...but many hops contract toward it
    ex_k = dataclasses.replace(ex, mix_rounds=60)
    near, _ = ex_k.params(x, None, {})
    assert float(jnp.abs(near - jnp.mean(x, 0)).max()) < 1e-3


def test_decentral_lossy_recompresses_per_hop(key):
    """Multi-hop ring/gossip applies the codec at EVERY mixing hop (each
    hop's payload is a fresh wire transmission — the byte accounting
    always counted per hop; the noise model now matches): the int8 rng
    counter advances once per hop, and error feedback (top-k residual)
    updates per hop while its exact accounting identity still closes over
    the whole round."""
    k = 3
    ex = comm.get_exchange("ring", "int8", 8, mix_rounds=k)
    x0 = jnp.zeros((8, 512))
    x = jax.random.normal(key, (8, 512)) * 0.1
    state = ex.init(x0)
    _, state = ex.params(x, x0, state)
    assert int(state["codec"]["count"]) == k      # one compress per hop
    # top-k per-hop error feedback: after the round, delta-minus-residual
    # equals the sum of everything transmitted (nothing lost, only delayed)
    ex_t = comm.get_exchange("ring", "topk", 8, mix_rounds=2,
                             topk_frac=0.1)
    state_t = ex_t.init(x0)
    out_t, state_t = ex_t.params(x, x0, state_t)
    assert bool(jnp.all(jnp.isfinite(state_t["codec"]["residual"])))
    # mean preservation still holds under per-hop top-k: the mixing is
    # doubly stochastic over the DECODED payloads, so the output mean is
    # the input mean minus exactly what still sits in the residual
    want = jnp.mean(x - state_t["codec"]["residual"], axis=0)
    np.testing.assert_allclose(jnp.mean(out_t, 0), want,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_per_hop_codec_consensus_contracts(codec, key):
    """The ROADMAP follow-up check: with the codec applied at every hop,
    repeated mixing still CONTRACTS disagreement (the per-hop noise is
    bounded by the per-chunk scale / absorbed by error feedback, so it
    cannot undo the spectral-gap contraction at these magnitudes)."""
    m, k = 8, 4
    ex = comm.get_exchange("ring", codec, m, mix_rounds=k, topk_frac=0.25)
    x0 = jnp.zeros((m, 512))
    x = jax.random.normal(key, (m, 512))
    state = ex.init(x0)
    out, _ = ex.params(x, x0, state)
    dis_in = float(jnp.abs(x - jnp.mean(x, 0)).max())
    dis_out = float(jnp.abs(out - jnp.mean(out, 0)).max())
    # ring(8): |lambda_2| ~ 0.80 -> 4 hops contract to ~0.42; leave head-
    # room for codec noise but require a real contraction
    assert dis_out < 0.7 * dis_in, (codec, dis_in, dis_out)


def test_async_stale_s0_equals_server(key):
    ex0 = comm.get_exchange("async_stale", "fp32", G, staleness=0)
    x = jax.random.normal(key, (G, 11))
    state = ex0.init(x * 0.0)
    out, state = ex0.params(x, None, state)
    want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    np.testing.assert_array_equal(out, want)


def test_async_stale_bounded_staleness(key):
    """s=1: each round only half the groups refresh their push; the
    average mixes fresh models with <= 1-round-old ones, deterministically
    (numpy re-simulation agrees)."""
    s = 1
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=s)
    x0 = jax.random.normal(key, (G, 5))
    state = ex.init(x0)
    pushed_ref = np.asarray(x0).copy()
    for rnd_i in range(4):
        x = x0 + (rnd_i + 1) * jnp.arange(G)[:, None]
        out, state = ex.params(x, None, state)
        fresh = (np.arange(G) + rnd_i) % (s + 1) == 0
        pushed_ref[fresh] = np.asarray(x)[fresh]
        np.testing.assert_allclose(
            np.asarray(out),
            np.broadcast_to(pushed_ref.mean(0), (G, 5)), rtol=1e-6)
    assert int(state["round"]) == 4


def test_wire_bytes_accounting():
    n = 1000
    cases = {
        ("server", "fp32"): G * 4 * n,
        ("server", "fp16"): G * 2 * n,
        ("server", "int8"): G * (n + 4 * 4),          # 4 chunks of 256
        ("server", "topk"): G * 8 * 50,               # k = 5% of 1000
        ("none", "fp32"): 0,
    }
    for (topo, codec), want in cases.items():
        ex = comm.get_exchange(topo, codec, G)
        assert ex.wire_bytes_up(n) == want, (topo, codec)
        # server broadcast: every group also PULLS the new average at the
        # same codec width; none has no wire at all
        assert ex.wire_bytes_down(n) == want, (topo, codec)
        assert ex.wire_bytes_per_round(n) == 2 * want, (topo, codec)
    # ring: one payload per directed edge per hop (G=4 ring: 8 edges);
    # peer-to-peer symmetry — every edge payload is one node's uplink and
    # its neighbor's downlink, i.e. the SAME transmission seen from both
    # endpoints: the total counts it once (no double-counting)
    ex = comm.get_exchange("ring", "fp32", G, mix_rounds=3)
    assert ex.wire_bytes_up(n) == 8 * 3 * 4 * n
    assert ex.wire_bytes_down(n) == ex.wire_bytes_up(n)
    assert ex.wire_bytes_per_round(n) == ex.wire_bytes_up(n)
    # async s=1: half the groups push per round (amortized), and the
    # downlink answers each push with the fresh average (pull-on-push)
    ex = comm.get_exchange("async_stale", "fp32", G, staleness=1)
    assert ex.wire_bytes_up(n) == G // 2 * 4 * n
    assert ex.wire_bytes_down(n) == G // 2 * 4 * n
    # moment buffers ride at fp32 width
    ex = comm.get_exchange("server", "int8", G)
    assert ex.wire_bytes_up(n, moment_elems=2 * n) == \
        G * ((n + 16) + 4 * 2 * n)
    assert ex.wire_bytes_per_round(n, moment_elems=2 * n) == \
        2 * G * ((n + 16) + 4 * 2 * n)


def test_round_metrics_report_wire_bytes(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    n = layout.size
    opt = optim.packed("adamw", 0.01, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    _, m = rnd(st, batch)
    # adamw: m and v buffers averaged at fp32; count not exchanged
    assert int(m["wire_bytes"]) == ex.wire_bytes_per_round(n, 2 * n)
    assert int(m["wire_bytes_up"]) == ex.wire_bytes_up(n, 2 * n)
    assert int(m["wire_bytes_down"]) == ex.wire_bytes_down(n, 2 * n)
    assert int(m["wire_bytes"]) == (int(m["wire_bytes_up"])
                                    + int(m["wire_bytes_down"]))
    # pytree path: the moment leaves count, the counter never does
    # (it is not exchanged on either path); server up == down
    opt_t = optim.momentum(0.05)
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    _, mt = rnd_t(lsgd.init_state(params, opt_t, n_groups=G), batch)
    assert int(mt["wire_bytes_up"]) == 4 * G * (n + n)
    assert int(mt["wire_bytes"]) == 2 * 4 * G * (n + n)


def test_pytree_counts_stay_lockstep_under_mixing(key):
    """The int32 step counter is never exchanged (map_moments convention,
    both paths): mixing it through the f32 gossip matmul used to truncate
    and drift per-group counts, corrupting adamw's bias correction."""
    params, batch = make_problem(key)
    opt = optim.adamw(0.01)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("gossip", "fp32", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G)
    for _ in range(30):
        st, _ = rnd(st, batch)
    c = np.asarray(st["opt"]["count"])
    assert c.dtype == np.int32
    np.testing.assert_array_equal(c, np.full(G, 60, np.int32))


def test_int8_round_converges(key):
    """Delta-coded quantized communication preserves convergence on the
    feasibility problem (the benchmark checks the full frontier)."""
    params, batch = make_problem(key, r=3, d=8)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.2, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    ex = comm.get_exchange("server", "int8", G)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                        exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, m0 = rnd(st, batch)
    for _ in range(60):
        st, m = rnd(st, batch)
    assert float(jnp.mean(m["grad_sq"])) < 1e-3 * float(
        jnp.mean(m0["grad_sq"]))


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_flat_only_codec_needs_layout(key):
    params, _ = make_problem(key)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    for codec in ("int8", "topk"):
        with pytest.raises(NotImplementedError):
            lsgd.make_local_round(
                quad_loss, optim.sgd(0.1), cfg,
                exchange=comm.get_exchange("server", codec, G))


def test_async_stale_refuses_opt_state_averaging(key):
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)  # avg_opt default
    with pytest.raises(NotImplementedError):
        lsgd.make_local_round(
            quad_loss, optim.packed("sgd", 0.1, impl="jnp"), cfg,
            layout=layout,
            exchange=comm.get_exchange("async_stale", "fp32", G))


def test_stateful_exchange_needs_init_state(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.1, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "topk", G)
    rnd = lsgd.make_local_round(quad_loss, opt, cfg, layout=layout,
                                exchange=ex)
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout)  # no comm
    with pytest.raises(ValueError):
        rnd(st, batch)


def test_exchange_group_mismatch_raises(key):
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    with pytest.raises(ValueError):
        lsgd.make_local_round(
            quad_loss, optim.sgd(0.1), cfg,
            exchange=comm.get_exchange("server", "fp32", G + 1))


def test_async_stale_refuses_topk():
    """Staleness drops non-pushed rounds by design; error feedback would
    absorb their top-k entries as delivered and silently lose them."""
    with pytest.raises(NotImplementedError):
        comm.get_exchange("async_stale", "topk", G, staleness=1)


def test_none_topology_skips_codec(key):
    """A no-comm baseline must not inject quantization noise (and must
    report zero wire bytes)."""
    ex = comm.get_exchange("none", "int8", G)
    x = jax.random.normal(key, (G, 50))
    x0 = jnp.zeros_like(x)
    # no wire -> no codec state either: nothing to allocate or carry
    assert not ex.stateful and ex.init(x0) == {}
    out, _ = ex.params(x, x0, {})
    np.testing.assert_array_equal(out, x)
    assert ex.wire_bytes_per_round(50) == 0
    # and no layout requirement: the flat-only codec never executes
    params = {"w": jnp.zeros(5)}
    lsgd.make_local_round(quad_loss, optim.sgd(0.1),
                          lsgd.LocalSGDConfig(n_groups=G, inner_steps=1),
                          exchange=ex)


def test_builder_meta_wire_bytes_counts_moments():
    """Dry-run meta must agree with the round's own metrics["wire_bytes"]
    (adamw: 2 moment buffers ride at fp32)."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = make_local_mesh(1, 1)
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2,
                             opt_name="adamw", packed=True)
    n = built.meta["n_flat"]
    ex = comm.get_exchange("server", "fp32", built.meta["groups"])
    assert built.meta["wire_bytes_per_round"] == \
        ex.wire_bytes_per_round(n, 2 * n)


def test_unknown_names_raise():
    with pytest.raises(ValueError):
        comm.get_exchange("mesh?", "fp32", G)
    with pytest.raises(ValueError):
        comm.get_codec("fp8")
    with pytest.raises(ValueError):
        comm.mixing_matrix("star", 4)
